"""In-silico federation driver: jitted FedALIGN rounds in a python loop,
evaluation + history logging. This is the engine behind every paper
experiment (benchmarks/bench_*.py).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import History
from repro.core.round import make_round_fn
from repro.data.synth import Federation
from repro.utils import tree_axpy


def evaluate(loss_fn, params, x, y, batch=4096):
    """Mean loss and accuracy over a test set (jitted: eager CNN eval on a
    1-core host was the dominant cost of whole benchmark suites)."""
    jitted = jax.jit(loss_fn)   # jax caches by fn identity across calls
    n = y.shape[0]
    losses, accs, cnt = [], [], 0
    for i in range(0, n, batch):
        b = {"x": jnp.asarray(x[i:i + batch]), "y": jnp.asarray(y[i:i + batch])}
        loss, m = jitted(params, b)
        w = b["y"].shape[0]
        losses.append(float(loss) * w)
        accs.append(float(m["acc"]) * w)
        cnt += w
    return sum(losses) / cnt, sum(accs) / cnt


def run_federation(loss_fn: Callable, init_params, fed, federation: Federation,
                   *, eval_every: int = 1, verbose: bool = False) -> History:
    """Run ``fed.rounds`` FedALIGN communication rounds."""
    round_fn = jax.jit(make_round_fn(loss_fn, fed))
    data = {"x": jnp.asarray(federation.x), "y": jnp.asarray(federation.y)}
    pm = jnp.asarray(federation.priority_mask)
    w = jnp.asarray(federation.weights)
    params = init_params
    rng = jax.random.PRNGKey(fed.seed)
    hist = History()

    # beyond-paper: FedAvgM-style server momentum over aggregated deltas
    use_server_m = fed.server_opt == "momentum"
    server_m = jax.tree.map(jnp.zeros_like, params) if use_server_m else None

    @jax.jit
    def apply_server_momentum(old, new, m):
        delta = jax.tree.map(jnp.subtract, new, old)
        m = jax.tree.map(lambda mi, d: fed.server_momentum * mi + d, m, delta)
        upd = jax.tree.map(lambda o, mi: o + fed.server_lr * mi, old, m)
        return upd, m

    for r in range(fed.rounds):
        rng, rkey = jax.random.split(rng)
        new_params, stats = round_fn(params, data, pm, w, rkey, jnp.int32(r))
        if use_server_m:
            params, server_m = apply_server_momentum(params, new_params, server_m)
        else:
            params = new_params
        if r % eval_every == 0 or r == fed.rounds - 1:
            tl, ta = evaluate(loss_fn, params, federation.test_x, federation.test_y)
            hist.log(stats, test_acc=ta, test_loss=tl)
            if verbose:
                print(f"  round {r:4d} loss={float(stats['global_loss']):.4f} "
                      f"test_acc={ta:.4f} inc={float(stats['included_nonpriority']):.1f}")
        else:
            hist.log(stats)
    hist.params = params
    return hist


def run_local_baseline(loss_fn, init_fn, fed, federation: Federation,
                       *, epochs: int = None, client_ids=None):
    """Paper App. C.1: train each client alone on its local data; report the
    per-client locally-trained model accuracy on the global test set."""
    from repro.core.round import _local_solver
    epochs = epochs or fed.rounds * fed.local_epochs
    fed_local = fed
    solver = _local_solver(loss_fn, fed_local)
    C = federation.x.shape[0]
    client_ids = client_ids if client_ids is not None else range(C)
    rng = jax.random.PRNGKey(fed.seed + 1)

    @jax.jit
    def train_one(d, key, params0):
        # reuse the E-epoch solver repeatedly to reach `epochs`
        def body(p, k):
            return solver(p, d, k, jnp.float32(fed.lr)), None
        keys = jax.random.split(key, max(epochs // fed.local_epochs, 1))
        p, _ = jax.lax.scan(body, params0, keys)
        return p

    accs = {}
    for c in client_ids:
        rng, k = jax.random.split(rng)
        d = {"x": jnp.asarray(federation.x[c]), "y": jnp.asarray(federation.y[c])}
        p = train_one(d, k, init_fn(jax.random.PRNGKey(fed.seed + 100 + c)))
        _, acc = evaluate(loss_fn, p, federation.test_x, federation.test_y)
        accs[c] = acc
    return accs
