"""FedALIGN renormalized gated aggregation (paper eq. (15)):

    w <- sum_k p_k I_k w_k / sum_k p_k I_k

over client-stacked parameter pytrees. The default ``fused`` path flattens
the WHOLE pytree into one [C, M_total] buffer and invokes the ``fedagg``
kernel (Pallas on TPU, its jnp lowering on CPU) ONCE per round instead of
once per leaf — one kernel launch, one contraction, and under pjit with the
client axis sharded over (pod, data) exactly one all-reduce: FedALIGN's
entire server-side communication. Accumulation is f32 regardless of leaf
dtype, so fused and per-leaf outputs agree to the cast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def flatten_stacked(client_params, dtype=jnp.float32):
    """Client-stacked pytree ([C, ...] leaves) -> one [C, M_total] buffer."""
    leaves = jax.tree.leaves(client_params)
    C = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.reshape(C, -1).astype(dtype) for leaf in leaves], axis=1)


def aggregate_clients(client_params, weights, gates, *, use_pallas=False,
                      fused=True, interpret=False):
    """client_params: pytree with leading client axis C on every leaf.

    fused=True (default): one fedagg call on the [C, M_total] flattening;
    fused=False: one fedagg call per leaf (the pre-fusion path, kept as the
    parity reference and for incremental/per-leaf sharded layouts)."""
    leaves, treedef = jax.tree.flatten(client_params)
    if not leaves:
        return client_params
    C = leaves[0].shape[0]

    if not fused:
        def agg_leaf(leaf):
            flat = leaf.reshape(C, -1)
            out = kops.fedagg(flat, weights, gates, use_pallas=use_pallas,
                              interpret=interpret)
            return out.reshape(leaf.shape[1:])
        return jax.tree.map(agg_leaf, client_params)

    # keep a uniform leaf dtype on the wire (bf16 deltas stay bf16 in the
    # [C, M_total] buffer and its collective); mixed-dtype trees go f32.
    # fedagg accumulates in f32 either way, so fused == per-leaf numerics.
    dtypes = {leaf.dtype for leaf in leaves}
    buf_dtype = dtypes.pop() if len(dtypes) == 1 else jnp.float32
    sizes = [leaf.size // C for leaf in leaves]
    buf = flatten_stacked(client_params, dtype=buf_dtype)
    out = kops.fedagg(buf, weights, gates, use_pallas=use_pallas,
                      interpret=interpret)
    agg_leaves, off = [], 0
    for leaf, size in zip(leaves, sizes):
        agg_leaves.append(
            out[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, agg_leaves)


def aggregate_updates(global_params, client_params, weights, gates, *,
                      use_pallas=False, fused=True, interpret=False,
                      server_lr=1.0):
    """Delta-form aggregation: w <- w + server_lr * agg(w_k - w).

    Equivalent to aggregate_clients at server_lr=1 but numerically nicer at
    scale and the natural hook for server-side optimizers (beyond-paper)."""
    deltas = jax.tree.map(lambda ck, g: ck - g[None], client_params, global_params)
    agg = aggregate_clients(deltas, weights, gates, use_pallas=use_pallas,
                            fused=fused, interpret=interpret)
    return jax.tree.map(lambda g, d: (g + server_lr * d.astype(g.dtype)),
                        global_params, agg)
