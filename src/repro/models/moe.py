"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

TPU adaptation note: instead of the GShard one-hot [T,E,C] dispatch einsum
(whose FLOPs dwarf the expert FFN itself at fine-grained expert counts like
DeepSeek's 64), we use a sort-based dispatch — argsort token->expert
assignments, rank-within-expert, scatter into a capacity-bounded [E,C,d]
buffer, einsum the expert FFNs, gather back. FLOPs stay ~capacity_factor x
active-expert compute, which keeps the roofline's MODEL_FLOPS/HLO_FLOPs
ratio honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.utils import ceil_div, fold_in_name


def init_moe(key, cfg):
    d, E, dff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = {n: fold_in_name(key, n) for n in ("router", "gate", "up", "down", "shared")}
    p = {
        "w_router": dense_init(ks["router"], (d, E), jnp.float32),  # router in fp32
        "w_gate": dense_init(ks["gate"], (E, d, dff), cfg.pdtype),
        "w_up": dense_init(ks["up"], (E, d, dff), cfg.pdtype),
        "w_down": dense_init(ks["down"], (E, dff, d), cfg.pdtype),
    }
    if cfg.num_shared_experts:
        sh = cfg.num_shared_experts * dff
        from repro.models.layers import init_swiglu
        p["shared"] = init_swiglu(ks["shared"], d, sh, cfg.pdtype)
    return p


def moe_apply(p, x, cfg, *, capacity: int | None = None):
    """x: [B,S,d] -> (y, aux) with aux = {'lb_loss', 'router_z'}."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    cd = cfg.cdtype
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["w_router"])                 # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                              # [T,k]
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    if capacity is None:
        capacity = max(1, int(ceil_div(T * k, E) * cfg.capacity_factor))
    C = capacity

    # ---- sort-based dispatch -------------------------------------------------
    e_flat = tope.reshape(-1)                                          # [T*k]
    order = jnp.argsort(e_flat, stable=True)                           # [T*k]
    e_sorted = e_flat[order]
    counts = jnp.bincount(e_flat, length=E)                            # [E]
    starts = jnp.cumsum(counts) - counts                               # exclusive
    rank = jnp.arange(T * k) - starts[e_sorted]                        # within-expert
    keep = rank < C
    slot = jnp.where(keep, rank, C)                                    # overflow -> spill row
    tok_sorted = order // k

    buf = jnp.zeros((E, C + 1, d), cd)
    buf = buf.at[e_sorted, slot].set(xf[tok_sorted].astype(cd))
    ex_in = buf[:, :C]                                                 # [E,C,d]

    # ---- expert FFN (SwiGLU) -------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", ex_in, p["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", ex_in, p["w_up"].astype(cd))
    ex_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(cd))

    # ---- combine ---------------------------------------------------------------
    gathered = ex_out[e_sorted, jnp.where(keep, rank, 0)]              # [T*k,d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = jnp.zeros((T * k, d), cd).at[order].set(gathered)
    y = jnp.einsum("tkd,tk->td", contrib.reshape(T, k, d), topw.astype(cd))

    if cfg.num_shared_experts:
        from repro.models.layers import swiglu_apply
        y = y + swiglu_apply(p["shared"], xf.astype(cd), cd)

    # ---- aux losses -------------------------------------------------------------
    frac = counts.astype(jnp.float32) / jnp.maximum(T * k, 1)          # f_e
    imp = jnp.mean(probs, axis=0)                                      # P_e
    lb_loss = E * jnp.sum(frac * imp)
    router_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = jnp.sum(~keep) / jnp.maximum(T * k, 1)
    aux = {"lb_loss": lb_loss, "router_z": router_z, "drop_frac": dropped}
    return y.reshape(B, S, d), aux
