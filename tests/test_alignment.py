"""Property-style tests for FedALIGN's selection rule and renormalized
aggregation — the paper's system invariants, checked over seeded random
draws (dependency-free: no hypothesis, tier-1 stays stdlib+jax+pytest)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import aggregate_clients
from repro.core.alignment import (epsilon_at, global_loss_from_locals,
                                  inclusion_gates)
from repro.configs.base import FedConfig

SEEDS = list(range(12))


def client_setup(seed):
    """Random federation slice: losses in [0, 10], >=1 priority, >=1 free."""
    rng = np.random.default_rng(seed)
    C = int(rng.integers(2, 17))
    losses = rng.uniform(0.0, 10.0, C).astype(np.float32)
    npri = int(rng.integers(1, C))
    pm = np.zeros(C, bool)
    pm[:npri] = True
    w = np.full(C, 1.0 / npri, np.float32)
    return jnp.asarray(losses), jnp.asarray(pm), jnp.asarray(w)


@pytest.mark.parametrize("seed", SEEDS)
def test_gates_binary_and_priority_always_in(seed):
    losses, pm, w = client_setup(seed)
    eps = np.random.default_rng(seed + 1000).uniform(0.0, 5.0)
    g_loss = global_loss_from_locals(losses, pm, w)
    gates = inclusion_gates(losses, g_loss, jnp.float32(eps), pm)
    gates = np.asarray(gates)
    assert set(np.unique(gates)).issubset({0.0, 1.0})
    assert np.all(gates[np.asarray(pm)] == 1.0)            # priority always in


@pytest.mark.parametrize("seed", SEEDS)
def test_eps_zero_is_priority_only(seed):
    """Paper §3.2: eps_t = 0 => theta_T = 1, rho_T = 0 => FedAvg-on-priority."""
    losses, pm, w = client_setup(seed)
    g_loss = global_loss_from_locals(losses, pm, w)
    gates = inclusion_gates(losses, g_loss, jnp.float32(0.0), pm)
    np.testing.assert_array_equal(np.asarray(gates), np.asarray(pm, np.float32))


@pytest.mark.parametrize("seed", SEEDS)
def test_eps_inf_includes_everyone(seed):
    losses, pm, w = client_setup(seed)
    g_loss = global_loss_from_locals(losses, pm, w)
    gates = inclusion_gates(losses, g_loss, jnp.float32(1e9), pm)
    assert np.all(np.asarray(gates) == 1.0)


@pytest.mark.parametrize("seed", SEEDS)
def test_gates_monotone_in_eps(seed):
    """A larger eps can only ADD clients (inclusion is monotone)."""
    losses, pm, w = client_setup(seed)
    e1, e2 = np.random.default_rng(seed + 2000).uniform(0.0, 4.0, 2)
    lo, hi = min(e1, e2), max(e1, e2)
    g_loss = global_loss_from_locals(losses, pm, w)
    g_lo = np.asarray(inclusion_gates(losses, g_loss, jnp.float32(lo), pm))
    g_hi = np.asarray(inclusion_gates(losses, g_loss, jnp.float32(hi), pm))
    assert np.all(g_hi >= g_lo)


@pytest.mark.parametrize("seed", SEEDS)
def test_theta_round_bounds(seed):
    """1/(1 + sum p_k I_k) in (0, 1] — paper eq. (7) per-round term."""
    losses, pm, w = client_setup(seed)
    g_loss = global_loss_from_locals(losses, pm, w)
    for eps in (0.0, 0.5, 1e9):
        gates = inclusion_gates(losses, g_loss, jnp.float32(eps), pm)
        npri = 1.0 - np.asarray(pm, np.float32)
        theta = 1.0 / (1.0 + float(jnp.sum(npri * w * gates)))
        assert 0.0 < theta <= 1.0
        if eps == 0.0:
            assert theta == 1.0


# ------------------------------------------------------ aggregation invariants
def stacked_params(seed):
    rng = np.random.default_rng(seed + 3000)
    C = int(rng.integers(2, 9))
    dim = int(rng.integers(1, 17))
    return jnp.asarray(rng.uniform(-5, 5, (C, dim)).astype(np.float32))


@pytest.mark.parametrize("seed", SEEDS)
def test_aggregate_is_convex_combination(seed):
    """Output lies inside the per-coordinate hull of included clients."""
    leaf = stacked_params(seed)
    C = leaf.shape[0]
    w = jnp.ones((C,)) / C
    g = jnp.ones((C,)).at[0].set(1.0)
    tree = {"p": leaf}
    out = aggregate_clients(tree, w, g)["p"]
    assert np.all(np.asarray(out) <= np.asarray(leaf.max(0)) + 1e-5)
    assert np.all(np.asarray(out) >= np.asarray(leaf.min(0)) - 1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_aggregate_identical_clients_identity(seed):
    leaf = stacked_params(seed)
    C = leaf.shape[0]
    same = jnp.broadcast_to(leaf[0], leaf.shape)
    w = jax.random.uniform(jax.random.PRNGKey(0), (C,)) + 0.1
    g = jnp.ones((C,))
    out = aggregate_clients({"p": same}, w, g)["p"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(leaf[0]),
                               atol=1e-4, rtol=1e-4)


def test_aggregate_renormalization_matches_paper():
    """w <- (sum_P p_k w_k + sum_notP p_k I_k w_k) / (1 + sum_notP p_k I_k)."""
    C, dim = 5, 7
    rng = np.random.default_rng(0)
    stack = jnp.asarray(rng.normal(size=(C, dim)).astype(np.float32))
    pm = np.array([1, 1, 0, 0, 0], bool)
    p = np.array([0.5, 0.5, 0.3, 0.4, 0.3], np.float32)   # priority mass = 1
    I = np.array([1, 1, 1, 0, 1], np.float32)
    out = aggregate_clients({"w": stack}, jnp.asarray(p), jnp.asarray(I))["w"]
    num = sum(p[k] * I[k] * np.asarray(stack[k]) for k in range(C))
    den = 1.0 + p[2] * 1 + p[4] * 1
    np.testing.assert_allclose(np.asarray(out), num / den, rtol=1e-5)


def test_epsilon_schedules():
    fed = FedConfig(epsilon=0.4, epsilon_schedule="exp", epsilon_decay=0.1)
    e0 = float(epsilon_at(fed, 0))
    e10 = float(epsilon_at(fed, 10))
    assert abs(e0 - 0.4) < 1e-6 and e10 < e0
    fed = FedConfig(epsilon=0.4, epsilon_schedule="constant")
    assert float(epsilon_at(fed, 100)) == np.float32(0.4)
