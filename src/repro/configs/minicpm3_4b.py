"""minicpm3-4b [dense] — MLA (multi-head latent attention).
[hf:openbmb/MiniCPM3-4B]

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA ranks per the model card:
q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64. The decode KV
cache stores latents only ([kv_lora + rope] per token instead of
2*H*head_dim) — the architecture's defining memory win.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=64,
    mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    tie_embeddings=True,
    source="hf:openbmb/MiniCPM3-4B",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=512, q_lora_rank=64, kv_lora_rank=32,
        qk_nope_head_dim=16, qk_rope_head_dim=16, v_head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
