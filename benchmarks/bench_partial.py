"""Paper Figure 5 (App C.3): partial participation — random client subsets
each round (fraction 0.3), 18 priority clients of N=60."""
from __future__ import annotations

from benchmarks.common import fed_suite
from repro.data.shards import make_benchmark_federation


def run(fast=True, seeds=(0,)):
    rounds = 20 if fast else 150
    fedn = make_benchmark_federation("fmnist", seed=0, n_priority=18,
                                     samples_per_client=200 if fast else None)
    rows = fed_suite(fedn, "logreg",
                     dict(num_clients=fedn.x.shape[0], num_priority=18,
                          rounds=rounds, local_epochs=5, epsilon=0.2, lr=0.1,
                          warmup_frac=0.1, batch_size=32, participation=0.3),
                     seeds=seeds)
    for r in rows:
        r["participation"] = 0.3
    return rows


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "acc_curve"})
