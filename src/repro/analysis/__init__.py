"""Static analysis over federation programs ("fedlint").

Two entry points:

* ``lint_program(fn, args, fed=...)`` — trace ``fn`` to a jaxpr, compile
  it to optimized HLO, and run every registered ``LintRule`` against the
  program WITHOUT executing a round (scripts/fedlint.py sweeps the
  strategy x backend x aggregator x codec matrix through this).
* ``lint_hlo_text(text, fed=...)`` — run the HLO-only rules against an
  already-dumped artifact (``launch/dryrun.py --dump-hlo``), so fedlint
  and the roofline share one set of lowered programs.

``analysis.hlo`` is the scan-aware HLO cost/shape parser (relocated from
``launch/hlo_analysis.py``, which remains as a re-export shim);
``analysis.jaxpr_walk`` is the recursive jaxpr walker the jaxpr-level
rules ride on.
"""
from repro.analysis.hlo import (analyze_file, analyze_text,  # noqa: F401
                                parse_hlo, parse_input_output_alias)
from repro.analysis.lint import (LINT_RULES, LintReport,  # noqa: F401
                                 LintViolation, lint_hlo_text, lint_program,
                                 lint_rule)
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)
