"""Per-assigned-architecture smoke tests: REDUCED same-family variants run
one forward/train step and one decode step on CPU; output shapes + no NaNs.
Also decode-vs-teacher-forced consistency (the strongest cheap correctness
check a transformer stack can get)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import get_model
from repro.models import transformer as T
from repro.utils import has_nan, tree_axpy

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def make_batch(cfg, key=KEY, S=S, B=B):
    S_text = S - cfg.num_image_tokens if cfg.vlm else S
    batch = {
        "tokens": jax.random.randint(key, (B, S_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (B, S_text),
                                     0, cfg.vocab_size),
        "mask": jnp.ones((B, S_text), jnp.float32),
    }
    if cfg.vlm:
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.num_image_tokens, cfg.d_model))
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.num_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    # an SGD step decreases loss on the same batch. MoE archs: top-k routing
    # flips make the surface locally non-smooth — accept any of a few lrs.
    lrs = (0.02, 0.005, 0.001) if cfg.moe else (0.1,)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    assert not bool(has_nan(grads)), arch
    decreased = False
    for lr in lrs:
        p2 = tree_axpy(-lr, grads, params)
        loss2, _ = model.loss_fn(p2, batch)
        if float(loss2) < float(loss):
            decreased = True
            break
    assert decreased, (arch, float(loss), float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    cache = model.make_cache(B, S)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, jnp.int32(3)))(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size), arch
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    # cache structure is preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


NON_MOE = [a for a in ARCH_IDS if not get_smoke(a).moe and not get_smoke(a).encdec]
MOE = [a for a in ARCH_IDS if get_smoke(a).moe]


@pytest.mark.parametrize("arch", NON_MOE)
def test_decode_matches_teacher_forced(arch):
    cfg = get_smoke(arch).replace(remat=False, vlm=False, num_image_tokens=0)
    model = get_model(cfg)
    params = model.init(KEY)
    S_ = 12
    toks = jax.random.randint(KEY, (B, S_), 0, cfg.vocab_size)
    hidden, _, _ = T.forward(params, toks, cfg, mode="train")
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ref_logits = hidden.astype(jnp.float32) @ w.astype(jnp.float32)
    cache = model.make_cache(B, S_)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    for t in range(S_):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, t]),
                                   atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("arch", MOE)
def test_decode_matches_teacher_forced_moe(arch):
    """MoE needs a no-drop capacity factor for step-wise equivalence."""
    cfg = get_smoke(arch).replace(remat=False, capacity_factor=16.0)
    model = get_model(cfg)
    params = model.init(KEY)
    S_ = 8
    toks = jax.random.randint(KEY, (B, S_), 0, cfg.vocab_size)
    hidden, _, _ = T.forward(params, toks, cfg, mode="train")
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ref_logits = hidden.astype(jnp.float32) @ w.astype(jnp.float32)
    cache = model.make_cache(B, S_)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    for t in range(S_):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, t]),
                                   atol=1e-3, rtol=1e-2)


def test_sliding_window_decode_ring_buffer():
    """Windowed decode with a ring cache matches a full-cache windowed ref."""
    arch = "qwen1_5_0_5b"
    cfg = get_smoke(arch).replace(remat=False, sliding_window=8)
    cfg_full = get_smoke(arch).replace(remat=False, sliding_window=8)
    model = get_model(cfg)
    params = model.init(KEY)
    S_ = 20
    toks = jax.random.randint(KEY, (B, S_), 0, cfg.vocab_size)
    hidden, _, _ = T.forward(params, toks, cfg_full, mode="train")
    ref_logits = hidden.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    cache = model.make_cache(B, S_)            # ring of size window=8
    assert cache["periods"]["l0"]["k"].shape[2] == 8  # [periods, B, W, KV, hd]
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    for t in range(S_):
        logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, t]),
                                   atol=5e-4, rtol=5e-3)


def test_vlm_image_positions_no_loss():
    cfg = get_smoke("llava_next_34b")
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    loss, m = model.loss_fn(params, batch)
    # token count excludes image positions
    assert float(m["tokens"]) == B * (S - cfg.num_image_tokens)
