"""Pallas TPU kernels for FedALIGN's gated client aggregation.

The base reduction is the paper's server step (eq. (15)): given C client
updates (flattened to [C, M]), data fractions p_k and inclusion gates I_k,

    out[m] = sum_k p_k I_k u[k, m] / sum_k p_k I_k

The parameter axis M is tiled in ``block_m`` columns; each grid cell loads a
[C, block_m] update slab into VMEM plus the tiny weight/gate vectors, and
emits one [block_m] output row. The mean reduction over clients is a
[1,C]x[C,bm] MXU contraction. Memory-bound (arithmetic intensity ~= 1
FLOP/byte), so block_m is sized for DMA efficiency (multiples of 512 lanes).

Robust / private variants are FUSED INTO THE SAME GRID CELL — the [C, bm]
slab is already in VMEM, so a coordinate-wise sort/select (``trimmed_mean``,
``median``), a per-client clip scale + noise add (``dp``), or a gate rewrite
(``cosine_filter``, handled upstream as a gate pre-pass) costs ~0 extra HBM
traffic versus a second pass over the parameters:

- ``trimmed_mean`` / ``median`` sort each column over the client axis with a
  bitonic compare/exchange network (C padded to a power of two; excluded
  clients keyed to +inf so the n included values occupy positions [0, n))
  and reduce the surviving order statistics. Both are UNWEIGHTED over the
  included clients (the Byzantine-robust convention of coordinate-wise
  trimmed mean / median, Yin et al., arXiv:1803.01498) — p_k weighting
  would let one heavy client dominate the order statistics it is supposed
  to be protected from.
- ``dp`` applies a per-client multiplicative clip scale (computed upstream
  from whole-model L2 norms) inside the weighted contraction and adds
  pre-generated Gaussian noise scaled by ``noise_scale / den`` — DP-FedAvg
  (McMahan et al., arXiv:1710.06963) on the renormalized gated mean. The
  noise vector is generated OUTSIDE the kernel with jax.random so the
  Pallas and jnp lowerings are bit-comparable (the in-kernel TPU PRNG
  would diverge from the CPU path).

Every variant returns an EXACT zero vector when no client is included
(zero inclusion mass) — the old 0/1e-30 guard is kept only as a
divide-safety net, never observed. Gated-out rows are masked before the
reduction so a non-finite update from an excluded client cannot leak
through 0 * NaN.

TPU caveat (ROADMAP): CI exercises interpret mode on CPU; the sort-network
variants lower through jnp primitives (take_along_axis / min / max / where)
that Mosaic supports, but like every kernel here they are unvalidated on
real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def sort_cols_jnp(x):
    """Ascending sort along axis 0 of [C, M] — the jnp-lowering twin of the
    kernel's ``_sort_cols``: the SAME bitonic compare/exchange schedule,
    unrolled in python with STATIC row permutations (illegal inside a
    pallas kernel, which cannot capture the [P] index constants). Static
    perms let XLA lower each exchange to vectorized row moves; the
    fori_loop form costs ~1.5x more here, and XLA's own comparator sort
    (jnp.sort) ~6x — it quicksorts every column at ~100 ns/compare, which
    dominated whole training rounds at M ~ 2e4. Bit-identical to both
    ``_sort_cols`` and jnp.sort (total order on floats; ties carry no
    payload)."""
    C = x.shape[0]
    P = _next_pow2(C)
    if P != C:
        pad = jnp.full((P - C,) + x.shape[1:], jnp.inf, x.dtype)
        x = jnp.concatenate([x, pad], axis=0)
    idx = np.arange(P)
    k = 2
    while k <= P:
        j = k // 2
        while j >= 1:
            px = x[idx ^ j]
            lo = jnp.minimum(x, px)
            hi = jnp.maximum(x, px)
            take_lo = jnp.asarray((idx & k == 0) == (idx & j == 0))[:, None]
            x = jnp.where(take_lo, lo, hi)
            j //= 2
        k *= 2
    return x[:C]


def _sort_cols(x):
    """Ascending sort along axis 0 (clients) of a [C, bm] f32 block.

    Bitonic compare/exchange network: rows are padded to a power of two
    with +inf, every stage is a static-shape permute + min/max/where, so
    the whole sort stays inside the grid cell (no HBM round-trip) and is
    bit-identical to the jnp lowering's ``sort_cols_jnp``.
    """
    C = x.shape[0]
    P = _next_pow2(C)
    if P != C:
        pad = jnp.full((P - C,) + x.shape[1:], jnp.inf, x.dtype)
        x = jnp.concatenate([x, pad], axis=0)
    idx = jax.lax.broadcasted_iota(jnp.int32, (P, 1), 0)
    # walk the (k, j) stage schedule with fori_loops (k and j derived from
    # the loop indices by shifts) so the traced graph holds ONE
    # compare/exchange body. Unrolling the log^2(P) stages instead makes
    # XLA's CPU pipeline blow up on the gather chain (minutes at P=16,
    # effectively forever at P=64); pallas kernels cannot capture a
    # precomputed schedule array, hence the arithmetic form.
    one = jnp.int32(1)

    def pass_body(pi, x):                 # pass p = pi + 1: k = 2^p
        k = jnp.left_shift(one, pi + 1)

        def sub_body(qi, x):              # j = 2^(p-1), 2^(p-2), ..., 1
            j = jnp.left_shift(one, pi - qi)
            px = jnp.take_along_axis(
                x, jnp.broadcast_to(idx ^ j, x.shape), axis=0)
            lo = jnp.minimum(x, px)
            hi = jnp.maximum(x, px)
            asc = (idx & k) == 0          # direction of this bitonic block
            first = (idx & j) == 0        # lower partner of the pair
            return jnp.where(asc == first, lo, hi)

        return jax.lax.fori_loop(0, pi + 1, sub_body, x)

    n_passes = P.bit_length() - 1         # log2(P) static
    return jax.lax.fori_loop(0, n_passes, pass_body, x)[:C]


def _included_stats(g):
    """Inclusion mask [C] bool and included count n (traced i32 scalar)."""
    inc = g > 0
    return inc, jnp.sum(inc.astype(jnp.int32))


# --------------------------------------------------------- wire-codec decode
# Each decoder turns a grid cell's ENCODED operand refs into the decoded
# [C, block_m] f32 tile, entirely in VMEM/registers — the dense buffer is
# never materialized in HBM on this path (the WireCodec contract,
# core/aggregation.py). The aggregator kernels below are codec-agnostic:
# they see only the decoded tile.

def _decode_identity(refs):
    (u_ref,) = refs
    return u_ref[...].astype(jnp.float32)


def _decode_int8(refs):
    # dequantize-in-register: int8 rows times the per-client f32 scale
    u_ref, s_ref = refs
    return u_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)[:, None]


def _decode_topk(block_m, refs):
    # sparse-scatter-accumulate: every cell walks the k (value, index)
    # pairs once and one-hot-accumulates the entries landing in its
    # column range. Indices within a row are distinct (top_k), so the
    # accumulation places each value exactly once — bit-identical to the
    # jnp lowering's scatter-add.
    v_ref, i_ref = refs                                        # [C, k] each
    v = v_ref[...].astype(jnp.float32)
    ix = i_ref[...]
    C, k = v.shape
    base = pl.program_id(0) * block_m
    cols = base + jax.lax.broadcasted_iota(jnp.int32, (C, block_m), 1)

    def body(j, acc):
        vj = jax.lax.dynamic_slice(v, (0, j), (C, 1))          # [C, 1]
        ij = jax.lax.dynamic_slice(ix, (0, j), (C, 1))         # [C, 1]
        return acc + jnp.where(cols == ij, vj, 0.0)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((C, block_m), jnp.float32))


def _decode_sketch(refs):
    # CountSketch estimate: gather each column's bucket from the [C, dim]
    # sketch rows and apply its sign (0 on the padded tail, so padded
    # columns decode to exact zero)
    s_ref, h_ref, sg_ref = refs
    s = s_ref[...].astype(jnp.float32)                         # [C, dim]
    h = h_ref[...]                                             # [bm] i32
    sg = sg_ref[...].astype(jnp.float32)                       # [bm]
    return jnp.take(s, h, axis=1) * sg[None, :]


def _mean_kernel(decode, n_enc, *refs):
    w_ref, g_ref, o_ref = refs[n_enc], refs[n_enc + 1], refs[-1]
    wg = (w_ref[...] * g_ref[...]).astype(jnp.float32)        # [C]
    den = jnp.sum(wg)
    u = jnp.where((wg > 0)[:, None], decode(refs[:n_enc]), 0.0)
    num = jax.lax.dot_general(wg[None, :], u, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[0]
    out = jnp.where(den > 0, num / jnp.maximum(den, 1e-30), 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def _dp_kernel(noise_scale, decode, n_enc, *refs):
    w_ref, g_ref = refs[n_enc], refs[n_enc + 1]
    s_ref, n_ref, o_ref = refs[n_enc + 2], refs[n_enc + 3], refs[-1]
    wg = (w_ref[...] * g_ref[...]).astype(jnp.float32)        # [C]
    den = jnp.sum(wg)
    # clip scales, masked on excluded rows: a NaN delta in a gated-out
    # client makes its row_scale NaN and 0 * NaN would leak through
    wgs = jnp.where(wg > 0, wg * s_ref[...].astype(jnp.float32), 0.0)
    u = jnp.where((wg > 0)[:, None], decode(refs[:n_enc]), 0.0)
    num = jax.lax.dot_general(wgs[None, :], u, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[0]
    safe = jnp.maximum(den, 1e-30)
    noisy = num / safe + n_ref[...].astype(jnp.float32) * (noise_scale / safe)
    o_ref[...] = jnp.where(den > 0, noisy, 0.0).astype(o_ref.dtype)


def _trimmed_kernel(trim_frac, decode, n_enc, *refs):
    g_ref, o_ref = refs[n_enc + 1], refs[-1]                   # unweighted
    inc, n = _included_stats(g_ref[...])
    u = jnp.where(inc[:, None], decode(refs[:n_enc]), jnp.inf)
    s = _sort_cols(u)                                          # [C, bm]
    t = (jnp.float32(trim_frac) * n.astype(jnp.float32)).astype(jnp.int32)
    idx = jax.lax.broadcasted_iota(jnp.int32, (s.shape[0], 1), 0)
    keep = (idx >= t) & (idx < n - t)                          # survivors
    cnt = n - 2 * t
    total = jnp.sum(jnp.where(keep, s, 0.0), axis=0)
    out = jnp.where(cnt > 0, total / jnp.maximum(cnt, 1).astype(jnp.float32), 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def _median_kernel(decode, n_enc, *refs):
    g_ref, o_ref = refs[n_enc + 1], refs[-1]                   # unweighted
    inc, n = _included_stats(g_ref[...])
    u = jnp.where(inc[:, None], decode(refs[:n_enc]), jnp.inf)
    s = _sort_cols(u)
    lo, hi = (n - 1) // 2, n // 2                              # even n: average
    idx = jax.lax.broadcasted_iota(jnp.int32, (s.shape[0], 1), 0)
    med = 0.5 * (jnp.sum(jnp.where(idx == lo, s, 0.0), axis=0)
                 + jnp.sum(jnp.where(idx == hi, s, 0.0), axis=0))
    o_ref[...] = jnp.where(n > 0, med, 0.0).astype(o_ref.dtype)


def fedagg_pallas(updates, weights, gates, *, block_m=2048, interpret=False,
                  aggregator="mean", trim_frac=0.0, row_scale=None,
                  noise=None, noise_scale=0.0, codec="identity",
                  dequant_scale=None, topk_idx=None, sketch_h=None,
                  sketch_sign=None, out_m=None):
    """updates: [C, M] (or the codec's wire shape); weights, gates: [C] -> [M].

    aggregator: mean | trimmed_mean | median | dp — one fused kernel launch
    regardless of variant. ``dp`` additionally takes ``row_scale`` [C]
    (per-client clip factors), ``noise`` [M] (standard-normal draws) and a
    static ``noise_scale`` (sigma numerator = dp_noise * dp_clip; divided
    by the inclusion mass inside the cell). ``cosine_filter`` is a gate
    pre-pass upstream and lands here as plain ``mean``.

    ``codec`` selects the in-kernel wire decode, COMPOSED with every
    aggregator in the same launch (decode feeds the mean/dp contraction
    directly, and runs before the order-statistics sort):

    - ``identity`` — ``updates`` is the dense [C, M] buffer (legacy path,
      output in ``updates.dtype``).
    - ``int8`` — ``updates`` is [C, M] int8; ``dequant_scale`` [C] f32
      dequantizes each row in-register after the tile load.
    - ``topk`` — ``updates`` is [C, k] f32 values with ``topk_idx``
      [C, k] i32 column indices (both full-array operands per cell);
      ``out_m`` gives the true M. Each cell scatter-accumulates its tile.
    - ``sketch`` — ``updates`` is [C, dim] f32 CountSketch rows (full per
      cell); ``sketch_h`` / ``sketch_sign`` [M] are the shared hash/sign
      planes (tiled per block); ``out_m`` gives the true M.

    Codec outputs are f32 (the wire dtype no longer matches the model).
    The dense decode is never materialized in HBM — each grid cell decodes
    its own [C, block_m] tile in VMEM. TPU caveat: the [C, k] / [C, dim]
    full-array operands assume k resp. dim pad to lane multiples on real
    hardware; CPU CI exercises interpret mode only, like every kernel
    here."""
    C = updates.shape[0]
    M = int(out_m) if out_m is not None else updates.shape[1]
    out_dtype = updates.dtype if codec == "identity" else jnp.float32
    block_m = min(block_m, M)
    pad = (-M) % block_m
    Mp = M + pad
    nm = Mp // block_m
    if pad and noise is not None:
        noise = jnp.pad(noise, (0, pad))

    vec_spec = pl.BlockSpec((C,), lambda im: (0,))
    col_spec = pl.BlockSpec((block_m,), lambda im: (im,))

    if codec == "identity":
        if pad:
            updates = jnp.pad(updates, ((0, 0), (0, pad)))
        enc_specs = [pl.BlockSpec((C, block_m), lambda im: (0, im))]
        enc_ops = [updates]
        decode = _decode_identity
    elif codec == "int8":
        if dequant_scale is None:
            raise ValueError("codec='int8' needs dequant_scale [C]")
        if pad:
            updates = jnp.pad(updates, ((0, 0), (0, pad)))
        enc_specs = [pl.BlockSpec((C, block_m), lambda im: (0, im)), vec_spec]
        enc_ops = [updates, dequant_scale]
        decode = _decode_int8
    elif codec == "topk":
        if topk_idx is None or out_m is None:
            raise ValueError("codec='topk' needs topk_idx [C, k] and out_m")
        k = updates.shape[1]
        full = pl.BlockSpec((C, k), lambda im: (0, 0))
        enc_specs = [full, full]
        enc_ops = [updates, topk_idx]
        decode = functools.partial(_decode_topk, block_m)
    elif codec == "sketch":
        if sketch_h is None or sketch_sign is None or out_m is None:
            raise ValueError(
                "codec='sketch' needs sketch_h [M], sketch_sign [M], out_m")
        if pad:
            # sign pads with 0 -> padded columns decode to exact zero
            sketch_h = jnp.pad(sketch_h, (0, pad))
            sketch_sign = jnp.pad(sketch_sign, (0, pad))
        dim = updates.shape[1]
        enc_specs = [pl.BlockSpec((C, dim), lambda im: (0, 0)),
                     col_spec, col_spec]
        enc_ops = [updates, sketch_h, sketch_sign]
        decode = _decode_sketch
    else:
        raise ValueError(f"unknown wire codec {codec!r}")

    in_specs = enc_specs + [vec_spec, vec_spec]
    operands = enc_ops + [weights, gates]
    n_enc = len(enc_ops)
    if aggregator == "mean":
        kernel = functools.partial(_mean_kernel, decode, n_enc)
    elif aggregator == "trimmed_mean":
        kernel = functools.partial(_trimmed_kernel, float(trim_frac), decode,
                                   n_enc)
    elif aggregator == "median":
        kernel = functools.partial(_median_kernel, decode, n_enc)
    elif aggregator == "dp":
        if row_scale is None or noise is None:
            raise ValueError("aggregator='dp' needs row_scale [C] and noise [M]")
        kernel = functools.partial(_dp_kernel, float(noise_scale), decode,
                                   n_enc)
        in_specs += [vec_spec, col_spec]
        operands += [row_scale, noise]
    else:
        raise ValueError(f"unknown in-kernel aggregator {aggregator!r}")

    out = pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=in_specs,
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((Mp,), out_dtype),
        interpret=interpret,
    )(*operands)
    return out[:M]
