"""jit'd dispatch layer for the Pallas kernels.

Every op has (a) a Pallas TPU kernel (``<name>.py``), (b) a production jnp
fallback here (chunked / memory-safe, used on CPU and in dry-run lowering),
and (c) a naive oracle in ``ref.py`` used by tests.

``use_pallas=True`` selects the Pallas path; on a CPU backend the Pallas
kernels only run in ``interpret=True`` mode (tests do this explicitly).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ============================================================ flash attention
def _flash_attention_jnp(q, k, v, *, causal, window, block_kv, kv_len=None,
                         scale=None, mm_dtype=None):
    """Blockwise online-softmax attention (no [S,S] materialization).

    q: [B,Sq,H,hd]; k/v: [B,Skv,KV,hd]; queries occupy the LAST Sq absolute
    positions of the kv sequence (q_offset = Skv - Sq).
    mm_dtype: matmul input dtype (e.g. bf16); softmax state stays f32.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    block = min(block_kv, Skv)
    q_offset = Skv - Sq
    if Skv % block:                       # pad kv to a block multiple, mask the tail
        pad = block - Skv % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = Skv
        Skv += pad
    nblk = Skv // block

    md = mm_dtype or jnp.float32
    qf = (q.astype(jnp.float32) * scale).astype(md).reshape(B, Sq, KV, G, hd)
    kb = k.astype(md).reshape(B, nblk, block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.astype(md).reshape(B, nblk, block, KV, hd).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, blk = inp
        k_pos = blk * block + jnp.arange(block)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kc,
                       preferred_element_type=jnp.float32)     # [B,KV,G,Sq,blk]
        mask = jnp.ones((Sq, block), bool)
        if kv_len is not None:
            mask = mask & (k_pos[None, :] < kv_len)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p_, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p_.astype(md), vc,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, block_kv=1024,
                    kv_len=None, scale=None, use_pallas=False, interpret=False,
                    mm_dtype=None):
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      kv_len=kv_len, scale=scale, interpret=interpret)
    return _flash_attention_jnp(q, k, v, causal=causal, window=window,
                                block_kv=block_kv, kv_len=kv_len, scale=scale,
                                mm_dtype=mm_dtype)


# ============================================================ decode attention
def _decode_attention_jnp(q, k_cache, v_cache, *, kv_len, scale=None):
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k_cache.shape
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(Skv)[None, :] < kv_len
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", w, v_cache.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, kv_len, scale=None,
                     use_pallas=False, interpret=False):
    if use_pallas:
        from repro.kernels.decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k_cache, v_cache, kv_len=kv_len,
                                       scale=scale, interpret=interpret)
    return _decode_attention_jnp(q, k_cache, v_cache, kv_len=kv_len, scale=scale)


# ===================================================================== fedagg
def _fedagg_jnp(updates, weights, gates):
    wg = (weights * gates).astype(jnp.float32)
    den = jnp.sum(wg)
    u = jnp.where((wg > 0)[:, None], updates.astype(jnp.float32), 0.0)
    num = jnp.einsum("c,cm->m", wg, u)
    out = jnp.where(den > 0, num / jnp.maximum(den, 1e-30), 0.0)
    return out.astype(updates.dtype)


def _fedagg_dp_jnp(updates, weights, gates, row_scale, noise, noise_scale):
    wg = (weights * gates).astype(jnp.float32)
    den = jnp.sum(wg)
    u = jnp.where((wg > 0)[:, None], updates.astype(jnp.float32), 0.0)
    # mask the clip scales too: an excluded client's NaN delta makes its
    # row_scale NaN, and 0 * NaN would re-poison the masked row
    wgs = jnp.where(wg > 0, wg * row_scale.astype(jnp.float32), 0.0)
    num = jnp.einsum("c,cm->m", wgs, u)
    safe = jnp.maximum(den, 1e-30)
    noisy = num / safe + noise.astype(jnp.float32) * (noise_scale / safe)
    return jnp.where(den > 0, noisy, 0.0).astype(updates.dtype)


def _fedagg_sorted_jnp(updates, gates, *, trim_frac=None):
    """Coordinate-wise trimmed mean (trim_frac set) or median (None) over the
    INCLUDED clients, unweighted — the Byzantine-robust convention (Yin et
    al., arXiv:1803.01498). Excluded clients sort to +inf, so the n included
    values occupy sorted positions [0, n). n == 0 -> exact zero."""
    from repro.kernels.fedagg import sort_cols_jnp

    C = updates.shape[0]
    inc = gates > 0
    n = jnp.sum(inc.astype(jnp.int32))
    u = jnp.where(inc[:, None], updates.astype(jnp.float32), jnp.inf)
    # the kernel's bitonic network (static-perm unrolling), not jnp.sort —
    # see sort_cols_jnp for why XLA's comparator sort is ~6x slower here
    s = sort_cols_jnp(u)
    idx = jnp.arange(C, dtype=jnp.int32)[:, None]
    if trim_frac is None:                                      # median
        lo, hi = (n - 1) // 2, n // 2
        med = 0.5 * (jnp.sum(jnp.where(idx == lo, s, 0.0), axis=0)
                     + jnp.sum(jnp.where(idx == hi, s, 0.0), axis=0))
        out = jnp.where(n > 0, med, 0.0)
    else:
        t = (jnp.float32(trim_frac) * n.astype(jnp.float32)).astype(jnp.int32)
        keep = (idx >= t) & (idx < n - t)
        cnt = n - 2 * t
        total = jnp.sum(jnp.where(keep, s, 0.0), axis=0)
        out = jnp.where(cnt > 0, total / jnp.maximum(cnt, 1).astype(jnp.float32), 0.0)
    return out.astype(updates.dtype)


def _decode_wire_jnp(updates, *, codec, dequant_scale=None, topk_idx=None,
                     sketch_h=None, sketch_sign=None, out_m=None):
    """Decode a wire-codec payload to the dense f32 [C, M] buffer.

    Bit-comparable to the in-kernel decoders in kernels/fedagg.py: int8
    multiplies the per-row scale after the f32 cast; topk scatter-adds the
    (value, index) pairs (indices within a row are distinct, so order is
    irrelevant); sketch gathers each column's CountSketch bucket and
    applies its sign."""
    if codec == "int8":
        if dequant_scale is None:
            raise ValueError("codec='int8' needs dequant_scale [C]")
        return updates.astype(jnp.float32) * dequant_scale.astype(jnp.float32)[:, None]
    if codec == "topk":
        if topk_idx is None or out_m is None:
            raise ValueError("codec='topk' needs topk_idx [C, k] and out_m")
        C = updates.shape[0]
        rows = jnp.arange(C, dtype=jnp.int32)[:, None]
        buf = jnp.zeros((C, int(out_m)), jnp.float32)
        return buf.at[rows, topk_idx].add(updates.astype(jnp.float32))
    if codec == "sketch":
        if sketch_h is None or sketch_sign is None:
            raise ValueError("codec='sketch' needs sketch_h [M] and sketch_sign [M]")
        return (jnp.take(updates.astype(jnp.float32), sketch_h, axis=1)
                * sketch_sign.astype(jnp.float32)[None, :])
    raise ValueError(f"unknown wire codec {codec!r}")


def fedagg(updates, weights, gates, *, use_pallas=False, interpret=False,
           block_m=2048, aggregator="mean", trim_frac=0.0, row_scale=None,
           noise=None, noise_scale=0.0, codec="identity", dequant_scale=None,
           topk_idx=None, sketch_h=None, sketch_sign=None, out_m=None):
    """Gated client aggregation: [C,M],[C],[C] -> [M].

    The fused aggregation path (core/aggregation.py) calls this ONCE per
    round on the whole-model [C, M_total] flattening, so M may be the full
    parameter count; the Pallas kernel tiles M in block_m columns.

    ``aggregator`` selects the in-kernel reduction (mean | trimmed_mean |
    median | dp); all variants return an exact zero vector on a
    zero-inclusion round and mask gated-out rows before reducing. See
    kernels/fedagg.py for the per-variant semantics and extra operands.

    ``codec`` (identity | int8 | topk | sketch) composes the wire decode
    with the reduction: on the Pallas path the decode happens per grid
    cell inside the same launch (no dense decode buffer in HBM); on this
    jnp fallback the buffer is decoded then reduced. Non-identity codecs
    output f32 regardless of the wire dtype; the extra operands
    (``dequant_scale``, ``topk_idx``, ``sketch_h``/``sketch_sign``,
    ``out_m``) are supplied by the codec's encode (core/aggregation.py)."""
    if use_pallas:
        from repro.kernels.fedagg import fedagg_pallas
        return fedagg_pallas(updates, weights, gates, block_m=block_m,
                             interpret=interpret, aggregator=aggregator,
                             trim_frac=trim_frac, row_scale=row_scale,
                             noise=noise, noise_scale=noise_scale,
                             codec=codec, dequant_scale=dequant_scale,
                             topk_idx=topk_idx, sketch_h=sketch_h,
                             sketch_sign=sketch_sign, out_m=out_m)
    if codec != "identity":
        updates = _decode_wire_jnp(updates, codec=codec,
                                   dequant_scale=dequant_scale,
                                   topk_idx=topk_idx, sketch_h=sketch_h,
                                   sketch_sign=sketch_sign, out_m=out_m)
    if aggregator == "mean":
        return _fedagg_jnp(updates, weights, gates)
    if aggregator == "trimmed_mean":
        return _fedagg_sorted_jnp(updates, gates, trim_frac=float(trim_frac))
    if aggregator == "median":
        return _fedagg_sorted_jnp(updates, gates, trim_frac=None)
    if aggregator == "dp":
        if row_scale is None or noise is None:
            raise ValueError("aggregator='dp' needs row_scale [C] and noise [M]")
        return _fedagg_dp_jnp(updates, weights, gates, row_scale, noise,
                              float(noise_scale))
    raise ValueError(f"unknown in-kernel aggregator {aggregator!r}")


# ==================================================================== rmsnorm
def _rmsnorm_jnp(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def rmsnorm(x, scale, *, eps=1e-6, use_pallas=False, interpret=False):
    if use_pallas:
        from repro.kernels.rmsnorm import rmsnorm_pallas
        return rmsnorm_pallas(x, scale, eps=eps, interpret=interpret)
    return _rmsnorm_jnp(x, scale, eps)


# =================================================================== ssm scan
def _ssm_scan_jnp(x, dt, A, B, C, D, *, chunk=256):
    """Chunked parallel selective scan (Mamba S6).

    Within a chunk the linear recurrence h_t = a_t h_{t-1} + b_t is solved
    with an associative scan; chunks are chained with a lax.scan carry.
    Shapes as in ref.ssm_scan_ref.
    """
    Bt, S, Di = x.shape
    N = A.shape[1]
    S0 = S
    chunk = min(chunk, S)
    if S % chunk:
        # identity-step padding: dt=0 => a=1, b=0 (state unchanged)
        pad = chunk - S % chunk
        p3 = ((0, 0), (0, pad), (0, 0))
        x, dt, B, C = (jnp.pad(t, p3) for t in (x, dt, B, C))
        S += pad
    nch = S // chunk
    xf = x.astype(jnp.float32).reshape(Bt, nch, chunk, Di).transpose(1, 0, 2, 3)
    dtf = dt.astype(jnp.float32).reshape(Bt, nch, chunk, Di).transpose(1, 0, 2, 3)
    Bf = B.astype(jnp.float32).reshape(Bt, nch, chunk, N).transpose(1, 0, 2, 3)
    Cf = C.astype(jnp.float32).reshape(Bt, nch, chunk, N).transpose(1, 0, 2, 3)
    Af = A.astype(jnp.float32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def body(h0, inp):
        xc, dtc, Bc, Cc = inp                              # [Bt,chunk,...]
        a = jnp.exp(dtc[..., None] * Af[None, None])       # [Bt,c,Di,N]
        b = (dtc * xc)[..., None] * Bc[:, :, None, :]      # [Bt,c,Di,N]
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = A_cum * h0[:, None] + B_cum                    # [Bt,c,Di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h, Cc)
        return h[:, -1], y

    h0 = jnp.zeros((Bt, Di, N), jnp.float32)
    _, ys = jax.lax.scan(body, h0, (xf, dtf, Bf, Cf))
    y = ys.transpose(1, 0, 2, 3).reshape(Bt, S, Di)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None]
    return y[:, :S0].astype(x.dtype)


def ssm_scan(x, dt, A, B, C, D, *, chunk=256, use_pallas=False, interpret=False):
    if use_pallas:
        from repro.kernels.ssm_scan import ssm_scan_pallas
        return ssm_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)
    return _ssm_scan_jnp(x, dt, A, B, C, D, chunk=chunk)


def ssm_step(h, xt, dtt, A, Bt_, Ct):
    """Single decode step of the selective scan. h:[B,Di,N] -> (h', y[B,Di])."""
    dA = jnp.exp(dtt[..., None] * A[None].astype(jnp.float32))
    dB = dtt[..., None] * Bt_[:, None, :].astype(jnp.float32)
    h = dA * h + dB * xt[..., None].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, Ct.astype(jnp.float32))
    return h, y
