"""Benchmark entrypoint tooling: a raising suite must fail the run with a
nonzero exit instead of being silently swallowed."""
import sys
import types

import pytest


def _fake_suite(name, fn):
    mod = types.ModuleType(name)
    mod.run = fn
    sys.modules[name] = mod
    return mod


def test_bench_runner_exits_nonzero_on_suite_error(monkeypatch, tmp_path,
                                                   capsys):
    import benchmarks.run as br

    _fake_suite("benchmarks._boom", lambda fast=True: (_ for _ in ()).throw(
        RuntimeError("boom")))
    _fake_suite("benchmarks._fine", lambda fast=True: [{"ok": 1}])
    monkeypatch.setattr(br, "SUITES", [("boom", "benchmarks._boom"),
                                       ("fine", "benchmarks._fine")])
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    with pytest.raises(SystemExit) as exc:
        br.main()
    assert exc.value.code == 1
    out = capsys.readouterr().out
    # the failing suite is reported AND the later suite still ran
    assert "ERROR:RuntimeError:boom" in out
    assert "fine," in out


def test_bench_runner_exits_zero_when_clean(monkeypatch, tmp_path):
    import benchmarks.run as br

    _fake_suite("benchmarks._fine2", lambda fast=True: [{"ok": 1}])
    monkeypatch.setattr(br, "SUITES", [("fine2", "benchmarks._fine2")])
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    assert br.main() is None
