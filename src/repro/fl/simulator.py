"""In-silico federation driver: whole-run scanned FedALIGN rounds,
evaluation + history logging. This is the engine behind every paper
experiment (benchmarks/bench_*.py).

The driver is NOT a per-round python loop: rounds are executed as
``lax.scan`` chunks of ``eval_every`` rounds inside one jitted program with
donated param/momentum buffers, so the host dispatches (and syncs) once per
eval point instead of once per round. Per-round stats come back as stacked
device arrays and cross to the host in one transfer per chunk.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import History
from repro.core.round import make_round_fn
from repro.data.synth import Federation
from repro.utils import tree_axpy


@functools.partial(jax.jit, static_argnames=("loss_fn",))
def _eval_batches(loss_fn, params, xb, yb):
    """[m, batch, ...] test shards -> (sum of per-batch mean losses, accs)."""
    def body(carry, b):
        loss, m = loss_fn(params, b)
        return carry, (loss, m["acc"])

    _, (losses, accs) = jax.lax.scan(body, 0, {"x": xb, "y": yb})
    return jnp.sum(losses), jnp.sum(accs)


@functools.partial(jax.jit, static_argnames=("loss_fn",))
def _eval_one(loss_fn, params, b):
    loss, m = loss_fn(params, b)
    return loss, m["acc"]


def evaluate(loss_fn, params, x, y, batch=4096):
    """Mean loss and accuracy over a test set: one jitted scan over the
    full-size batches (plus one call for the remainder) and a SINGLE
    device->host transfer, instead of a ``float()`` sync per batch."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    n = y.shape[0]
    bs = min(batch, n)
    m, rem = divmod(n, bs)
    loss_tot = acc_tot = jnp.float32(0.0)
    if m:
        ls, as_ = _eval_batches(loss_fn, params,
                                x[:m * bs].reshape(m, bs, *x.shape[1:]),
                                y[:m * bs].reshape(m, bs, *y.shape[1:]))
        loss_tot, acc_tot = ls * bs, as_ * bs
    if rem:
        lr_, ar_ = _eval_one(loss_fn, params,
                             {"x": x[m * bs:], "y": y[m * bs:]})
        loss_tot, acc_tot = loss_tot + lr_ * rem, acc_tot + ar_ * rem
    out = np.asarray(jnp.stack([loss_tot, acc_tot])) / n
    return float(out[0]), float(out[1])


def run_federation(loss_fn: Callable, init_params, fed, federation: Federation,
                   *, eval_every: int = 1, verbose: bool = False) -> History:
    """Run ``fed.rounds`` FedALIGN communication rounds."""
    round_fn = make_round_fn(loss_fn, fed)
    data = {"x": jnp.asarray(federation.x), "y": jnp.asarray(federation.y)}
    pm = jnp.asarray(federation.priority_mask)
    w = jnp.asarray(federation.weights)
    # private copy: chunk buffers are donated, and the caller keeps ownership
    # of whatever it passed in
    params = jax.tree.map(lambda a: jnp.array(a, copy=True), init_params)
    rng = jax.random.PRNGKey(fed.seed)
    hist = History()

    # beyond-paper: FedAvgM-style server momentum over aggregated deltas
    use_server_m = fed.server_opt == "momentum"
    server_m = jax.tree.map(jnp.zeros_like, params) if use_server_m else None

    @functools.partial(jax.jit, static_argnames=("n",),
                       donate_argnums=(0, 1, 2))
    def run_chunk(params, server_m, rng, r0, *, n):
        """n rounds as one scanned program; stats leaves come back [n, ...]."""
        def body(carry, i):
            params, server_m, rng = carry
            rng, rkey = jax.random.split(rng)
            new_params, stats = round_fn(params, data, pm, w, rkey, r0 + i)
            if use_server_m:
                delta = jax.tree.map(jnp.subtract, new_params, params)
                sm = jax.tree.map(lambda mi, d: fed.server_momentum * mi + d,
                                  server_m, delta)
                params = jax.tree.map(lambda o, mi: o + fed.server_lr * mi,
                                      params, sm)
                return (params, sm, rng), stats
            return (new_params, server_m, rng), stats

        (params, server_m, rng), stats = jax.lax.scan(
            body, (params, server_m, rng), jnp.arange(n, dtype=jnp.int32))
        return params, server_m, rng, stats

    # chunk boundaries = the eval rounds of the old per-round loop
    # (r % eval_every == 0, plus the final round), so logging cadence and
    # History contents are unchanged — only the dispatch granularity is.
    bounds = sorted(set(range(0, fed.rounds, eval_every)) | {fed.rounds - 1})
    start = 0
    for b in bounds:
        n = b - start + 1
        params, server_m, rng, stats = run_chunk(params, server_m, rng,
                                                 jnp.int32(start), n=n)
        stats_np = jax.tree.map(np.asarray, stats)   # one transfer per chunk
        tl, ta = evaluate(loss_fn, params, federation.test_x, federation.test_y)
        for i in range(n):
            s = {k: v[i] for k, v in stats_np.items()}
            if i == n - 1:
                hist.log(s, test_acc=ta, test_loss=tl)
                if verbose:
                    print(f"  round {b:4d} loss={float(s['global_loss']):.4f} "
                          f"test_acc={ta:.4f} "
                          f"inc={float(s['included_nonpriority']):.1f}")
            else:
                hist.log(s)
        start = b + 1
    hist.params = params
    return hist


def run_local_baseline(loss_fn, init_fn, fed, federation: Federation,
                       *, epochs: int = None, client_ids=None):
    """Paper App. C.1: train each client alone on its local data; report the
    per-client locally-trained model accuracy on the global test set."""
    from repro.core.round import _local_solver
    epochs = epochs or fed.rounds * fed.local_epochs
    fed_local = fed
    solver = _local_solver(loss_fn, fed_local)
    C = federation.x.shape[0]
    client_ids = client_ids if client_ids is not None else range(C)
    rng = jax.random.PRNGKey(fed.seed + 1)

    @jax.jit
    def train_one(d, key, params0):
        # reuse the E-epoch solver repeatedly to reach `epochs`
        def body(p, k):
            return solver(p, d, k, jnp.float32(fed.lr)), None
        keys = jax.random.split(key, max(epochs // fed.local_epochs, 1))
        p, _ = jax.lax.scan(body, params0, keys)
        return p

    accs = {}
    for c in client_ids:
        rng, k = jax.random.split(rng)
        d = {"x": jnp.asarray(federation.x[c]), "y": jnp.asarray(federation.y[c])}
        p = train_one(d, k, init_fn(jax.random.PRNGKey(fed.seed + 100 + c)))
        _, acc = evaluate(loss_fn, p, federation.test_x, federation.test_y)
        accs[c] = acc
    return accs
