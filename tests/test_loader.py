"""Federated batch loader + document packing."""
import numpy as np

from repro.data.loader import FederatedBatches, pack_token_documents


def test_batches_cover_epoch_without_repeats():
    C, n, d = 3, 12, 4
    data = {"x": np.arange(C * n * d).reshape(C, n, d),
            "y": np.arange(C * n).reshape(C, n)}
    fb = FederatedBatches(data, batch_size=4, seed=0)
    seen = [set() for _ in range(C)]
    for _ in range(3):                     # one epoch = 3 batches
        b = fb.next_batch()
        assert b["x"].shape == (C, 4, d)
        for c in range(C):
            for yv in b["y"][c]:
                assert yv not in seen[c]   # no repeats within the epoch
                seen[c].add(int(yv))
    assert all(len(s) == n for s in seen)


def test_batches_reshuffle_across_epochs():
    data = {"y": np.arange(2 * 8).reshape(2, 8)}
    fb = FederatedBatches(data, batch_size=8, seed=0)
    e1 = fb.next_batch()["y"].copy()
    e2 = fb.next_batch()["y"].copy()
    assert sorted(e1[0]) == sorted(e2[0])
    assert not np.array_equal(e1, e2)      # different order


def test_pack_token_documents():
    docs = [np.arange(10, dtype=np.int32), np.arange(7, dtype=np.int32)]
    rows = pack_token_documents(docs, seq_len=4)
    assert rows.shape[1] == 5
    assert rows.shape[0] == 17 // 5
    flat = np.concatenate(docs)
    np.testing.assert_array_equal(rows.reshape(-1), flat[:rows.size])


def test_pack_short_doc_pads():
    rows = pack_token_documents([np.arange(3, dtype=np.int32)], seq_len=7,
                                pad_id=9)
    assert rows.shape == (1, 8)
    assert list(rows[0][:3]) == [0, 1, 2]
    assert all(rows[0][3:] == 9)
