"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219]

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    tie_embeddings=False,
    source="arXiv:2404.14219",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=512, vocab_size=512, param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
