"""Kernel micro-benchmarks: wall-time of the production jnp paths on host
(CPU here; the same harness times the Pallas paths on TPU), plus oracle
max-error so every timing row is also a correctness row."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, iters=5):
    # single warm-up dispatch (block_until_ready walks pytrees, so the
    # return type never needs probing with a second — compiling — call)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(fast=True):
    rows = []
    # flash attention
    B, S, H, KV, hd = (1, 512, 8, 2, 64) if fast else (4, 2048, 16, 4, 128)
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd))
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, block_kv=128))
    us = _time(f, q, k, v)
    err = float(jnp.max(jnp.abs(f(q, k, v) - ref.attention_ref(q, k, v))))
    rows.append({"kernel": "flash_attention", "us_per_call": round(us, 1),
                 "max_err_vs_oracle": err})
    # decode attention
    kc = jax.random.normal(KEY, (B, 4096, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(KEY, 3), (B, 4096, KV, hd))
    qd = jax.random.normal(KEY, (B, 1, H, hd))
    fd = jax.jit(lambda q, k, v: ops.decode_attention(q, k, v, kv_len=4000))
    us = _time(fd, qd, kc, vc)
    err = float(jnp.max(jnp.abs(fd(qd, kc, vc)
                                - ref.decode_attention_ref(qd, kc, vc, kv_len=4000))))
    rows.append({"kernel": "decode_attention", "us_per_call": round(us, 1),
                 "max_err_vs_oracle": err})
    # fedagg
    C, M = 60, 1_000_000 if not fast else 100_000
    u = jax.random.normal(KEY, (C, M))
    w = jnp.full((C,), 1.0 / 2)
    g = (jax.random.uniform(jax.random.fold_in(KEY, 4), (C,)) > 0.5).astype(jnp.float32)
    fa = jax.jit(ops.fedagg)
    us = _time(fa, u, w, g)
    err = float(jnp.max(jnp.abs(fa(u, w, g) - ref.fedagg_ref(u, w, g))))
    rows.append({"kernel": "fedagg", "us_per_call": round(us, 1),
                 "max_err_vs_oracle": err})
    # fused multi-leaf aggregation: the whole client-stacked pytree in ONE
    # fedagg call vs one call per leaf (the production round path)
    from repro.core.aggregation import aggregate_clients
    n_leaves = 12
    leaf_m = M // n_leaves
    tree = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, 20 + i),
                                       (C, leaf_m))
            for i in range(n_leaves)}
    agg_fused = jax.jit(lambda t, w, g: aggregate_clients(t, w, g, fused=True))
    agg_leaf = jax.jit(lambda t, w, g: aggregate_clients(t, w, g, fused=False))
    us_f = _time(agg_fused, tree, w, g)
    us_l = _time(agg_leaf, tree, w, g)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(agg_fused(tree, w, g)),
                  jax.tree.leaves(agg_leaf(tree, w, g))))
    assert err < 1e-5, f"fused aggregation diverged from per-leaf: {err}"
    rows.append({"kernel": f"fedagg_fused_{n_leaves}leaf",
                 "us_per_call": round(us_f, 1), "max_err_vs_oracle": err})
    rows.append({"kernel": f"fedagg_per_leaf_{n_leaves}leaf",
                 "us_per_call": round(us_l, 1), "max_err_vs_oracle": 0.0})
    # ssm scan
    Bt, S2, Di, N = (2, 512, 64, 16) if fast else (4, 4096, 512, 16)
    x = jax.random.normal(KEY, (Bt, S2, Di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 5), (Bt, S2, Di))) * 0.1
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 6), (Di, N)) * 0.5)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 7), (Bt, S2, N))
    Cm = jax.random.normal(jax.random.fold_in(KEY, 8), (Bt, S2, N))
    Dm = jax.random.normal(jax.random.fold_in(KEY, 9), (Di,))
    fs = jax.jit(lambda *a: ops.ssm_scan(*a, chunk=128))
    us = _time(fs, x, dt, A, Bm, Cm, Dm)
    err = float(jnp.max(jnp.abs(fs(x, dt, A, Bm, Cm, Dm)
                                - ref.ssm_scan_ref(x, dt, A, Bm, Cm, Dm))))
    rows.append({"kernel": "ssm_scan_chunked", "us_per_call": round(us, 1),
                 "max_err_vs_oracle": err})
    # rmsnorm
    x = jax.random.normal(KEY, (4096, 1024))
    s = jax.random.uniform(jax.random.fold_in(KEY, 10), (1024,))
    fr = jax.jit(ops.rmsnorm)
    us = _time(fr, x, s)
    err = float(jnp.max(jnp.abs(fr(x, s) - ref.rmsnorm_ref(x, s))))
    rows.append({"kernel": "rmsnorm", "us_per_call": round(us, 1),
                 "max_err_vs_oracle": err})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
