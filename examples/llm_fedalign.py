"""End-to-end driver: federated FedALIGN training of an assigned
architecture on synthetic token streams with controllable client alignment.

Default: reduced xlstm-125m family for a quick CPU run. ``--full`` uses the
real 125M-parameter xlstm-125m config (the assignment's ~100M model) — the
same code path the dry-run lowers for the production mesh.

    PYTHONPATH=src python examples/llm_fedalign.py                 # reduced
    PYTHONPATH=src python examples/llm_fedalign.py --full --rounds 300
"""
import argparse

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    params, hist = run(arch=args.arch, smoke=not args.full,
                       rounds=args.rounds, clients=args.clients,
                       n_priority=args.clients // 2, per_client=4,
                       seq=args.seq, lr=args.lr, misalign_max=1.0)
    print("\nround  server_loss  included_nonpriority")
    for h in hist:
        print(f"{h['round']:5d}  {h['server_loss']:11.4f}  {h['included']:8.0f}")
    drop = hist[0]["server_loss"] - hist[-1]["server_loss"]
    print(f"\nserver loss drop over {args.rounds} rounds: {drop:.3f}")


if __name__ == "__main__":
    main()
