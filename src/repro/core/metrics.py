"""Round-history bookkeeping for federation runs."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class History:
    rounds: list = field(default_factory=list)
    global_loss: list = field(default_factory=list)
    test_acc: list = field(default_factory=list)
    test_loss: list = field(default_factory=list)
    theta_round: list = field(default_factory=list)
    included: list = field(default_factory=list)
    eps: list = field(default_factory=list)
    lr: list = field(default_factory=list)
    gates: list = field(default_factory=list)

    def log(self, stats, test_acc=None, test_loss=None):
        self.rounds.append(int(stats["round"]))
        self.global_loss.append(float(stats["global_loss"]))
        self.theta_round.append(float(stats["theta_round"]))
        self.included.append(float(stats["included_nonpriority"]))
        self.eps.append(float(stats["eps"]))
        self.lr.append(float(stats["lr"]))
        self.gates.append(np.asarray(stats["gates"]))
        if test_acc is not None:
            self.test_acc.append(float(test_acc))
        if test_loss is not None:
            self.test_loss.append(float(test_loss))

    def theta_T(self, gamma, E):
        t = np.asarray(self.theta_round, np.float64)
        T = len(t) * E
        return float(np.sum(np.repeat(t, E)) / (T + gamma - 2))

    def summary(self):
        return {
            "final_acc": self.test_acc[-1] if self.test_acc else None,
            "best_acc": max(self.test_acc) if self.test_acc else None,
            "final_loss": self.global_loss[-1] if self.global_loss else None,
            "mean_included": float(np.mean(self.included)) if self.included else 0.0,
        }
