"""WireCodec registry + error-feedback semantics at the engine level.

Pins (1) the identity wire: the codec-rate / error-feedback knobs are
INERT under ``wire_codec="identity"`` — bit-identical state and stats for
every registered strategy x backend, and no ``ef_accum`` leaves; (2) the
EF accumulator lifecycle — residual advance on transmitting rows only,
push-time advance under ``scan_async`` (the accumulator moves while the
pipe is still warming up and no delta has landed); (3) mid-flight
checkpoint/resume bit-identity with a compressed wire; (4) the
fingerprint refusal on codec/rate mismatch and the accumulator-naming
layout errors (checkpoint/io.py); (5) the analytic ``wire_bytes_per_round``
accounting the bench frontier rows are built on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import aggregation as agg
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.fl.simulator import (load_federation_state, run_federation,
                                save_federation_state)
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=7, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))

BACKENDS = ("vmap_spatial", "scan_temporal", "scan_async")


def _base(**kw):
    d = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
             epsilon=0.5, warmup_frac=0.0, align_stat="loss", topk=2,
             welfare_floor=0.05)
    d.update(kw)
    return FedConfig(**d)


def _run(fed, backend, r=2, seed=1, rounds=2):
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    state = engine.init_state(PARAMS, fed, C)
    for i in range(rounds):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(seed + i),
                          jnp.int32(r + i))
    return state, stats


# ===================================================== identity bit-identity
@pytest.mark.parametrize("selection", sorted(engine.STRATEGIES))
@pytest.mark.parametrize("backend", BACKENDS)
def test_identity_knobs_inert_per_strategy_backend(selection, backend):
    """The acceptance pin: under the identity wire the codec-rate and
    error-feedback knobs must not perturb a single bit of the round, for
    every strategy x backend — the codec-off branch is LITERALLY the
    legacy trace."""
    fed = _base(selection=selection)
    knobbed = fed.replace(wire_codec="identity", error_feedback=False,
                          codec_topk_frac=0.5, codec_sketch_dim=7)
    sa, ta = _run(fed, backend)
    sb, tb = _run(knobbed, backend)
    assert sa.ef_accum == () and sb.ef_accum == ()
    np.testing.assert_array_equal(np.asarray(ta["gates"]),
                                  np.asarray(tb["gates"]))
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_identity_with_ef_accum_refuses():
    """Passing accumulators alongside the identity codec is a caller bug
    (the residual is identically zero); the aggregation layer refuses."""
    cp = {"w": jnp.ones((3, 4))}
    ones = jnp.ones((3,))
    with pytest.raises(ValueError, match="identity"):
        agg.aggregate_clients(cp, ones, ones,
                              ef_accum={"w": jnp.zeros((3, 4))})


def test_codec_requires_fused_agg():
    with pytest.raises(ValueError, match="fused_agg"):
        agg.check_codec_config(_base(wire_codec="int8", fused_agg=False))
    with pytest.raises(ValueError, match="codec_topk_frac"):
        agg.check_codec_config(_base(wire_codec="topk", codec_topk_frac=0.0))
    with pytest.raises(ValueError, match="codec_sketch_dim"):
        agg.check_codec_config(_base(wire_codec="sketch",
                                     codec_sketch_dim=0))
    with pytest.raises(ValueError, match="unknown wire codec"):
        agg.get_wire_codec("zstd")


# ======================================================= EF accumulator
def test_ef_accum_layout_follows_config():
    st_id = engine.init_state(PARAMS, _base(), C)
    assert st_id.ef_accum == ()
    st_i8 = engine.init_state(PARAMS, _base(wire_codec="int8"), C)
    for p, e in zip(jax.tree.leaves(PARAMS), jax.tree.leaves(st_i8.ef_accum)):
        assert e.shape == (C,) + p.shape and e.dtype == jnp.float32
        assert float(jnp.sum(jnp.abs(e))) == 0.0
    st_noef = engine.init_state(
        PARAMS, _base(wire_codec="int8", error_feedback=False), C)
    assert st_noef.ef_accum == ()


def test_ef_residual_matches_codec_identity():
    """One aggregate_clients call: the returned accumulator IS the codec
    residual buf - decode(encode(buf)) on transmitting rows and the old
    accumulator elsewhere (gated-out rows keep their debt)."""
    fed = _base(wire_codec="int8")
    key = jax.random.PRNGKey(3)
    cp = {"w": jax.random.normal(key, (4, 6))}
    w = jnp.ones((4,))
    g = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    ef0 = {"w": jnp.full((4, 6), 0.25, jnp.float32)}
    out, ef1 = agg.aggregate_clients(cp, w, g, fed=fed, wire_codec="int8",
                                     ef_accum=ef0)
    buf = cp["w"].astype(jnp.float32) + ef0["w"]
    codec = agg.get_wire_codec("int8")
    enc, kw = codec.encode(fed, buf)
    resid = buf - codec.decode(fed, enc, kw, buf.shape[1])
    want = jnp.where(g[:, None] > 0, resid, ef0["w"])
    np.testing.assert_allclose(np.asarray(ef1["w"]), np.asarray(want),
                               atol=1e-6, rtol=1e-6)
    assert float(jnp.max(jnp.abs(ef1["w"][2] - 0.25))) == 0.0


@pytest.mark.parametrize("codec,kw", [
    ("int8", {}),
    ("topk", dict(codec_topk_frac=0.2)),
    # sketch_dim=2 << M forces collisions; at dim >= M the CountSketch can
    # be lossless and the residual (hence this advance check) exactly zero
    ("sketch", dict(codec_sketch_dim=2)),
])
@pytest.mark.parametrize("backend", BACKENDS)
def test_ef_advances_and_loss_stays_finite(codec, kw, backend):
    fed = _base(wire_codec=codec, **kw)
    state, stats = _run(fed, backend)
    assert np.isfinite(float(stats["global_loss"]))
    total = sum(float(jnp.sum(jnp.abs(e)))
                for e in jax.tree.leaves(state.ef_accum))
    assert total > 0.0, f"{codec} EF accumulator never advanced"


def test_async_ef_advances_at_push_time():
    """scan_async with a warming pipe: after round 0 NO delta has been
    applied (params bit-equal to init) but the EF accumulator has already
    advanced — the residual is charged when the cohort's delta is encoded
    and pushed, not when it lands."""
    fed = _base(wire_codec="int8", backend="scan_async", async_depth=2,
                selection="all")
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    st0 = engine.init_state(PARAMS, fed, C)
    st1, _ = fn(st0, DATA, PM, W, jax.random.PRNGKey(1), jnp.int32(2))
    for a, b in zip(jax.tree.leaves(st0.params), jax.tree.leaves(st1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    total = sum(float(jnp.sum(jnp.abs(e)))
                for e in jax.tree.leaves(st1.ef_accum))
    assert total > 0.0


# ================================================= checkpoint / resume
def test_ef_checkpoint_resume_mid_flight(tmp_path):
    """Interrupt a compressed-wire async run with cohorts still in flight
    AND live EF debt; the resumed run must be bit-identical to the
    uninterrupted one — accumulators included."""
    path = str(tmp_path / "ef.msgpack")
    fed = FedConfig(num_clients=C, num_priority=3, rounds=8, local_epochs=2,
                    epsilon=0.3, lr=0.1, warmup_frac=0.0, batch_size=32,
                    align_stat="loss", server_opt="yogi", server_lr=0.3,
                    max_cohort=5, backend="scan_async", async_depth=2,
                    staleness_decay=0.9, wire_codec="int8")
    full = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=4)

    half = run_federation(LOSS, PARAMS, fed.replace(rounds=5), FEDN,
                          eval_every=4)
    assert float(jnp.sum(half.state.inflight["valid"])) == 2.0
    assert sum(float(jnp.sum(jnp.abs(e)))
               for e in jax.tree.leaves(half.state.ef_accum)) > 0.0
    save_federation_state(path, half.state, half.rng, 5)
    state, rng, step = load_federation_state(
        path, engine.init_state(PARAMS, fed, C))
    for a, b in zip(jax.tree.leaves(half.state), jax.tree.leaves(state)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=4,
                             state=state, rng=rng, start_round=step)
    for a, b in zip(jax.tree.leaves(full.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fingerprint_refuses_codec_mismatch(tmp_path):
    """A checkpoint written under one codec (or rate) must not resume
    under another: the restored accumulators would re-inject residuals of
    a wire that no longer exists. The refusal names the codec fields."""
    path = str(tmp_path / "codec.msgpack")
    fed = _base(wire_codec="int8")
    st = engine.init_state(PARAMS, fed, C)
    save_federation_state(path, st, jax.random.PRNGKey(0), 3, fed=fed)
    like = engine.init_state(PARAMS, fed, C)
    # same codec round-trips bit-identically
    st2, _, _ = load_federation_state(path, like, fed=fed)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="wire_codec"):
        load_federation_state(path, like, fed=fed.replace(wire_codec="topk"))
    with pytest.raises(ValueError, match="error.feedback"):
        load_federation_state(path, like,
                              fed=fed.replace(error_feedback=False))

    # rate knobs are part of the wire identity too
    tfed = _base(wire_codec="topk", codec_topk_frac=0.1)
    tpath = str(tmp_path / "topk.msgpack")
    save_federation_state(tpath, engine.init_state(PARAMS, tfed, C),
                          jax.random.PRNGKey(0), 3, fed=tfed)
    with pytest.raises(ValueError, match="codec_topk_frac"):
        load_federation_state(tpath, engine.init_state(PARAMS, tfed, C),
                              fed=tfed.replace(codec_topk_frac=0.2))

    # under the identity wire the rate knobs stay inert — no refusal
    ifed = _base()
    ipath = str(tmp_path / "id.msgpack")
    save_federation_state(ipath, engine.init_state(PARAMS, ifed, C),
                          jax.random.PRNGKey(0), 3, fed=ifed)
    load_federation_state(ipath, engine.init_state(PARAMS, ifed, C),
                          fed=ifed.replace(codec_topk_frac=0.9))


def test_layout_error_names_ef_accum(tmp_path):
    """The leaf-count refusal for an EF-bearing checkpoint loaded into an
    EF-free structure (or vice versa) must name the accumulator leaves —
    the actionable-ValueError contract of checkpoint/io.py."""
    path = str(tmp_path / "layout.msgpack")
    fed = _base(wire_codec="int8")
    save_federation_state(path, engine.init_state(PARAMS, fed, C),
                          jax.random.PRNGKey(0), 3)
    with pytest.raises(ValueError, match="ef_accum"):
        load_federation_state(path, engine.init_state(PARAMS, _base(), C))


# ======================================================== bytes accounting
def test_wire_bytes_analytics():
    M = 10_000
    fed = _base(codec_topk_frac=0.01, codec_sketch_dim=256)
    ident = agg.wire_bytes_per_round(fed, C, M)
    assert ident == C * M * 4
    i8 = agg.wire_bytes_per_round(fed.replace(wire_codec="int8"), C, M)
    assert i8 == C * M + C * 4
    # the exact int8 ratio is 4M/(M+4) — strictly under 4x (f32 row scales)
    assert 3.9 < ident / i8 < 4.0
    tk = agg.wire_bytes_per_round(fed.replace(wire_codec="topk"), C, M)
    assert tk == C * 100 * 8
    sk = agg.wire_bytes_per_round(fed.replace(wire_codec="sketch"), C, M)
    assert sk == C * 256 * 4
    # bfloat16 identity wire halves the baseline the codecs compete with
    bf = agg.wire_bytes_per_round(fed.replace(agg_dtype="bfloat16"), C, M)
    assert bf == C * M * 2
