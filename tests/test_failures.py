"""Failure model + event-driven client clock + divergence guard.

Pins (1) the acceptance criterion — a DISABLED failure model (rate-0
chaos, default latency, infinite deadline) is bit-identical to the plain
round for every strategy on vmap_spatial and scan_async (fifo and ready);
(2) fault semantics — crashes lose delta mass but keep selection gates
(backlog re-enqueue), drop-outs window the availability mask, NaN
corruption is caught by the divergence guard with a bit-exact skip and a
consecutive-skip counter; (3) the event clock — per-slot countdown timers
drive the ready-mode buffer, staleness becomes the measured completion
time, finite deadlines cap timers and mask too-slow clients; (4) the
engine-boundary validation, checkpoint fingerprints, mid-flight resume
with live timers, partition specs for the new leaves, the RDP accountant,
and the sharded pod rounds threading it all."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.fl.simulator import (load_federation_state, run_federation,
                                save_federation_state)
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=7, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))

STRATEGIES = sorted(engine.STRATEGIES)


def _base(**kw):
    d = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
             epsilon=0.5, warmup_frac=0.0, align_stat="loss", topk=2,
             welfare_floor=0.05)
    d.update(kw)
    return FedConfig(**d)


def _run(fed, backend, r=0, seed=1, state=None, rounds=1):
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    if state is None:
        state = engine.init_state(PARAMS, fed, C)
    for i in range(rounds):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(seed + i),
                          jnp.int32(r + i))
    return state, stats


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _clocked(**kw):
    d = dict(backend="scan_async", async_depth=4, async_mode="ready",
             staleness_decay=1.0, latency_mode="lognormal")
    d.update(kw)
    return _base(**d)


def _with_latency(state, compute, net):
    """Pin the drawn latency leaves to known values (tests set the clock)."""
    return state.replace(latency={
        "compute": jnp.full((C,), compute, jnp.float32),
        "net": jnp.full((C,), net, jnp.float32)})


# ================================== acceptance pin: disabled == plain
DISABLED_CONFIGS = [
    ("vmap_spatial", {}),
    ("scan_async", dict(backend="scan_async", async_depth=2,
                        async_mode="fifo", staleness_decay=0.7)),
    ("scan_async", dict(backend="scan_async", async_depth=2,
                        async_mode="ready", min_lag=1,
                        staleness_decay=0.7)),
]


@pytest.mark.parametrize("selection", STRATEGIES)
@pytest.mark.parametrize("backend,cfg", DISABLED_CONFIGS,
                         ids=["vmap", "fifo2", "ready1"])
def test_disabled_failure_model_bit_identical(selection, backend, cfg):
    """crash_rate=0 chaos + default latency + round_deadline=inf must leave
    every state leaf BIT-identical to the failure-model-free round — the
    fault-free trace is untouched, for every strategy and pop policy."""
    plain = _base(selection=selection, grad_sim_sketch=True, sketch_dim=64,
                  **cfg)
    wired = plain.replace(failure_model="chaos", crash_rate=0.0,
                          dropout_rate=0.0, corrupt_rate=0.0)
    sp, tp = _run(plain, backend, rounds=3)
    sw, tw = _run(wired, backend, rounds=3)
    np.testing.assert_array_equal(np.asarray(tp["gates"]),
                                  np.asarray(tw["gates"]))
    _assert_trees_equal(sp, sw)
    # survivor accounting exists (and reads zero) only when faults are on
    assert "lost_clients" not in tp
    assert float(tw["lost_clients"]) == 0.0


def test_divergence_guard_alone_is_bit_identical_when_finite():
    """The guard itself (no faults) adds only the skip-counter leaf: on a
    finite run the cond takes the apply branch bit-exactly."""
    plain = _base()
    guarded = plain.replace(divergence_guard=True)
    sp, _ = _run(plain, "vmap_spatial", rounds=3)
    sg, tg = _run(guarded, "vmap_spatial", rounds=3)
    _assert_trees_equal(sp.params, sg.params)
    _assert_trees_equal(sp.opt_state, sg.opt_state)
    assert int(tg["skipped_nonfinite"]) == 0


# ======================================================== crash faults
def test_crash_all_freezes_params_and_reenqueues_backlog():
    """crash_rate=1: every client trains but no delta arrives — zero mass,
    bit-frozen params/moments; selection gates stay, so every selected
    client re-enqueues (+1/round) and will win cohort ties on return."""
    fed = _base(failure_model="crash", crash_rate=1.0, selection="all")
    st, t = _run(fed, "vmap_spatial", rounds=3)
    _assert_trees_equal(st.params, PARAMS)
    assert float(t["lost_clients"]) == C
    assert np.asarray(t["gates"]).sum() == 0          # effective gates
    # selection='all' gates everyone in, everyone crashes: every client's
    # ledger ticks +1 per round
    assert int(np.min(np.asarray(st.backlog))) == 3


def test_crash_faults_are_reproducible_and_round_keyed():
    """Same seed -> identical fault draws; different rounds -> independent
    draws (the failure stream folds the ABSOLUTE round index)."""
    fed = _base(failure_model="crash", crash_rate=0.5)
    p0 = engine.failure_plan(fed, 3, C)
    p1 = engine.failure_plan(fed, 3, C)
    np.testing.assert_array_equal(np.asarray(p0.crashed),
                                  np.asarray(p1.crashed))
    draws = [np.asarray(engine.failure_plan(fed, r, C).crashed)
             for r in range(32)]
    assert any(not np.array_equal(draws[0], d) for d in draws[1:])
    # and the main round rng chain is untouched: a crash-free chaos config
    # gates identically to the plain config (covered by the bit-identity
    # pin); here pin that rates compose — chaos with only crash_rate set
    # crashes exactly like the single-fault model
    chaos = fed.replace(failure_model="chaos")
    np.testing.assert_array_equal(
        np.asarray(engine.failure_plan(chaos, 3, C).crashed),
        np.asarray(p0.crashed))


def test_partial_crash_masks_only_crashed_mass():
    """crash_rate=0.5: survivors' deltas still aggregate (params move) and
    lost_clients counts exactly the crashed-and-selected mask."""
    fed = _base(failure_model="crash", crash_rate=0.5, selection="all")
    st, t = _run(fed, "vmap_spatial", rounds=1)
    crashed = np.asarray(engine.failure_plan(fed, 0, C).crashed)
    assert float(t["lost_clients"]) == crashed.sum()
    g = np.asarray(t["gates"])
    assert np.all(g[crashed] == 0.0)
    if (~crashed).any():
        changed = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(st.params),
                            jax.tree.leaves(PARAMS)))
        assert changed


# ====================================================== drop-out faults
def test_dropout_windows_hold_for_dropout_len_rounds():
    """A dropped-out client stays unavailable for dropout_len consecutive
    rounds (window-keyed stream), then redraws."""
    fed = _base(failure_model="dropout", dropout_rate=0.5, dropout_len=3)
    avail = [np.asarray(engine.failure_plan(fed, r, C).available)
             for r in range(12)]
    for w0 in range(0, 12, 3):
        np.testing.assert_array_equal(avail[w0], avail[w0 + 1])
        np.testing.assert_array_equal(avail[w0], avail[w0 + 2])
    assert any(not np.array_equal(avail[0], avail[w]) for w in (3, 6, 9))


def test_dropout_masks_selection_gates():
    """Unavailable clients fold into the participation mask: selection
    never sees them, so their gates are exactly zero."""
    fed = _base(failure_model="dropout", dropout_rate=0.9, dropout_len=1,
                selection="all")
    _, t = _run(fed, "vmap_spatial", rounds=1)
    avail = np.asarray(engine.failure_plan(fed, 0, C).available)
    g = np.asarray(t["gates"])
    assert np.all(g[~avail] == 0.0)


# ========================================== corruption + divergence guard
def test_nan_corruption_guard_skips_bit_exactly():
    """corrupt_scale=0 garbles every delta to NaN; the guard cond-skips the
    apply each round — params AND moments bit-frozen, consecutive-skip
    counter ticking 1, 2, 3, ..."""
    fed = _base(failure_model="corrupt", corrupt_rate=1.0, corrupt_scale=0.0,
                divergence_guard=True, server_opt="yogi")
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend="vmap_spatial"))
    st = engine.init_state(PARAMS, fed, C)
    for r in range(4):
        st, t = fn(st, DATA, PM, W, jax.random.PRNGKey(1 + r), jnp.int32(r))
        assert int(t["skipped_nonfinite"]) == r + 1
    _assert_trees_equal(st.params, PARAMS)
    # yogi moments untouched too: the skip is the whole ServerOptimizer
    ref = engine.init_state(PARAMS, fed, C)
    _assert_trees_equal(st.opt_state, ref.opt_state)


def test_skip_counter_resets_on_finite_round():
    """The counter tracks CONSECUTIVE skips: stochastic corruption shows
    skips[i] == 0 after any finite round, else skips[i-1] + 1."""
    fed = _base(failure_model="corrupt", corrupt_rate=0.1, corrupt_scale=0.0,
                divergence_guard=True, selection="all")
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend="vmap_spatial"))
    st = engine.init_state(PARAMS, fed, C)
    skips, prev = [], 0
    for r in range(16):
        st, t = fn(st, DATA, PM, W, jax.random.PRNGKey(1 + r), jnp.int32(r))
        s = int(t["skipped_nonfinite"])
        assert s in (0, prev + 1)
        skips.append(s)
        prev = s
    assert 0 in skips and max(skips) >= 1     # both behaviours exercised


def test_scaled_corruption_is_finite_and_unguarded():
    """corrupt_scale != 0 is a scaled-delta fault, not a NaN: the guard
    stays green and the (scaled) aggregate applies."""
    fed = _base(failure_model="corrupt", corrupt_rate=1.0, corrupt_scale=3.0,
                divergence_guard=True, selection="all")
    st, t = _run(fed, "vmap_spatial", rounds=2)
    assert int(t["skipped_nonfinite"]) == 0
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree.leaves(st.params))


def test_run_federation_halts_on_consecutive_skips():
    """The driver stops launching chunks once the counter crosses
    max_nonfinite_skips, reports the round, and returns the last finite
    params (== init here, everything was poisoned)."""
    fed = _base(rounds=10, local_epochs=1,
                failure_model="corrupt", corrupt_rate=1.0, corrupt_scale=0.0,
                divergence_guard=True, max_nonfinite_skips=3)
    h = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=4)
    assert h.diverged_at == 2          # skips reach 3 at round index 2
    assert len(h.rounds) < 10          # later chunks never launched
    _assert_trees_equal(h.params, PARAMS)


# ============================================================ event clock
def test_latency_draws_are_deterministic_and_positive():
    fed = _base(latency_mode="lognormal")
    a = engine.init_state(PARAMS, fed, C)
    b = engine.init_state(PARAMS, fed, C)
    _assert_trees_equal(a.latency, b.latency)
    for leaf in jax.tree.leaves(a.latency):
        assert np.all(np.asarray(leaf) > 0)
    assert np.asarray(a.latency["compute"]).shape == (C,)
    # different seed -> different draws (a named stream off fed.seed)
    c = engine.init_state(PARAMS, fed.replace(seed=123), C)
    assert not np.array_equal(np.asarray(a.latency["compute"]),
                              np.asarray(c.latency["compute"]))


def test_event_clock_timer_drives_landing():
    """Hand-set latency 2.0 + 0.3 -> slot timer ceil(2.3) = 3: the cohort
    pushed at round 0 lands at round 3 with MEASURED staleness 3, and
    occupancy plateaus at 3 in-flight cohorts."""
    fed = _clocked()
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend="scan_async"))
    st = _with_latency(engine.init_state(PARAMS, fed, C), 2.0, 0.3)
    pat = []
    for r in range(6):
        st, t = fn(st, DATA, PM, W, jax.random.PRNGKey(1 + r), jnp.int32(r))
        pat.append((int(t["applied_valid"]), int(t["staleness"]),
                    int(t["inflight_occupancy"])))
    assert pat[:4] == [(0, 0, 1), (0, 0, 2), (0, 0, 3), (1, 3, 3)]
    assert pat[4] == (1, 3, 3) and pat[5] == (1, 3, 3)   # steady state


def test_fast_clock_lands_next_round():
    """Sub-round latency floors at timer 1 — the delta lands exactly one
    round later, like the fifo depth-1 pipe."""
    fed = _clocked()
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend="scan_async"))
    st = _with_latency(engine.init_state(PARAMS, fed, C), 0.2, 0.1)
    st, t0 = fn(st, DATA, PM, W, jax.random.PRNGKey(1), jnp.int32(0))
    assert int(t0["applied_valid"]) == 0
    st, t1 = fn(st, DATA, PM, W, jax.random.PRNGKey(2), jnp.int32(1))
    assert int(t1["applied_valid"]) == 1
    assert int(t1["staleness"]) == 1


def test_deadline_masks_late_clients_and_caps_timer():
    """round_deadline=1.5 with one client at 10.2 rounds: that client is
    masked out of every aggregation (lost, backlogged) and the slot timer
    is capped at ceil(1.5) = 2 — the force-landing."""
    fed = _clocked(round_deadline=1.5, selection="all")
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend="scan_async"))
    st = engine.init_state(PARAMS, fed, C)
    comp = np.full((C,), 0.5, np.float32)
    comp[5] = 10.0
    st = st.replace(latency={"compute": jnp.asarray(comp),
                             "net": jnp.full((C,), 0.2, jnp.float32)})
    for r in range(3):
        st, t = fn(st, DATA, PM, W, jax.random.PRNGKey(1 + r), jnp.int32(r))
        assert float(t["lost_clients"]) == 1.0
        assert float(np.asarray(t["gates"])[5]) == 0.0
        assert int(np.max(np.asarray(st.inflight["timer"]))) <= 2
    assert int(np.asarray(st.backlog)[5]) == 3     # re-enqueued every round


def test_slot_timer_is_cohort_max_of_survivors():
    lat = {"compute": jnp.asarray([1.2, 5.0, 0.3] + [0.1] * (C - 3)),
           "net": jnp.zeros((C,))}
    gates = jnp.zeros((C,)).at[0].set(1.0).at[2].set(1.0)
    fed = _clocked()
    assert int(engine.slot_timer(fed, lat, gates)) == 2   # ceil(1.2), not 5
    # all-lost cohort: empty slot still ticks out after 1 round
    assert int(engine.slot_timer(fed, lat, jnp.zeros((C,)))) == 1


# =========================================== engine-boundary validation
@pytest.mark.parametrize("kw,match", [
    (dict(latency_mode="lognormal", round_deadline=0.0), "deadline"),
    (dict(latency_mode="lognormal", round_deadline=-1.0), "deadline"),
    (dict(round_deadline=2.0), "latency_mode"),
    (dict(latency_mode="lognormal", backend="scan_async", async_depth=2,
          async_mode="fifo"), "ready"),
    (dict(latency_mode="weird"), "latency_mode"),
    (dict(latency_mode="lognormal", latency_sigma=-0.5), "sigma"),
    (dict(failure_model="nope"), "unknown failure model"),
    (dict(failure_model="crash", crash_rate=1.5), "crash_rate"),
    (dict(failure_model="dropout", dropout_rate=-0.1), "dropout_rate"),
    (dict(failure_model="dropout", dropout_len=0), "dropout_len"),
    (dict(divergence_guard=True, max_nonfinite_skips=-1), "max_nonfinite"),
])
def test_bad_clock_config_raises(kw, match):
    fed = _base(**kw)
    with pytest.raises(ValueError, match=match):
        engine.check_clock_config(fed)


def test_temporal_pod_round_refuses_corruption():
    from repro.fl import sharded

    class M:
        init = staticmethod(INIT)
        loss_fn = staticmethod(LOSS)

    fed = _base(failure_model="corrupt", corrupt_rate=0.5)
    with pytest.raises(ValueError, match="temporal"):
        sharded.make_temporal_round(M, fed, C)


# ============================================= checkpoint / resume
def test_resume_refuses_mismatched_clock_and_failure_config(tmp_path):
    """latency_*/round_deadline/failure-model knobs change NO leaf shape
    (beyond presence) — the fingerprint refuses a mismatched resume
    instead of replaying a different fault/timer schedule."""
    path = str(tmp_path / "clock.msgpack")
    fed_w = _clocked(round_deadline=3.0, failure_model="crash",
                     crash_rate=0.1)
    st = engine.init_state(PARAMS, fed_w, C)
    save_federation_state(path, st, jax.random.PRNGKey(0), 5, fed=fed_w)
    like = engine.init_state(PARAMS, fed_w, C)
    _, _, step = load_federation_state(path, like, fed=fed_w)  # match: ok
    assert step == 5
    for bad in (fed_w.replace(latency_sigma=0.9),
                fed_w.replace(round_deadline=2.0),
                fed_w.replace(failure_model="chaos"),
                fed_w.replace(crash_rate=0.25)):
        with pytest.raises(ValueError, match="fingerprint"):
            load_federation_state(path, like, fed=bad)
    # legacy: no fed passed -> shapes-only validation still accepted
    load_federation_state(path, like)


def test_midflight_resume_with_live_timers_bit_identical(tmp_path):
    """Checkpoint after 2 clocked rounds (live countdowns in the buffer),
    reload, continue — every leaf, timers included, matches the
    uninterrupted run bit-for-bit."""
    path = str(tmp_path / "mid.msgpack")
    fed = _clocked(round_deadline=3.0)
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend="scan_async"))

    def steps(st, r0, n):
        for i in range(n):
            st, _ = fn(st, DATA, PM, W, jax.random.PRNGKey(10 + r0 + i),
                       jnp.int32(r0 + i))
        return st

    st = steps(engine.init_state(PARAMS, fed, C), 0, 2)
    assert int(np.asarray(st.inflight["timer"]).max()) > 0   # live countdowns
    save_federation_state(path, st, jax.random.PRNGKey(0), 2, fed=fed)
    st_resumed, _, step = load_federation_state(
        path, engine.init_state(PARAMS, fed, C), fed=fed)
    _assert_trees_equal(st, st_resumed)
    full = steps(steps(engine.init_state(PARAMS, fed, C), 0, 2), 2, 3)
    resumed = steps(st_resumed, 2, 3)
    _assert_trees_equal(full, resumed)


def test_federation_state_specs_cover_clock_leaves():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding.specs import auto_param_specs, federation_state_specs

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    pspecs = auto_param_specs(jax.eval_shape(lambda: params), mesh)
    fed = _clocked(divergence_guard=True, server_opt="momentum",
                   server_momentum=0.9)
    shapes = jax.eval_shape(lambda: engine.init_state(params, fed, C))
    specs = federation_state_specs(fed, pspecs)
    is_p = lambda x: isinstance(x, P)
    assert (jax.tree.structure(shapes)
            == jax.tree.structure(specs, is_leaf=is_p))
    # clock/guard leaves replicate like the other [C]/scalar client state
    assert tuple(specs.inflight["timer"]) == ()
    assert tuple(specs.latency["compute"]) == ()
    assert tuple(specs.latency["net"]) == ()
    assert tuple(specs.nonfinite_skips) == ()
    # disabled clock/guard keeps the old layout (no leaves, no specs)
    off = federation_state_specs(_base(), pspecs)
    assert off.latency == () and off.nonfinite_skips == ()


# ============================================================ DP accounting
def test_dp_epsilon_anchor_and_monotonicity():
    from repro.core.aggregation import dp_epsilon

    eps, order = dp_epsilon(1.0, 1, 1e-5)
    assert 4.5 < eps < 6.5 and order is not None   # textbook anchor ~5.3
    assert dp_epsilon(1.0, 100, 1e-5)[0] > eps     # more rounds, more spend
    assert dp_epsilon(2.0, 1, 1e-5)[0] < eps       # more noise, less spend
    assert dp_epsilon(1.0, 1, 1e-3)[0] < eps       # looser delta, less eps
    assert dp_epsilon(0.0, 10, 1e-5)[0] == float("inf")
    assert dp_epsilon(1.0, 0, 1e-5) == (0.0, None)
    with pytest.raises(ValueError, match="delta"):
        dp_epsilon(1.0, 10, 0.0)


def test_dp_report_only_for_noisy_dp_runs():
    from repro.core.aggregation import dp_report

    assert dp_report(_base(), 50) is None
    assert dp_report(_base(aggregator="dp", dp_noise=0.0), 50) is None
    eps, delta = dp_report(_base(aggregator="dp", dp_noise=1.0), 50)
    assert np.isfinite(eps) and delta == 1e-5


def test_run_federation_reports_dp_epsilon():
    fed = _base(rounds=4, local_epochs=1, aggregator="dp", dp_clip=1.0,
                dp_noise=1.0)
    h = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=2)
    assert h.dp_epsilon is not None and h.dp_delta == 1e-5
    h2 = run_federation(LOSS, PARAMS, _base(rounds=4, local_epochs=1),
                        FEDN, eval_every=2)
    assert h2.dp_epsilon is None


# ======================================================= sharded pod rounds
def _pod_batch(n=16):
    return {
        "clients": {"x": DATA["x"][:, :n], "y": DATA["y"][:, :n]},
        "server": {"x": DATA["x"][0, :n], "y": DATA["y"][0, :n]},
        "priority_mask": PM,
        "weights": W,
    }


class _TinyPodModel:
    init = staticmethod(INIT)
    loss_fn = staticmethod(LOSS)


def test_pod_rounds_disabled_failure_bit_identical():
    from repro.fl import sharded

    base = FedConfig(num_clients=C, num_priority=3, local_epochs=1,
                     epsilon=1e9, lr=0.1, warmup_frac=0.0, topk=2,
                     welfare_floor=0.05)
    b = _pod_batch()
    for mk in (sharded.make_spatial_round, sharded.make_temporal_round):
        wired = base.replace(failure_model="crash", crash_rate=0.0)
        s_ref, _ = jax.jit(mk(_TinyPodModel, base, C))(
            engine.init_state(PARAMS, base, C), b, 0)
        s_f, t_f = jax.jit(mk(_TinyPodModel, wired, C))(
            engine.init_state(PARAMS, wired, C), b, 0)
        _assert_trees_equal(s_ref, s_f)
        assert float(t_f["lost_clients"]) == 0.0


def test_pod_rounds_crash_freezes_and_backlogs():
    from repro.fl import sharded

    fed = FedConfig(num_clients=C, num_priority=3, local_epochs=1,
                    epsilon=1e9, lr=0.1, warmup_frac=0.0, topk=2,
                    welfare_floor=0.05, failure_model="crash",
                    crash_rate=1.0)
    b = _pod_batch()
    for mk in (sharded.make_spatial_round, sharded.make_temporal_round):
        step = jax.jit(mk(_TinyPodModel, fed, C))
        st = engine.init_state(PARAMS, fed, C)
        for r in range(3):
            st, t = step(st, b, r)
            assert float(t["lost_clients"]) == C
        _assert_trees_equal(st.params, PARAMS)
        assert int(np.min(np.asarray(st.backlog))) >= 3


def test_pod_spatial_nan_corruption_guarded():
    from repro.fl import sharded

    fed = FedConfig(num_clients=C, num_priority=3, local_epochs=1,
                    epsilon=1e9, lr=0.1, warmup_frac=0.0, topk=2,
                    welfare_floor=0.05, failure_model="corrupt",
                    corrupt_rate=1.0, corrupt_scale=0.0,
                    divergence_guard=True)
    step = jax.jit(sharded.make_spatial_round(_TinyPodModel, fed, C))
    st = engine.init_state(PARAMS, fed, C)
    b = _pod_batch()
    for r in range(3):
        st, t = step(st, b, r)
        assert int(t["skipped_nonfinite"]) == r + 1
    _assert_trees_equal(st.params, PARAMS)


def test_pod_rounds_event_clock_landing():
    from repro.fl import sharded

    fed = FedConfig(num_clients=C, num_priority=3, local_epochs=1,
                    epsilon=1e9, lr=0.1, warmup_frac=0.0, topk=2,
                    welfare_floor=0.05, backend="scan_async", async_depth=4,
                    async_mode="ready", staleness_decay=1.0,
                    latency_mode="lognormal")
    b = _pod_batch()
    for mk in (sharded.make_spatial_round, sharded.make_temporal_round):
        st = _with_latency(engine.init_state(PARAMS, fed, C), 2.0, 0.3)
        step = jax.jit(mk(_TinyPodModel, fed, C))
        pat = []
        for r in range(5):
            st, t = step(st, b, r)
            pat.append((int(t["applied_valid"]), int(t["staleness"]),
                        int(t["inflight_occupancy"])))
        assert pat[:4] == [(0, 0, 1), (0, 0, 2), (0, 0, 3), (1, 3, 3)]
