"""Pallas TPU decode attention: one query token vs a long KV cache.

The cache length axis is tiled in ``block_kv`` rows and is the sequential
grid axis; the online-softmax state for the G query heads of one kv-head
group lives in VMEM scratch. ``kv_len`` (number of valid cache rows) is a
dynamic scalar, passed via scalar prefetch so block masking happens on-core.

Memory-bound by design: decode attention moves the whole cache through
VMEM once; the roofline term that matters is HBM bandwidth, so blocks are
sized to keep the DMA pipeline busy rather than to feed the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, block_kv, nkv, G, hd):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)                 # [bk, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, bk]
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (G, block_kv), 1)
    s = jnp.where(k_pos < kv_len_ref[0], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, *, kv_len, scale=None,
                            block_kv=512, interpret=False):
    """q: [B,1,H,hd]; caches: [B,Skv,KV,hd]; kv_len: scalar int32."""
    B, Sq, H, hd = q.shape
    assert Sq == 1
    _, Skv, KV, _ = k_cache.shape
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    block_kv = min(block_kv, Skv)
    assert Skv % block_kv == 0
    nkv = Skv // block_kv

    qr = q[:, 0].reshape(B, KV, G, hd)
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, scale=scale, block_kv=block_kv,
                               nkv=nkv, G=G, hd=hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nkv),
        in_specs=[
            # index maps receive the prefetched scalar ref as trailing arg
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, ik, s: (b, kv, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, kv, ik, s: (b, ik, kv, 0)),
            pl.BlockSpec((1, block_kv, 1, hd), lambda b, kv, ik, s: (b, ik, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kv, ik, s: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(kv_len_arr, qr, k_cache, v_cache)

    return out.reshape(B, 1, H, hd)
