from repro.fl.simulator import evaluate, run_federation, run_local_baseline  # noqa: F401
