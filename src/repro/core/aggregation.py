"""FedALIGN renormalized gated aggregation (paper eq. (15)):

    w <- sum_k p_k I_k w_k / sum_k p_k I_k

over client-stacked parameter pytrees. The default ``fused`` path flattens
the WHOLE pytree into one [C, M_total] buffer and invokes the ``fedagg``
kernel (Pallas on TPU, its jnp lowering on CPU) ONCE per round instead of
once per leaf — one kernel launch, one contraction, and under pjit with the
client axis sharded over (pod, data) exactly one all-reduce: FedALIGN's
entire server-side communication. Accumulation is f32 regardless of leaf
dtype, so fused and per-leaf outputs agree to the cast.

This module also owns the **ServerOptimizer registry**: the fused
aggregated delta is a pseudo-gradient, and ``aggregate_updates`` applies
the configured server-side update rule (FedOpt, Reddi et al.,
arXiv:2003.00295) to it — ``sgd`` (FedAvg), ``momentum`` (FedAvgM),
``adam`` (FedAdam), ``yogi`` (FedYogi) — reusing the update rules from
``optim/optimizers.py``. Optimizer moments live in
``fl.engine.FederationState.opt_state`` and thread through the round scan.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.optim import optimizers as _opt


def flatten_stacked(client_params, dtype=jnp.float32):
    """Client-stacked pytree ([C, ...] leaves) -> one [C, M_total] buffer."""
    leaves = jax.tree.leaves(client_params)
    C = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.reshape(C, -1).astype(dtype) for leaf in leaves], axis=1)


def aggregate_clients(client_params, weights, gates, *, use_pallas=False,
                      fused=True, interpret=False):
    """client_params: pytree with leading client axis C on every leaf.

    fused=True (default): one fedagg call on the [C, M_total] flattening;
    fused=False: one fedagg call per leaf (the pre-fusion path, kept as the
    parity reference and for incremental/per-leaf sharded layouts)."""
    leaves, treedef = jax.tree.flatten(client_params)
    if not leaves:
        return client_params
    C = leaves[0].shape[0]

    if not fused:
        def agg_leaf(leaf):
            flat = leaf.reshape(C, -1)
            out = kops.fedagg(flat, weights, gates, use_pallas=use_pallas,
                              interpret=interpret)
            return out.reshape(leaf.shape[1:])
        return jax.tree.map(agg_leaf, client_params)

    # keep a uniform leaf dtype on the wire (bf16 deltas stay bf16 in the
    # [C, M_total] buffer and its collective); mixed-dtype trees go f32.
    # fedagg accumulates in f32 either way, so fused == per-leaf numerics.
    dtypes = {leaf.dtype for leaf in leaves}
    buf_dtype = dtypes.pop() if len(dtypes) == 1 else jnp.float32
    sizes = [leaf.size // C for leaf in leaves]
    buf = flatten_stacked(client_params, dtype=buf_dtype)
    out = kops.fedagg(buf, weights, gates, use_pallas=use_pallas,
                      interpret=interpret)
    agg_leaves, off = [], 0
    for leaf, size in zip(leaves, sizes):
        agg_leaves.append(
            out[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, agg_leaves)


# ========================================================= server optimizers
SERVER_OPTIMIZERS: dict[str, Callable] = {}


def register_server_optimizer(name: str):
    """Register ``factory(fed) -> optim.optimizers.Optimizer`` under ``name``.

    The factory reads its hyper-parameters off the FedConfig (duck-typed:
    anything with the ``server_*`` attributes works); the resulting
    Optimizer's ``init(params)`` builds the moment pytree carried in
    ``FederationState.opt_state`` and ``update`` consumes the aggregated
    delta as a pseudo-gradient."""
    def deco(factory):
        factory.opt_name = name
        SERVER_OPTIMIZERS[name] = factory
        return factory
    return deco


def resolve_server_opt(name) -> str:
    """Canonical registry name ('none', the legacy no-op, is plain sgd)."""
    return "sgd" if name in (None, "none") else name


def get_server_optimizer(name: str) -> Callable:
    name = resolve_server_opt(name)
    try:
        return SERVER_OPTIMIZERS[name]
    except KeyError:
        raise ValueError(f"unknown server optimizer {name!r}; "
                         f"registered: {sorted(SERVER_OPTIMIZERS)}") from None


def server_optimizer(fed):
    """The configured ServerOptimizer instance for ``fed.server_opt``."""
    return get_server_optimizer(fed.server_opt)(fed)


@register_server_optimizer("sgd")
def _server_sgd(fed):
    # w <- w + server_lr * agg_delta: FedAvg at server_lr=1 (the paper rule)
    return _opt.sgd(0.0)


@register_server_optimizer("momentum")
def _server_momentum(fed):
    # FedAvgM: momentum over aggregated deltas
    return _opt.sgd(momentum=fed.server_momentum)


@register_server_optimizer("adam")
def _server_adam(fed):
    return _opt.adam(fed.server_b1, fed.server_b2, fed.server_eps)


@register_server_optimizer("yogi")
def _server_yogi(fed):
    return _opt.yogi(fed.server_b1, fed.server_b2, fed.server_eps)


def apply_server_opt(fed, global_params, opt_state, agg_delta, *, scale=1.0):
    """One server-optimizer step on an already-aggregated global delta.

    Returns (new_params, new_opt_state). The delta enters the optimizer as
    the pseudo-gradient g = -agg_delta, so ``sgd`` at server_lr recovers
    w + server_lr * delta exactly and ``momentum`` reproduces the legacy
    FedAvgM recursion m <- beta m + delta, w <- w + server_lr m.

    ``scale`` pre-multiplies the delta (in f32, after the wire-dtype cast):
    the staleness discount of the ``scan_async`` backend enters the
    optimizer here — one call PER POPPED in-flight slot, each with that
    slot's own scale (the constant ``staleness_decay ** async_depth``
    under the fifo pipe; ``staleness_decay ** age``, optionally times the
    measured-drift cosine, under the variable-lag ``ready`` buffer) — so a
    stale delta's momentum/second-moment contribution is discounted too,
    not just its parameter step. ``scale`` may be a traced scalar (the
    measured-age discounts are); only the python-literal 1.0 skips the
    multiply entirely — the synchronous path is untouched."""
    opt = server_optimizer(fed)
    if isinstance(scale, (int, float)) and float(scale) == 1.0:
        grads = jax.tree.map(lambda d: -d.astype(jnp.float32), agg_delta)
    else:
        grads = jax.tree.map(lambda d: -d.astype(jnp.float32) * scale,
                             agg_delta)
    return opt.update(grads, opt_state, global_params, fed.server_lr)


def aggregate_delta(global_params, client_params, weights, gates, *,
                    fed, interpret=False):
    """Delta-form gated aggregation WITHOUT the server step:

        d <- agg(cast(w_k - w, fed.agg_dtype))      (ONE fused fedagg call)

    Returns the aggregated global delta (leaves in ``fed.agg_dtype``).
    This is the seam the ``scan_async`` backend buffers: an in-flight
    cohort is exactly one of these deltas awaiting its (staleness-
    discounted) ``apply_server_opt`` some rounds later. ``client_params``
    may live in cohort space [K, ...] (zero gates drop padding slots)."""
    ad = jnp.dtype(fed.agg_dtype)
    deltas = jax.tree.map(lambda ck, g: (ck - g[None]).astype(ad),
                          client_params, global_params)
    return aggregate_clients(deltas, weights, gates,
                             use_pallas=fed.use_pallas,
                             fused=fed.fused_agg, interpret=interpret)


def aggregate_updates(global_params, client_params, weights, gates, *,
                      fed, opt_state=(), interpret=False):
    """Delta-form gated aggregation + the configured server optimizer:

        d  <- aggregate_delta(...)                  (ONE fused fedagg call)
        w, moments <- ServerOptimizer(fed.server_opt)(w, moments, d)

    Returns (new_params, new_opt_state). ``fed.agg_dtype`` selects the
    reduced-precision delta wire format; accumulation is f32 either way."""
    agg = aggregate_delta(global_params, client_params, weights, gates,
                          fed=fed, interpret=interpret)
    return apply_server_opt(fed, global_params, opt_state, agg)
