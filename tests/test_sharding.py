"""Auto-sharder rules on the production AbstractMesh (no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.configs.base import FedConfig
from repro.models import get_model
from repro.sharding.specs import (auto_batch_specs, auto_param_specs,
                                  auto_tree_specs, federation_state_specs)

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _check_divisible(shapes, specs, mesh):
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0]):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multi"])
def test_param_specs_divisible_full_configs(arch, mesh):
    cfg = get_config(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = auto_param_specs(shapes, mesh, fsdp=arch in
                             ("jamba_1_5_large_398b", "llava_next_34b"))
    _check_divisible(shapes, specs, mesh)


def test_model_axis_used_on_big_weights():
    cfg = get_config("qwen1_5_0_5b")
    model = get_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = auto_param_specs(shapes, MESH)
    # attention projections must be tensor-parallel
    wq_spec = specs["periods"]["l0"]["attn"]["wq"]
    assert "model" in tuple(wq_spec)
    # embed sharded too (vocab or d)
    assert any(x is not None for x in specs["embed"])


def test_fsdp_adds_data_axis():
    cfg = get_config("llava_next_34b")
    model = get_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs_f = auto_param_specs(shapes, MESH, fsdp=True)
    leaves = jax.tree.leaves(jax.tree.map(
        lambda s: int("data" in [a for a in s if a]), specs_f,
        is_leaf=lambda s: isinstance(s, P)))
    assert sum(leaves) > 0


def test_batch_specs():
    shapes = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
              "odd": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
    specs = auto_batch_specs(shapes, MESH)
    assert specs["tokens"] == P(("data",), None) or specs["tokens"] == P(("data",),) \
        or specs["tokens"][0] == ("data",)
    assert all(s is None for s in specs["odd"])


def test_cache_specs_divisible():
    cfg = get_config("qwen2_5_3b")       # KV=2: model axis must NOT land on KV
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.make_cache(128, 32768))
    specs = auto_tree_specs(shapes, MESH)
    _check_divisible(shapes, specs, MESH)


def test_cache_specs_batch_one():
    cfg = get_config("xlstm_125m")
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.make_cache(1, 524288))
    specs = auto_tree_specs(shapes, MESH)
    _check_divisible(shapes, specs, MESH)


@pytest.mark.parametrize("server_opt,kw", [
    ("sgd", {}), ("momentum", {}), ("adam", {}), ("yogi", {}),
    ("momentum", {"server_momentum": 0.0}),     # collapses to stateless sgd
])
def test_federation_state_specs_match_state_tree(server_opt, kw):
    """The FederationState spec tree must mirror init_state's pytree for
    every optimizer layout (dryrun lowers the full state), with moments
    inheriting the param specs and client-state replicated."""
    from repro.fl import engine
    cfg = get_smoke("qwen1_5_0_5b")
    model = get_model(cfg)
    fed = FedConfig(server_opt=server_opt, **kw)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = auto_param_specs(shapes, MESH)
    state_shapes = jax.eval_shape(lambda p: engine.init_state(p, fed, 8),
                                  shapes)
    sspecs = federation_state_specs(fed, pspecs)
    assert (jax.tree.structure(state_shapes) ==
            jax.tree.structure(sspecs, is_leaf=lambda s: isinstance(s, P)))
    assert sspecs.backlog == P() and sspecs.util_ema == P()
    if server_opt in ("adam", "yogi"):
        assert sspecs.opt_state["m"] == pspecs


def test_expert_parallel_toggle():
    cfg = get_config("jamba_1_5_large_398b")   # 16 experts == model axis
    model = get_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    sp = auto_param_specs(shapes, MESH, expert_parallel=True)
    moe_spec = sp["periods"]["l1"]["moe"]["w_gate"]
    # stacked periods axis + expert axis
    assert jax.tree.leaves(moe_spec)[0] is None or True
    flat = [a for a in moe_spec if a is not None]
    assert "model" in flat
    # expert dim (index 1 after the period-stack axis) carries model
    assert moe_spec[1] == "model"
