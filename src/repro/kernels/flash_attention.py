"""Pallas TPU flash attention (blockwise online softmax, GQA).

Target: TPU v5e MXU. Tiling: queries in ``block_q`` rows, keys/values in
``block_kv`` rows, one (batch x kv-head x q-group) per grid cell; the kv
dimension is the innermost (sequential) grid axis so the m/l/acc online-
softmax state lives in VMEM scratch across kv blocks.

Layout notes (HBM->VMEM):
  q   [B*KV, G, Sq, hd]   block (1, 1, block_q, hd)
  k,v [B*KV, Skv, hd]     block (1, block_kv, hd)
  out like q.
hd is expected to be 64/96/128 (lane-aligned); block_q/block_kv multiples
of 128 keep the MXU fed on the s = q @ k^T and p @ v contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal, window, scale, block_q, block_kv, nkv, q_offset):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                       # [bk, hd]
    v = v_ref[0].astype(jnp.float32)                       # [bk, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nkv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _kernel_fwd_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                    causal, window, scale, block_q, block_kv, nkv, q_offset):
    """Forward kernel variant that also emits LSE = m + log(l) per query row
    (needed by the backward pass)."""
    ik = pl.program_id(3)
    _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            causal=causal, window=window, scale=scale, block_q=block_q,
            block_kv=block_kv, nkv=nkv, q_offset=q_offset)

    @pl.when(ik == nkv - 1)
    def _emit_lse():
        lse_ref[0, 0] = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))


def _mask(block_q, block_kv, iq, ik, *, causal, window, q_offset):
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    return mask


def _kernel_dq(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc_ref, *,
               causal, window, scale, block_q, block_kv, nkv, q_offset):
    """dq = sum_kv (P o (dP - delta)) K * scale, P = exp(S - LSE).
    Grid: (BKV, G, nq, nkv); kv innermost, accumulated in VMEM scratch."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = _mask(block_q, block_kv, iq, ik, causal=causal, window=window,
                 q_offset=q_offset)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ik == nkv - 1)
    def _done():
        dq_ref[0, 0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _kernel_dkv(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                causal, window, scale, block_q, block_kv, nq, q_offset):
    """dk/dv for one kv block; grid (BKV, G, nkv, nq) with q innermost.
    dv = P^T dO ; dk = dS^T Q * scale."""
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = _mask(block_q, block_kv, iq, ik, causal=causal, window=window,
                 q_offset=q_offset)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)          # [bq, bk]
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _layout(q, k, v):
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qr = q.transpose(0, 2, 1, 3).reshape(B, KV, G, Sq, hd).reshape(B * KV, G, Sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    return qr, kr, vr, (B, Sq, H, hd, Skv, KV, G)


def _unlayout_q(x, dims):
    B, Sq, H, hd, Skv, KV, G = dims
    return x.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


def flash_attention_fwd_pallas(q, k, v, *, causal=True, window=0, scale=None,
                               block_q=128, block_kv=128, interpret=False):
    """Returns (out [B,Sq,H,hd], lse [B*KV, G, Sq])."""
    qr, kr, vr, dims = _layout(q, k, v)
    B, Sq, H, hd, Skv, KV, G = dims
    if scale is None:
        scale = hd ** -0.5
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq, nkv = Sq // block_q, Skv // block_kv

    kernel = functools.partial(
        _kernel_fwd_lse, causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv, nkv=nkv, q_offset=Skv - Sq)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B * KV, G, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, g, iq, ik: (b, g, iq, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, g, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, g, iq, ik: (b, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, g, iq, ik: (b, g, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, g, iq, ik: (b, g, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, G, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B * KV, G, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return _unlayout_q(out, dims), lse


def flash_attention_bwd_pallas(q, k, v, out, lse, do, *, causal=True, window=0,
                               scale=None, block_q=128, block_kv=128,
                               interpret=False):
    """Two-pass flash backward: (dq, dk, dv), all like their primals."""
    qr, kr, vr, dims = _layout(q, k, v)
    B, Sq, H, hd, Skv, KV, G = dims
    or_, dor = (_layout(out, k, v)[0], _layout(do, k, v)[0])
    if scale is None:
        scale = hd ** -0.5
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq, nkv = Sq // block_q, Skv // block_kv
    q_offset = Skv - Sq

    # delta = rowsum(dO o O) — tiny, compute with jnp
    delta = jnp.sum(dor.astype(jnp.float32) * or_.astype(jnp.float32), axis=-1)

    common = dict(causal=causal, window=window, scale=scale,
                  block_q=block_q, block_kv=block_kv, q_offset=q_offset)

    q_spec = pl.BlockSpec((1, 1, block_q, hd), lambda b, g, i, j: (b, g, i, 0))
    kv_spec_q = pl.BlockSpec((1, block_kv, hd), lambda b, g, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda b, g, i, j: (b, g, i))

    dq = pl.pallas_call(
        functools.partial(_kernel_dq, nkv=nkv, **common),
        grid=(B * KV, G, nq, nkv),
        in_specs=[q_spec, kv_spec_q, kv_spec_q, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, dor, lse, delta)

    # dk/dv: kv block outer, q block inner (sequential) so dk/dv accumulate
    q_spec2 = pl.BlockSpec((1, 1, block_q, hd), lambda b, g, j, i: (b, g, i, 0))
    kv_spec2 = pl.BlockSpec((1, block_kv, hd), lambda b, g, j, i: (b, j, 0))
    row_spec2 = pl.BlockSpec((1, 1, block_q), lambda b, g, j, i: (b, g, i))

    # dk/dv: the out block (b, j) is revisited once per q-head group g with
    # other j blocks in between, so cross-g accumulation can't live in VMEM
    # scratch — run one call per group and sum (G is small: <= 8 for the
    # assigned archs). G==1 (MHA after grouping) needs a single call.
    def _dkv_call(qg, dog, lseg, deltag):
        return pl.pallas_call(
            functools.partial(_kernel_dkv, nq=nq, **common),
            grid=(B * KV, 1, nkv, nq),
            in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2,
                      row_spec2],
            out_specs=[kv_spec2, kv_spec2],
            out_shape=[jax.ShapeDtypeStruct((B * KV, Skv, hd), jnp.float32),
                       jax.ShapeDtypeStruct((B * KV, Skv, hd), jnp.float32)],
            scratch_shapes=[pltpu.VMEM((block_kv, hd), jnp.float32),
                            pltpu.VMEM((block_kv, hd), jnp.float32)],
            interpret=interpret,
        )(qg, kr, vr, dog, lseg, deltag)

    dk_g = jnp.zeros((B * KV, Skv, hd), jnp.float32)
    dv_g = jnp.zeros((B * KV, Skv, hd), jnp.float32)
    for g in range(G):
        dk1, dv1 = _dkv_call(qr[:, g:g + 1], dor[:, g:g + 1],
                             lse[:, g:g + 1], delta[:, g:g + 1])
        dk_g = dk_g + dk1
        dv_g = dv_g + dv1

    dq = _unlayout_q(dq, dims)
    dk = dk_g.reshape(B, KV, Skv, hd).transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv_g.reshape(B, KV, Skv, hd).transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


def flash_attention_pallas(q, k, v, *, causal=True, window=0, kv_len=None,
                           scale=None, block_q=128, block_kv=128, interpret=False):
    """q: [B,Sq,H,hd]; k/v: [B,Skv,KV,hd]. Returns [B,Sq,H,hd].

    Differentiable: forward saves per-row LSE; backward runs the two-pass
    flash backward kernels (dq then dk/dv)."""
    assert kv_len is None, "flash path assumes a full kv sequence"

    @functools.partial(jax.custom_vjp, nondiff_argnums=())
    def _fa(q, k, v):
        out, _ = flash_attention_fwd_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
        return out

    def _fwd(q, k, v):
        out, lse = flash_attention_fwd_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
        return out, (q, k, v, out, lse)

    def _bwd(res, do):
        q, k, v, out, lse = res
        return flash_attention_bwd_pallas(
            q, k, v, out, lse, do, causal=causal, window=window, scale=scale,
            block_q=block_q, block_kv=block_kv, interpret=interpret)

    _fa.defvjp(_fwd, _bwd)
    return _fa(q, k, v)
