"""Pod-scale FedALIGN: the communication round as a single pjit program.

Two execution modes, chosen by model size (DESIGN.md §3):

* **spatial** — clients ARE the (pod, data) mesh shards. Client-stacked
  params [C, ...] are vmapped through E local SGD steps in parallel; the
  gated aggregation contracts the client axis, lowering to ONE all-reduce
  over (pod, data) — FedALIGN's entire server communication.

* **temporal** — for models too large to replicate per client (jamba-398b,
  llava-34b): params stay (data, model)-sharded (FSDP+TP); the client
  cohort is traversed with lax.scan, each client running its local steps
  on the full mesh; gated updates accumulate in the scan carry. The
  federation semantics are identical — clients are time-multiplexed
  instead of space-multiplexed.

Both rounds have the engine's persistent-state signature

    round_step(state: engine.FederationState, batch, round_idx=0)
        -> (new_state, stats)

so server-optimizer moments (``fed.server_opt``), the ``max_cohort``
overflow backlog, the welfare utility EMAs, and the ``scan_async``
in-flight cohort buffer thread through pod rounds exactly as through the
in-silico simulator. ``fed.async_depth = D > 0`` runs BOTH pod modes with
overlapped cohorts: the round aggregates as usual but its delta enters the
``FederationState.inflight`` buffer and whichever buffered deltas the
``fed.async_mode`` pop policy declares ready (the slot that aged exactly D
rounds under "fifo"; every slot aged >= ``min_lag``, oldest first, under
the FedBuff-style "ready") are applied instead, each staleness-discounted
by its own age — and by its measured drift under
``fed.adaptive_staleness`` (``engine.async_apply`` — the same state
machine as the engine's ``scan_async`` backend, so pod rounds and the
simulator stay drift-free).

The server statistic F(w_t) is computed on a server-held global batch
(paper §3.1: "the server transmits ... also its associated loss"), so the
gate needs no second pass over clients. Gating itself comes from the
SelectionStrategy registry in fl/engine.py — the SAME implementation the
in-silico simulator uses, as is the cohort gather order
(``engine.cohort_select``: one overflow/backlog policy, no pod/simulator
drift). Both modes gate BEFORE training wherever the strategy allows it
(``not needs_deltas``): the temporal scan fixes gates from a cheap eval
pre-pass (one forward per client, negligible next to E local steps) and
wraps each streamed client's training in ``lax.cond(gate > 0, ...)`` so
gated-out FSDP clients skip their E local steps entirely; the spatial
round, when ``fed.max_cohort > 0``, gathers the included clients into a
dense [K, ...] cohort and trains only those. Delta-based strategies
(grad_sim) keep the train-first order; the temporal round requires
``fed.grad_sim_sketch=True`` and scores streamed clients on a CountSketch
random projection of their delta (``engine.delta_sketch``, width
``fed.sketch_dim``) — the [C, sketch_dim] sketch buffer replaces the
impossible [C, M_total] flatten — then re-runs the (deterministic) local
steps of included clients in a second cond-skipped scan once the gates
are known. The opt-in is explicit because the sketch is JL-approximate:
with it set, the spatial round scores on the same sketches, so the two
modes stay gate-identical; without it, exact cosines exist only
spatially and the temporal round refuses rather than silently diverge.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import validate_config
from repro.core.aggregation import (aggregator_key, apply_server_opt,
                                    flatten_stacked, get_aggregator,
                                    inclusion_mass, resolve_aggregator,
                                    resolve_wire_codec)
from repro.core.alignment import epsilon_at
from repro.fl import engine
from repro.utils import fold_in_name, tree_axpy, tree_sub

FSDP_ARCHS = {"jamba-1.5-large-398b", "llava-next-34b"}


def needs_fsdp(cfg) -> bool:
    return cfg.name in FSDP_ARCHS


def _train_steps(model, params, batch, lr, n_steps):
    """E local SGD steps on one client's batch (deterministic: full-batch
    gradients, no PRNG — re-running them reproduces the update exactly)."""
    def step(p, _):
        loss, grads = jax.value_and_grad(
            lambda q: model.loss_fn(q, batch)[0])(p)
        return tree_axpy(-lr, grads, p), loss

    params, _ = jax.lax.scan(step, params, None, length=n_steps)
    return params


def _local_steps(model, params, batch, lr, n_steps):
    """Local training plus F_k(w_t) of the *received* model (the paper's
    matching statistic). Returns (params', loss0)."""
    loss0, _ = model.loss_fn(params, batch)
    return _train_steps(model, params, batch, lr, n_steps), loss0


def _gate_ctx(fed, state, util_ema, local_losses, server_loss, pm, w,
              delta_cos=None, round_idx=0, participation=None):
    """SelectionContext for one pod-scale round. ``round_idx`` threads the
    driver's round counter into the eps schedule (eps_t via ``epsilon_at``);
    drivers that never pass it keep the t=0 value (== fed.epsilon).
    ``util_ema`` is the updated RAW loss-gap EMA (this round's observation
    folded in) — the strategy sees its bias-corrected estimate;
    backlog/incl_ema come straight from the FederationState.
    ``participation`` carries the failure model's availability mask
    (transient drop-outs) — None keeps the everyone-present gate."""
    return engine.SelectionContext(
        align_vals=local_losses, global_align=server_loss,
        eps=epsilon_at(fed, round_idx), priority_mask=pm, weights=w,
        participation=participation,
        delta_cos=delta_cos, topk=fed.topk, sim_threshold=fed.sim_threshold,
        backlog=state.backlog,
        util_ema=engine.utility_estimate(fed, util_ema, round_idx),
        incl_ema=state.incl_ema, welfare_floor=fed.welfare_floor)


def _next_state(fed, state, new_params, opt_state, sel_gates, eff_gates,
                util_ema, inflight=None, last_delta=None,
                nonfinite_skips=None, ef_accum=None):
    """Advance the cross-round carry with THE engine update rules."""
    return engine.FederationState(
        params=new_params, opt_state=opt_state,
        backlog=engine.backlog_update(state.backlog, sel_gates, eff_gates),
        util_ema=util_ema,
        incl_ema=engine.inclusion_update(fed, state.incl_ema, eff_gates),
        inflight=state.inflight if inflight is None else inflight,
        last_delta=state.last_delta if last_delta is None else last_delta,
        latency=state.latency,
        nonfinite_skips=(state.nonfinite_skips if nonfinite_skips is None
                         else nonfinite_skips),
        ef_accum=state.ef_accum if ef_accum is None else ef_accum)


def _apply_delta(fed, state, params, agg_delta, mass=None, push_timer=None,
                 finite=None):
    """Apply an aggregated global delta the way the engine would: at the
    round barrier when ``fed.async_depth == 0``, or through the
    FederationState in-flight buffer's pop policy (``engine.async_apply``,
    THE staleness state machine — fifo pipe, variable-lag readiness pops,
    or the event clock's per-slot countdowns via ``push_timer``) when the
    pod round runs overlapped cohorts. ``mass`` is the aggregator's
    inclusion mass for the round (``aggregation.inclusion_mass`` / the
    temporal round's streamed denominator): when given, a zero-mass round
    skips the ServerOptimizer entirely — params AND moments stay
    bit-identical instead of momentum decaying on an all-zero delta.
    ``finite`` is the divergence-guard predicate (``engine
    .aggregate_finite``): a non-finite aggregate is skipped the same
    bit-exact way (sync) or zeroed before it enters the buffer (async).
    Returns (new_params, opt_state, inflight, last_delta, info | None)."""
    if fed.async_depth > 0:
        if finite is not None:
            agg_delta = jax.tree.map(
                lambda d: jnp.where(finite, d, jnp.zeros_like(d)), agg_delta)
        return engine.async_apply(fed, params, state.opt_state,
                                  state.inflight, agg_delta,
                                  last_delta=state.last_delta,
                                  push_timer=push_timer)
    pred = None if mass is None else mass > 0
    if finite is not None:
        pred = finite if pred is None else pred & finite
    if pred is None:
        new_params, opt_state = apply_server_opt(fed, params, state.opt_state,
                                                 agg_delta)
    else:
        new_params, opt_state = jax.lax.cond(
            pred,
            lambda: apply_server_opt(fed, params, state.opt_state, agg_delta),
            lambda: (params, state.opt_state))
    return new_params, opt_state, state.inflight, state.last_delta, None


def _async_stats(fed, stats, info, inflight):
    """Async-only stat keys (python-level branch: synchronous pod rounds
    keep their exact stats structure). "staleness" reports the MEASURED
    age of the oldest delta applied this round — 0 when nothing landed
    (warm-up rounds), never the constant pipeline depth."""
    if fed.async_depth > 0:
        stats["staleness"] = info["applied_age"]
        stats["applied_valid"] = info["applied_valid"]
        stats["inflight_occupancy"] = jnp.sum(inflight["valid"])
    return stats


def _failure_stats(fed, stats, lost, nonfinite_skips):
    """Failure-model / divergence-guard stat keys (python-level branches,
    like ``_async_stats``): survivor accounting + consecutive skips."""
    if lost is not None:
        stats["lost_clients"] = jnp.sum(lost.astype(jnp.float32))
    if fed.divergence_guard:
        stats["skipped_nonfinite"] = nonfinite_skips
    return stats


def pool_round_key(fed, round_idx):
    """The pod rounds take no rng argument, so the candidate-pool draw is a
    NAMED stream off the config seed folded with the ABSOLUTE round index —
    deterministic across processes (crc32 ``fold_in_name``), resume-safe
    (round r redraws r's exact pool), and independent of the failure /
    aggregator / latency streams."""
    base = fold_in_name(jax.random.PRNGKey(fed.seed), "candidate_pool")
    return jax.random.fold_in(base, round_idx)


def _pool_wrap(fed, round_step):
    """Candidate-pool wrapper shared by both pod rounds: sample P of the C
    clients (``engine.pool_select`` — priority always in-pool), run the
    wrapped round on the [P] gather of the batch and the per-client state
    leaves, and scatter the updated leaves back at the sampled indices.
    The pool slice keeps the existing mesh layout: client-sharded leaves
    gather into [P] shards, shard-local aggregation runs unchanged, and
    the cross-pod reduce stays the one [M_total] all-reduce.

    ``candidate_pool = 0`` (and P >= C) returns the wrapped round itself —
    the dense trace, bit-identical to the legacy pod round."""
    pool = int(getattr(fed, "candidate_pool", 0))
    if pool <= 0:
        return round_step
    clock_on = fed.latency_mode != "none"
    ef_on = (resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
             != "identity") and bool(fed.error_feedback)

    def pooled_step(state, batch, round_idx=0):
        pm = batch["priority_mask"]
        C = pm.shape[0]
        if pool >= C:
            return round_step(state, batch, round_idx)
        pool_idx = engine.pool_select(fed, pool_round_key(fed, round_idx),
                                      pm, state.backlog, state.incl_ema,
                                      pool)

        def take(a):
            return a[pool_idx]

        view = state.replace(
            backlog=take(state.backlog),
            util_ema=take(state.util_ema),
            incl_ema=take(state.incl_ema),
            latency=(jax.tree.map(take, state.latency) if clock_on
                     else state.latency),
            ef_accum=(jax.tree.map(take, state.ef_accum) if ef_on
                      else state.ef_accum))
        sub_batch = dict(batch)
        sub_batch["clients"] = jax.tree.map(take, batch["clients"])
        sub_batch["priority_mask"] = take(pm)
        sub_batch["weights"] = take(batch["weights"])
        sub, stats = round_step(view, sub_batch, round_idx,
                                client_ids=pool_idx)
        new_state = sub.replace(
            backlog=state.backlog.at[pool_idx].set(sub.backlog),
            util_ema=state.util_ema.at[pool_idx].set(sub.util_ema),
            incl_ema=state.incl_ema.at[pool_idx].set(sub.incl_ema),
            latency=state.latency,      # read-only: drawn once at init
            ef_accum=(jax.tree.map(
                lambda full, s: full.at[pool_idx].set(s),
                state.ef_accum, sub.ef_accum) if ef_on else state.ef_accum))
        # per-client stats keep the dense [C] index space downstream
        # tooling expects; out-of-pool rows report 0
        for name in ("local_losses", "gates"):
            stats[name] = (jnp.zeros((C,), stats[name].dtype)
                           .at[pool_idx].set(stats[name]))
        stats["backlog"] = new_state.backlog
        stats["pool_idx"] = pool_idx
        return new_state, stats

    return pooled_step


def make_spatial_round(model, fed, num_clients: int):
    """Returns round_step(state, batch, round_idx=0) -> (new_state, stats).

    batch: client-stacked arrays [C, b, ...] + server_* arrays (global data).
    priority_mask/weights [C] ride inside batch so everything is one pytree.

    Gate-before-train: for strategies that gate from losses of the received
    model alone (``not needs_deltas``) and ``fed.max_cohort > 0``, an eval
    pre-pass fixes the gates, the K included clients are gathered into a
    dense [K, ...] cohort (``engine.cohort_select`` — backlog-aware
    overflow), and only they run their E local steps — round cost O(K*E)
    instead of O(C*E). grad_sim keeps the train-first order.
    """
    E = fed.local_epochs
    lr = fed.lr
    validate_config(fed)
    agg_needs_key = get_aggregator(fed.aggregator).needs_key
    strategy = engine.get_strategy(fed.selection)
    use_cohort = fed.max_cohort > 0 and not strategy.needs_deltas
    failure_on = engine.resolve_failure_model(fed.failure_model) != "none"
    clock_on = fed.latency_mode != "none"
    # the wire codec is shard-local: each pod shard encodes its own client
    # rows and the fused kernel decodes-and-reduces per shard — the single
    # cross-shard all-reduce stays on the [M_total] aggregate, unchanged
    codec_on = (resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
                != "identity")
    ef_on = codec_on and bool(fed.error_feedback)

    def round_step(state, batch, round_idx=0, client_ids=None):
        params = state.params
        client_batch = batch["clients"]
        pm = batch["priority_mask"]
        w = batch["weights"]
        C = pm.shape[0]

        server_loss, _ = model.loss_fn(params, batch["server"])
        akey = aggregator_key(fed, round_idx) if agg_needs_key else None
        ef_accum = state.ef_accum

        # fault injection mirrors the engine round: availability folds into
        # the selection context, crashes/deadline-late clients are masked
        # AFTER training (lost_mask), corruption rides the same transform.
        # client_ids (a pooled round's [P] global identities) keys the
        # fault draws on the IDENTITY, pool-independent
        plan = (engine.failure_plan(fed, round_idx, C, client_ids=client_ids)
                if failure_on else None)
        part = (plan.available if plan is not None
                and plan.available is not None else None)
        lost = engine.lost_mask(fed, state, plan)
        ctf = (engine.corruption_transform(fed, plan.corrupt)
               if plan is not None and plan.corrupt is not None else None)

        if use_cohort:
            # eval -> gates -> gather-train: only K cohort slots pay E steps
            local_losses = jax.vmap(
                lambda cb: model.loss_fn(params, cb)[0])(client_batch)
            util_ema = engine.utility_update(fed, state.util_ema,
                                             local_losses, server_loss)
            sel_gates = engine.compute_gates(
                _gate_ctx(fed, state, util_ema, local_losses, server_loss,
                          pm, w, round_idx=round_idx, participation=part),
                fed.selection)
            idx, cg, gates = engine.cohort_select(
                sel_gates, local_losses, server_loss, pm,
                min(fed.max_cohort, C), backlog=state.backlog,
                backlog_boost=float(fed.backlog_boost))
            cohort_params = jax.vmap(
                lambda cb: _train_steps(model, params, cb, lr, E))(
                jax.tree.map(lambda a: a[idx], client_batch))
            if ctf is not None:
                cohort_params = ctf(cohort_params, params, idx)
            agg_w, agg_g = w[idx], cg
            if lost is not None:
                # crashed / deadline-late: trained, but the delta never
                # arrives — mass masked out; sel_gates stay, so the backlog
                # re-enqueues them (+1, tie-winning on return)
                keep = 1.0 - lost.astype(jnp.float32)
                agg_g = agg_g * keep[idx]
                gates = gates * keep
            if ef_on:
                # only the K gathered slots encoded a delta: their EF rows
                # gather with the cohort, scatter back advanced
                cohort_ef = jax.tree.map(lambda a: a[idx], state.ef_accum)
                agg_delta, cohort_ef = engine.server_delta(
                    fed, params, cohort_params, agg_w, agg_g, key=akey,
                    ef_accum=cohort_ef)
                ef_accum = jax.tree.map(
                    lambda full, sub: full.at[idx].set(sub),
                    state.ef_accum, cohort_ef)
            else:
                agg_delta = engine.server_delta(fed, params, cohort_params,
                                                agg_w, agg_g, key=akey)
        else:
            client_params, local_losses = jax.vmap(
                lambda cb: _local_steps(model, params, cb, lr, E))(client_batch)
            util_ema = engine.utility_update(fed, state.util_ema,
                                             local_losses, server_loss)
            if ctf is not None:
                # before the delta statistic, matching the engine: a
                # realistic attacker influences grad_sim scores with the
                # very delta it submits
                client_params = ctf(client_params, params, jnp.arange(C))

            delta_cos = None
            if strategy.needs_deltas:
                deltas = jax.tree.map(lambda ck, g: ck - g[None],
                                      client_params, params)
                if fed.grad_sim_sketch:
                    skey = engine.sketch_key(fed, round_idx)
                    sketches = jax.vmap(lambda d: engine.delta_sketch(
                        d, skey, int(fed.sketch_dim)))(deltas)
                    delta_cos = engine.cosine_to_priority(sketches, w, pm)
                else:
                    delta_cos = engine.cosine_to_priority(
                        flatten_stacked(deltas), w, pm)

            sel_gates = gates = engine.compute_gates(
                _gate_ctx(fed, state, util_ema, local_losses, server_loss,
                          pm, w, delta_cos, round_idx=round_idx,
                          participation=part),
                fed.selection)
            if lost is not None:
                gates = gates * (1.0 - lost.astype(jnp.float32))
            agg_w, agg_g = w, gates
            if ef_on:
                agg_delta, ef_accum = engine.server_delta(
                    fed, params, client_params, agg_w, agg_g, key=akey,
                    ef_accum=state.ef_accum)
            else:
                agg_delta = engine.server_delta(fed, params, client_params,
                                                agg_w, agg_g, key=akey)
        finite = engine.aggregate_finite(fed, agg_delta, server_loss)
        push_timer = (engine.slot_timer(fed, state.latency, gates)
                      if clock_on and fed.async_depth > 0 else None)
        new_params, opt_state, inflight, last_delta, applied = _apply_delta(
            fed, state, params, agg_delta,
            mass=inclusion_mass(fed, agg_w, agg_g),
            push_timer=push_timer, finite=finite)
        new_state = _next_state(fed, state, new_params, opt_state,
                                sel_gates, gates, util_ema, inflight=inflight,
                                last_delta=last_delta,
                                nonfinite_skips=engine.skips_update(state,
                                                                    finite),
                                ef_accum=ef_accum)
        stats = _async_stats(fed, {
            "server_loss": server_loss,
            "local_losses": local_losses,
            "gates": gates,
            "backlog": new_state.backlog,
            "theta_round": 1.0 / (1.0 + jnp.sum((1 - pm.astype(jnp.float32)) * w * gates)),
        }, applied, inflight)
        stats = _failure_stats(fed, stats, lost, new_state.nonfinite_skips)
        return new_state, stats

    return _pool_wrap(fed, round_step)


def make_temporal_round(model, fed, cohort: int):
    """FSDP variant: scan over a client cohort; accumulate gated updates.

    batch['clients'] leaves are [C, b, ...] with C the SCAN axis (unsharded);
    the inner batch dim b is sharded over (pod, data).

    Delta-based strategies (grad_sim) stream too: a first scan trains each
    client and keeps only a [sketch_dim] CountSketch of its delta
    (``engine.delta_sketch`` — the projection, never the [C, M_total]
    deltas, crosses the scan), cosines against the priority-weighted mean
    sketch fix the gates, and a second cond-skipped scan re-runs the
    deterministic local steps of the included clients to accumulate their
    gated updates. Cost: one extra pass of E local steps for included
    clients — the price of scoring without materializing per-client deltas.

    **Robust/private aggregators gather the client axis.** The streaming
    weighted-sum carry above only exists for the (linear) gated mean;
    coordinate-wise trimmed_mean/median are order statistics ACROSS
    clients, dp clips on whole-delta norms, and cosine_filter compares
    client directions — none decompose into a running sum. With
    ``fed.aggregator != "mean"`` the scan therefore stacks every client's
    trained params as its ys output — a deliberate resharding that
    materializes [C, ...] leaves (asserted below), the one place the
    temporal round pays spatial-round memory — and routes them through
    ``engine.server_delta`` (the same fused fedagg call as the spatial
    round, so the two pod modes stay bit-comparable per aggregator).
    """
    E = fed.local_epochs
    lr = fed.lr
    validate_config(fed)
    codec_on = (resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
                != "identity")
    ef_on = codec_on and bool(fed.error_feedback)
    # a non-identity wire codec also forces the gather: it encodes per-
    # client ROWS of the fused [C, M_total] buffer (row max-abs scales,
    # row top-k, row sketches), which the streamed (num, den) mean carry
    # never materializes — the codec path IS the fused fedagg seam
    robust_gather = resolve_aggregator(fed.aggregator) != "mean" or codec_on
    agg_needs_key = get_aggregator(fed.aggregator).needs_key
    strategy = engine.get_strategy(fed.selection)
    failure_on = engine.resolve_failure_model(fed.failure_model) != "none"
    clock_on = fed.latency_mode != "none"
    if (engine.resolve_failure_model(fed.failure_model) in ("corrupt", "chaos")
            and fed.corrupt_rate > 0):
        raise ValueError(
            f"failure model {fed.failure_model!r} with corrupt_rate="
            f"{fed.corrupt_rate} poisons trained params in transit, but the "
            "temporal (FSDP) round streams clients through a scan carry and "
            "has no per-client materialization to corrupt on the linear "
            "path — use the spatial round for corruption faults, or set "
            "corrupt_rate=0 (crash/drop-out faults stream fine)")
    if strategy.needs_deltas and not fed.grad_sim_sketch:
        raise ValueError(
            f"selection {fed.selection!r} needs client deltas; the temporal "
            "(FSDP) round streams clients and can only score them on a "
            "CountSketch of their delta — set FedConfig.grad_sim_sketch=True "
            "(and size sketch_dim) to opt in to the JL-approximate statistic "
            "(the spatial round then sketches too, keeping the modes "
            "identical), or use the spatial round for exact cosines")

    def round_step(state, batch, round_idx=0, client_ids=None):
        params = state.params
        pm = batch["priority_mask"]
        w = batch["weights"]
        C = pm.shape[0]
        server_loss, _ = model.loss_fn(params, batch["server"])
        ef_accum = state.ef_accum

        # fault injection (corruption excluded above): availability masks
        # selection, crashes/deadline-late clients lose their mass
        # post-train; client_ids keys pooled draws on the global identity
        plan = (engine.failure_plan(fed, round_idx, C, client_ids=client_ids)
                if failure_on else None)
        part = (plan.available if plan is not None
                and plan.available is not None else None)
        lost = engine.lost_mask(fed, state, plan)

        # eval pre-pass: F_k(w_t) for the whole cohort before any gate is
        # fixed (rank-based strategies need the full loss vector)
        local_losses = jax.lax.map(
            lambda cb: model.loss_fn(params, cb)[0], batch["clients"])
        util_ema = engine.utility_update(fed, state.util_ema,
                                         local_losses, server_loss)

        delta_cos = None
        if strategy.needs_deltas:
            # pass 1: train each streamed client, keep only its delta sketch
            skey = engine.sketch_key(fed, round_idx)
            dim = int(fed.sketch_dim)

            def sketch_client(carry, cbatch):
                p_k = _train_steps(model, params, cbatch, lr, E)
                return carry, engine.delta_sketch(tree_sub(p_k, params),
                                                  skey, dim)

            _, sketches = jax.lax.scan(sketch_client, 0, batch["clients"])
            delta_cos = engine.cosine_to_priority(sketches, w, pm)

        sel_gates = gates = engine.compute_gates(
            _gate_ctx(fed, state, util_ema, local_losses, server_loss, pm, w,
                      delta_cos, round_idx=round_idx, participation=part),
            fed.selection)
        if lost is not None:
            # a lost streamed client's delta never reaches the carry, so it
            # may as well skip its E local steps (gate 0 cond-skips); its
            # SELECTION gate stays for the backlog re-enqueue
            gates = gates * (1.0 - lost.astype(jnp.float32))

        if robust_gather:
            # robust/private aggregators need every client's delta at once
            # (order statistics / whole-delta norms / direction cosines):
            # stack the trained params as scan ys — the documented [C, ...]
            # resharding — and reduce through THE fused fedagg seam.
            def per_client_stack(carry, inp):
                cbatch, gate = inp
                p_k = jax.lax.cond(
                    gate > 0,
                    lambda b: _train_steps(model, params, b, lr, E),
                    lambda b: params, cbatch)
                return carry, p_k

            _, stacked = jax.lax.scan(per_client_stack, 0,
                                      (batch["clients"], gates))
            C = w.shape[0]
            for s, p in zip(jax.tree.leaves(stacked), jax.tree.leaves(params)):
                assert s.shape == (C,) + p.shape, (
                    "temporal robust aggregation must gather the client axis: "
                    f"expected {(C,) + p.shape}, got {s.shape}")
            akey = aggregator_key(fed, round_idx) if agg_needs_key else None
            if ef_on:
                agg_delta, ef_accum = engine.server_delta(
                    fed, params, stacked, w, gates, key=akey,
                    ef_accum=state.ef_accum)
            else:
                agg_delta = engine.server_delta(fed, params, stacked, w,
                                                gates, key=akey)
            mass = inclusion_mass(fed, w, gates)
        else:
            def per_client(carry, inp):
                acc_num, acc_den = carry
                cbatch, w_k, gate = inp
                # gates are fixed before the scan, so gated-out streamed
                # clients skip their E local steps entirely (cond, not
                # select: scan bodies are traced once and branch at run time)
                p_k = jax.lax.cond(
                    gate > 0,
                    lambda b: _train_steps(model, params, b, lr, E),
                    lambda b: params, cbatch)
                wg = w_k * gate
                acc_num = jax.tree.map(
                    lambda a, pk: a + wg * pk.astype(jnp.float32), acc_num, p_k)
                return (acc_num, acc_den + wg), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (num, den), _ = jax.lax.scan(
                per_client, (zeros, jnp.float32(0)),
                (batch["clients"], w, gates))
            # streamed aggregation accumulates f32 in the carry; the
            # aggregated DELTA then feeds the same ServerOptimizer step as
            # the fused path (or the in-flight buffer, when the round runs
            # overlapped cohorts). A zero-mass round yields an EXACT zero
            # delta (num/1e-30 - params would be -params, wiping the model).
            mass = den
            agg_delta = jax.tree.map(
                lambda n, p: jnp.where(
                    den > 0,
                    n / jnp.maximum(den, 1e-30) - p.astype(jnp.float32), 0.0),
                num, params)
        finite = engine.aggregate_finite(fed, agg_delta, server_loss)
        push_timer = (engine.slot_timer(fed, state.latency, gates)
                      if clock_on and fed.async_depth > 0 else None)
        new_params, opt_state, inflight, last_delta, applied = _apply_delta(
            fed, state, params, agg_delta, mass=mass,
            push_timer=push_timer, finite=finite)
        new_state = _next_state(fed, state, new_params, opt_state,
                                sel_gates, gates, util_ema, inflight=inflight,
                                last_delta=last_delta,
                                nonfinite_skips=engine.skips_update(state,
                                                                    finite),
                                ef_accum=ef_accum)
        stats = _async_stats(fed, {
            "server_loss": server_loss,
            "local_losses": local_losses,
            "gates": gates,
            "backlog": new_state.backlog,
            "theta_round": 1.0 / (1.0 + jnp.sum((1 - pm.astype(jnp.float32)) * w * gates)),
        }, applied, inflight)
        stats = _failure_stats(fed, stats, lost, new_state.nonfinite_skips)
        return new_state, stats

    return _pool_wrap(fed, round_step)


def make_round_step(model, fed, num_clients: int, *, fsdp: bool):
    return (make_temporal_round(model, fed, num_clients) if fsdp
            else make_spatial_round(model, fed, num_clients))


def capture_round_program(model, fed, num_clients: int, batch, *,
                          fsdp: bool = False, round_idx: int = 0):
    """Package the pod round-step for static analysis without executing
    (or even materializing) anything:

        step, args, meta = sharded.capture_round_program(model, fed, C, batch)
        report = repro.analysis.lint_program(step, args, fed, meta=meta)

    ``batch`` may be real arrays or ShapeDtypeStructs (dryrun-style); the
    FederationState is built abstractly via ``jax.eval_shape``. ``meta``
    carries the wire width and ``pod=True`` so the collective-budget rule
    holds the round to its single-all-reduce promise (mean path) or the
    documented client-axis-gather allowance (order statistics / coded
    wires)."""
    from repro.utils import param_count
    step = make_round_step(model, fed, num_clients, fsdp=fsdp)
    state = jax.eval_shape(lambda: engine.init_state(
        model.init(jax.random.PRNGKey(0)), fed, num_clients))
    meta = {"m_total": param_count(state.params),
            "num_clients": num_clients, "rounds": 1, "pod": True}

    def fn(state, batch):
        return step(state, batch, round_idx=round_idx)

    return fn, (state, batch), meta


# ----------------------------------------------------------------- serving
def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model):
    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)
    return serve_step
