"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential) — arXiv:2405.04517.

TPU adaptation: the mLSTM training path uses a *chunkwise* formulation
(intra-chunk [c,c] parallel attention-like matrices + inter-chunk recurrent
[hd,hd] state carried through a lax.scan) rather than the O(S^2) fully
parallel form — the same memory-hierarchy reasoning as flash attention.
Exponential gating is stabilized with a running log-max ``m`` exactly as in
the paper (App. formulas); forget gate uses log-sigmoid.

sLSTM has a true sequential dependency (recurrent R-matrix through h_{t-1})
and cannot be parallelized over time; it is a lax.scan over steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.utils import fold_in_name

NEG_INF = -1e30


# ===================================================================== mLSTM
def init_mlstm(key, cfg):
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    K = cfg.ssm_conv_dim
    ks = {n: fold_in_name(key, n) for n in ("up", "q", "k", "v", "if", "down", "conv")}
    return {
        "w_up": dense_init(ks["up"], (d, 2 * di), cfg.pdtype),
        "conv_w": dense_init(ks["conv"], (K, di), cfg.pdtype, scale=K ** -0.5),
        "conv_b": jnp.zeros((di,), cfg.pdtype),
        "wq": dense_init(ks["q"], (di, di), cfg.pdtype),
        "wk": dense_init(ks["k"], (di, di), cfg.pdtype),
        "wv": dense_init(ks["v"], (di, di), cfg.pdtype),
        "w_if": dense_init(ks["if"], (di, 2 * H), jnp.float32),
        "b_if": jnp.zeros((2 * H,), jnp.float32),
        "gn_scale": jnp.ones((di,), cfg.pdtype),
        "w_down": dense_init(ks["down"], (di, d), cfg.pdtype),
    }


def _mlstm_qkv_gates(p, xi, cfg):
    """xi: [B,S,di] -> q,k,v [B,S,H,hd], li,lf [B,S,H] (log gates, fp32)."""
    from repro.models.ssm import _causal_conv
    B, S, di = xi.shape
    H = cfg.num_heads
    hd = di // H
    cd = cfg.cdtype
    xc = jax.nn.silu(_causal_conv(xi, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
                                  cfg.ssm_conv_dim))
    q = (xc @ p["wq"].astype(cd)).reshape(B, S, H, hd)
    k = (xc @ p["wk"].astype(cd)).reshape(B, S, H, hd) * hd ** -0.5
    v = (xi @ p["wv"].astype(cd)).reshape(B, S, H, hd)
    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]             # [B,S,2H]
    li, f_raw = gates[..., :H], gates[..., H:]
    lf = jax.nn.log_sigmoid(f_raw)
    return q, k, v, li, lf


def _group_norm(h, scale, H):
    """Per-head normalization of h: [B,S,H,hd] -> [B,S,H*hd]."""
    B, S, Hh, hd = h.shape
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.var(hf, axis=-1, keepdims=True)
    y = (hf - mu) * jax.lax.rsqrt(var + 1e-6)
    return (y.reshape(B, S, Hh * hd) * scale.astype(jnp.float32)).astype(h.dtype)


def mlstm_chunked(q, k, v, li, lf, state=None, chunk=256):
    """Chunkwise stabilized mLSTM.

    q/k/v: [B,S,H,hd]; li/lf: [B,S,H].
    state: (Ct [B,H,hd,hd], nt [B,H,hd], mt [B,H]) or None.
    Returns (h [B,S,H,hd], state').
    """
    B, S, H, hd = q.shape
    S0 = S
    chunk = min(chunk, S)
    if S % chunk:
        # pad with identity steps: li=-inf (no input), lf=0 (no decay)
        pad = chunk - S % chunk
        padt = lambda x, val=0.0: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
                                          constant_values=val)
        q, k, v = padt(q), padt(k), padt(v)
        li, lf = padt(li, NEG_INF), padt(lf, 0.0)
        S += pad
    nch = S // chunk

    def resh(x, extra):
        return x.reshape((B, nch, chunk) + extra).transpose((1, 0) + tuple(range(2, x.ndim + 1)))

    qc = resh(q.astype(jnp.float32), (H, hd))     # [nch,B,c,H,hd]
    kc = resh(k.astype(jnp.float32), (H, hd))
    vc = resh(v.astype(jnp.float32), (H, hd))
    lic = resh(li, (H,))                          # [nch,B,c,H]
    lfc = resh(lf, (H,))

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))                     # s<=t

    def body(carry, inp):
        Cp, np_, mp = carry
        qb, kb, vb, lib, lfb = inp
        F = jnp.cumsum(lfb, axis=1)                                    # [B,c,H] inclusive
        # in-chunk log weights: w[t,s] = F_t - F_s + li_s  (s<=t)
        logw = (F[:, :, None] - F[:, None, :] + lib[:, None, :])       # [B,t,s,H]
        logw = jnp.where(tri[None, :, :, None], logw, NEG_INF)
        carry_log = F + mp[:, None]                                    # [B,c,H]
        m_t = jnp.maximum(jnp.max(logw, axis=2), carry_log)            # [B,c,H]
        w_in = jnp.exp(logw - m_t[:, :, None])                         # [B,t,s,H]
        w_carry = jnp.exp(carry_log - m_t)                             # [B,c,H]

        qk = jnp.einsum("bthd,bshd->btsh", qb, kb)                     # [B,t,s,H]
        num_in = jnp.einsum("btsh,bshd->bthd", w_in * qk, vb)
        num_carry = jnp.einsum("bthd,bhde->bthe", qb, Cp) * w_carry[..., None]
        den_in = jnp.einsum("btsh,btsh->bth", w_in, qk)
        den_carry = jnp.einsum("bthd,bhd->bth", qb, np_) * w_carry
        num = num_in + num_carry
        den = den_in + den_carry
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # ---- state update to end of chunk -----------------------------------
        Fc = F[:, -1]                                                  # [B,H]
        src_log = Fc[:, None] - F + lib                                # [B,c,H]
        m_out = jnp.maximum(mp + Fc, jnp.max(src_log, axis=1))
        w_src = jnp.exp(src_log - m_out[:, None])                      # [B,c,H]
        w_old = jnp.exp(mp + Fc - m_out)                               # [B,H]
        C_new = (Cp * w_old[..., None, None]
                 + jnp.einsum("bsh,bshd,bshe->bhde", w_src, kb, vb))
        n_new = np_ * w_old[..., None] + jnp.einsum("bsh,bshd->bhd", w_src, kb)
        return (C_new, n_new, m_out), h

    (Cn, nn_, mn), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)[:, :S0]
    return h.astype(q.dtype), (Cn, nn_, mn)


def mlstm_step(q, k, v, li, lf, state):
    """Single decode step. q/k/v: [B,H,hd]; li/lf: [B,H]."""
    Cp, np_, mp = state
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    m_new = jnp.maximum(lf + mp, li)
    fw = jnp.exp(lf + mp - m_new)
    iw = jnp.exp(li - m_new)
    C = Cp * fw[..., None, None] + iw[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n = np_ * fw[..., None] + iw[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.einsum("bhd,bhd->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C, n, m_new)


def mlstm_block(p, x, cfg, *, mode, cache=None):
    """x: [B,S,d]. cache (decode): {'conv': [B,K-1,di], 'C','n','m'}."""
    B, S, d = x.shape
    di = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    hd = di // H
    K = cfg.ssm_conv_dim
    cd = cfg.cdtype
    u = x @ p["w_up"].astype(cd)
    xi, z = jnp.split(u, 2, axis=-1)

    if mode in ("train", "prefill"):
        q, k, v, li, lf = _mlstm_qkv_gates(p, xi, cfg)
        h, state = mlstm_chunked(q, k, v, li, lf, chunk=cfg.ssm_chunk)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": xi[:, S - (K - 1):].astype(cd),
                         "C": state[0], "n": state[1], "m": state[2]}
    else:
        window = jnp.concatenate([cache["conv"], xi], axis=1)          # [B,K,di]
        xc_ = jnp.einsum("bkd,kd->bd", window.astype(cd), p["conv_w"].astype(cd))
        xc_ = jax.nn.silu(xc_ + p["conv_b"].astype(cd))
        q = (xc_ @ p["wq"].astype(cd)).reshape(B, H, hd)
        k = (xc_ @ p["wk"].astype(cd)).reshape(B, H, hd) * hd ** -0.5
        v = (xi[:, 0] @ p["wv"].astype(cd)).reshape(B, H, hd)
        gates = xc_.astype(jnp.float32) @ p["w_if"] + p["b_if"]
        li, lf = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
        h, state = mlstm_step(q, k, v, li, lf, (cache["C"], cache["n"], cache["m"]))
        h = h[:, None]                                                  # [B,1,H,hd]
        new_cache = {"conv": window[:, 1:], "C": state[0], "n": state[1], "m": state[2]}

    y = _group_norm(h, p["gn_scale"], H) * jax.nn.silu(z)
    return y @ p["w_down"].astype(cd), new_cache


# ===================================================================== sLSTM
def init_slstm(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    f = cfg.slstm_proj_factor
    dff = int(f * d)
    ks = {n: fold_in_name(key, n) for n in ("w", "r", "conv", "up", "down")}
    return {
        "conv_w": dense_init(ks["conv"], (cfg.ssm_conv_dim, d), cfg.pdtype,
                             scale=cfg.ssm_conv_dim ** -0.5),
        "conv_b": jnp.zeros((d,), cfg.pdtype),
        "w_gates": dense_init(ks["w"], (d, 4 * d), jnp.float32),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "r_gates": dense_init(ks["r"], (H, hd, 4 * hd), jnp.float32, scale=hd ** -0.5),
        "gn_scale": jnp.ones((d,), cfg.pdtype),
        "w_up": dense_init(ks["up"], (d, 2 * dff), cfg.pdtype),
        "w_down": dense_init(ks["down"], (dff, d), cfg.pdtype),
    }


def _slstm_cell(p, gx, state, H, hd):
    """One sLSTM step. gx: [B,4d] input-side gate preactivations."""
    h, c, n, m = state                                                 # h,c,n: [B,d]; m: [B,d]
    B = h.shape[0]
    hr = h.reshape(B, H, hd)
    rec = jnp.einsum("bhd,hde->bhe", hr, p["r_gates"]).reshape(B, 4 * H * hd)
    g = gx + rec
    d = H * hd
    li_raw, f_raw, z_raw, o_raw = jnp.split(g, 4, axis=-1)
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m, li_raw)
    i_ = jnp.exp(li_raw - m_new)
    f_ = jnp.exp(lf + m - m_new)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_block(p, x, cfg, *, mode, cache=None):
    """x: [B,S,d]. cache (decode): {'conv', 'h','c','n','m'}."""
    from repro.models.ssm import _causal_conv
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    K = cfg.ssm_conv_dim
    cd = cfg.cdtype

    if mode in ("train", "prefill"):
        xc = jax.nn.silu(_causal_conv(x, p["conv_w"].astype(cd), p["conv_b"].astype(cd), K))
        gx = xc.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]      # [B,S,4d]

        def step(state, gxt):
            new = _slstm_cell(p, gxt, state, H, hd)
            return new, new[0]

        z0 = jnp.zeros((B, d), jnp.float32)
        state0 = (z0, z0, z0, jnp.full((B, d), NEG_INF, jnp.float32))
        state, hs = jax.lax.scan(step, state0, gx.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2)                                      # [B,S,d]
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": x[:, S - (K - 1):].astype(cd),
                         "h": state[0], "c": state[1], "n": state[2], "m": state[3]}
    else:
        window = jnp.concatenate([cache["conv"], x.astype(cd)], axis=1)
        xc_ = jnp.einsum("bkd,kd->bd", window.astype(cd), p["conv_w"].astype(cd))
        xc_ = jax.nn.silu(xc_ + p["conv_b"].astype(cd))
        gx = xc_.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
        state = _slstm_cell(p, gx, (cache["h"], cache["c"], cache["n"], cache["m"]), H, hd)
        h = state[0][:, None]
        new_cache = {"conv": window[:, 1:], "h": state[0], "c": state[1],
                     "n": state[2], "m": state[3]}

    h4 = h.reshape(B, -1, H, hd)
    y = _group_norm(h4, p["gn_scale"], H).astype(cd)
    u = y @ p["w_up"].astype(cd)
    a, b = jnp.split(u, 2, axis=-1)
    return (jax.nn.silu(a) * b) @ p["w_down"].astype(cd), new_cache
