from repro.data.synth import make_synth_federation  # noqa: F401
from repro.data.shards import make_benchmark_federation  # noqa: F401
from repro.data.tokens import make_token_federation  # noqa: F401
