"""Production mesh builders (TPU v5e pods; 256 chips/pod).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py forces
512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16). Two pods: (pod=2, data=16, model=16).

    data carries FedALIGN clients (+FSDP for the largest archs); model is
    tensor/expert parallel; pod is additional client parallelism across the
    DCN/ICI boundary.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
