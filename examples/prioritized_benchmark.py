"""Paper Figure-1 style comparison on the FMNIST stand-in: FedALIGN vs
FedAvg(priority-only) vs FedAvg(all), with an ASCII accuracy plot.

    PYTHONPATH=src python examples/prioritized_benchmark.py [--rounds 60]
"""
import argparse

import jax

from repro.configs.base import FedConfig
from repro.data.shards import make_benchmark_federation
from repro.fl.simulator import run_federation
from repro.models.small import SMALL_MODELS, make_loss_fn


def ascii_plot(curves: dict, width=64, height=14):
    lo = min(min(c) for c in curves.values())
    hi = max(max(c) for c in curves.values())
    rows = [[" "] * width for _ in range(height)]
    marks = {}
    for mark, (name, c) in zip("*+o", curves.items()):
        marks[mark] = name
        n = len(c)
        for i, v in enumerate(c):
            x = int(i / max(n - 1, 1) * (width - 1))
            y = height - 1 - int((v - lo) / max(hi - lo, 1e-9) * (height - 1))
            rows[y][x] = mark
    print(f"  acc  {hi:.3f}")
    for r in rows:
        print("       |" + "".join(r))
    print(f"  acc  {lo:.3f}  (x: rounds)   " +
          "  ".join(f"{m}={n}" for m, n in marks.items()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    args = ap.parse_args()

    fedn = make_benchmark_federation("fmnist", seed=0, n_priority=2)
    init_fn, apply_fn = SMALL_MODELS["logreg"]
    loss_fn = make_loss_fn(apply_fn)

    curves = {}
    for sel in ("fedalign", "priority_only", "all"):
        fed = FedConfig(num_clients=60, num_priority=2, rounds=args.rounds,
                        local_epochs=5, epsilon=0.2, lr=0.1, warmup_frac=0.1,
                        selection=sel)
        hist = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                              fedn, eval_every=2)
        curves[sel] = hist.test_acc
        print(f"{sel:15s} final={hist.test_acc[-1]:.4f} "
              f"best={max(hist.test_acc):.4f}")
    print()
    ascii_plot(curves)


if __name__ == "__main__":
    main()
