from repro.fl.simulator import (evaluate, load_federation_state,  # noqa: F401
                                run_federation, run_local_baseline,
                                save_federation_state)
from repro.fl.engine import (BACKENDS, STRATEGIES, FederationState,  # noqa: F401
                             SelectionContext, compute_gates, get_strategy,
                             init_state, make_round_fn, register_strategy)
