"""Paper Figure 1: FedALIGN vs FedAvg(priority) vs FedAvg(all) on the three
benchmark-dataset stand-ins (uniclass shards, N=60, |P|=2, E=5, eps=0.2,
10% warm-up). Offline container => class-prototype synthetic stand-ins with
matching shard statistics (DESIGN.md §6)."""
from __future__ import annotations

from benchmarks.common import fed_suite
from repro.data.shards import make_benchmark_federation

DATASET_MODEL = {"fmnist": "logreg", "emnist": "mlp2", "cifar": "cnn"}


def run(fast=True, datasets=("fmnist", "emnist", "cifar"), seeds=(0,)):
    rows = []
    rounds = 20 if fast else 200
    for ds in datasets:
        n_pri = 2
        # fast mode (single CPU core): fewer clients for the heavy models
        clients = None
        if fast and ds == "cifar":
            clients, rounds_ds = 4, 3      # CNN on 1 CPU core: keep it tiny
        elif fast and ds == "emnist":
            clients, rounds_ds = 10, 10
        else:
            rounds_ds = rounds
        fedn = make_benchmark_federation(ds, seed=0, n_priority=n_pri,
                                         clients=clients,
                                         samples_per_client=(100 if ds == 'cifar' else 150) if fast else None)
        out = fed_suite(fedn, DATASET_MODEL[ds],
                        dict(num_clients=fedn.x.shape[0], num_priority=n_pri,
                             rounds=rounds_ds, local_epochs=5, epsilon=0.2,
                             lr=0.1 if ds != "cifar" else 0.01,
                             warmup_frac=0.1, batch_size=32),
                        seeds=seeds)
        for r in out:
            r["dataset"] = ds
        rows += out
    return rows


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "acc_curve"})
