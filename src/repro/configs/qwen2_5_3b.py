"""qwen2.5-3b [dense] — GQA (kv=2), QKV bias. [hf:Qwen/Qwen2.5-0.5B family]

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen2.5-0.5B (family card)",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
