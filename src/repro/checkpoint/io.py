"""Msgpack pytree checkpointing.

Arrays are gathered to host (works for sharded arrays via
``jax.device_get``), serialized with shape/dtype headers, and restored to
the exact pytree structure. Sufficient for single-controller runs; a real
multi-host deployment would write per-shard files keyed by device — the
layout here keeps that extension local to this module.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        arr = np.asarray(obj)
        return {b"__nd__": True, b"dtype": arr.dtype.str, b"shape": list(arr.shape),
                b"data": arr.tobytes()}
    raise TypeError(type(obj))


def _decode(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"])
                             ).reshape(obj[b"shape"]).copy()
    return obj


def save_pytree(path: str, tree: Any, step: int | None = None) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    payload = {"treedef": str(treedef), "step": step,
               "leaves": host_leaves}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, default=_encode))
    os.replace(tmp, path)           # atomic


def load_pytree(path: str, like: Any):
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode, strict_map_key=False)
    leaves, treedef = jax.tree.flatten(like)
    new_leaves = payload["leaves"]
    assert len(new_leaves) == len(leaves), (len(new_leaves), len(leaves))
    out = []
    for old, new in zip(leaves, new_leaves):
        assert tuple(new.shape) == tuple(old.shape), (new.shape, old.shape)
        out.append(jnp.asarray(new, dtype=old.dtype))
    return jax.tree.unflatten(treedef, out), payload.get("step")
