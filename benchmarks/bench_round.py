"""Round-pipeline benchmark: dense train-everyone vs gate-before-train
cohort execution (``FedConfig.max_cohort``), the server-optimizer
ablation (sgd vs momentum/adam/yogi on the aggregated delta), the
FederationState threading overhead of the scanned driver, and the
``scan_async`` overlapped-cohort backend — fifo fixed-lag pipe vs the
FedBuff-style variable-lag ``ready`` buffer at depths {1, 2, 4}
(rounds/sec vs the synchronous round, plus the convergence price of
staleness as rounds-to-target-loss, including the drift-adaptive
discount's rescue of the oscillating decay-0.9 depth-2 pipe), the
aggregator ablation (mean vs trimmed_mean/median/dp/cosine_filter
rounds/sec — the robust variants are fused into the same fedagg kernel
launch and must stay within 10% of the mean), and the Byzantine attack
rows (label-flip and x(-100) scaled-delta attackers at 10%/25% of the
population: at 25% scaled-delta the robust aggregators reach the
priority-loss target that the plain mean, NaN-divergent, misses).

Times full engine rounds at C=64 clients on a small MLP across inclusion
rates, reporting rounds/sec and the wasted-local-epoch fraction (clients
that paid E local epochs but were dropped at aggregation). The ``pool:*``
rows sweep the POPULATION size over a log axis (C = 1e3..1e5) at a fixed
``candidate_pool`` and assert the pooled round time stays flat (< 1.3x)
while the dense contrast rows scale ~linearly, plus a pool-vs-dense
rounds-to-target pair at C=256 pricing the sampling. Every gated
row also reports ``bytes_per_round`` — the analytic uplink cost of its
client rows under the configured wire codec — and the ``codec:*`` /
``codec_frontier:*`` rows sweep the WireCodec registry (identity / int8 /
topk / sketch, error feedback on): the frontier rows pin bytes/round
against rounds-to-target-loss and assert that int8+EF buys ~4x uplink
compression (exact analytic: 4M/(M+4), the per-client f32 scales) at
<=1% rounds-to-target regression vs the identity wire. Every timing
pair is also a correctness pair: the cohort round must reproduce the dense
round exactly before its timing row is emitted, and the async backend at
``async_depth=0`` must be BIT-identical to ``vmap_spatial`` before any
async row is emitted. EVERY wall-clock comparison — the state-threading
<5% overhead assertion included — is timed inside the ONE pooled
interleaved median-of-reps session (``_timed_rows``); no row compares
clocks taken minutes apart.

    PYTHONPATH=src python benchmarks/bench_round.py [--full|--quick] [--out PATH]

emits ``BENCH_round.json`` (uploaded as the BENCH_round CI artifact and
diffed against the committed baseline by ``scripts/check_bench.py`` —
>15% rounds/sec regression in any row fails CI). ``--quick`` runs the
trimmed smoke subset registered as ``round_pipeline_quick`` in
``benchmarks/run.py``.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import init_mlp2, make_loss_fn, mlp2_apply

CLIENTS = 64
N_PRIORITY = 2
SCAN_ROUNDS = 8  # rounds per scanned program in the server-opt/threading rows
ASYNC_SCAN_ROUNDS = 32  # async rows scan longer: their cohort rounds are
# ~40ms, and the CI gate needs >1s dispatches to sit well inside its 15%
# tolerance
ASYNC_DEPTHS = (1, 2, 4)  # fifo-vs-ready sweep points


def _time_interleaved(thunks, reps=9):
    """Per-thunk MEDIAN-of-``reps`` wall time, measured ROUND-ROBIN.

    Every row that feeds the 15% CI regression gate is timed here.
    Interleaving the programs (a,b,c,a,b,c,... instead of aaa,bbb,ccc)
    turns a transient load spike into common-mode noise shared by every
    row — which the gate's median normalization cancels — instead of
    sinking whichever single row happened to be on the clock. The median
    (not the min) absorbs what interleaving can't: a min is hostage to one
    lucky-fast window, and a baseline that commits such an outlier fails
    every honest fresh run thereafter. Nine reps (not five): on shared CI
    boxes a single row's median still swung ~20% across runs at five reps
    — a couple of slow dispatches land on one thunk — and the gate's 15%
    tolerance needs the per-row median stable to well under that."""
    for t in thunks:
        jax.block_until_ready(t())  # compile + warm-up
    samples = [[] for _ in thunks]
    for _ in range(reps):
        for i, t in enumerate(thunks):
            t0 = time.perf_counter()
            jax.block_until_ready(t())
            samples[i].append(time.perf_counter() - t0)
    return [float(np.median(s)) for s in samples]


def _setup(samples):
    fedn = make_synth_federation(
        seed=0,
        n_priority=N_PRIORITY,
        n_nonpriority=CLIENTS - N_PRIORITY,
        samples_per_client=samples,
    )
    data = {"x": jnp.asarray(fedn.x), "y": jnp.asarray(fedn.y)}
    pm = jnp.asarray(fedn.priority_mask)
    w = jnp.asarray(fedn.weights)
    loss_fn = make_loss_fn(mlp2_apply)
    params = init_mlp2(jax.random.PRNGKey(42), in_dim=60, hidden=256, num_classes=10)
    return data, pm, w, loss_fn, params


def _m_total(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def _wire_row_fields(fed, params, uplink_rows):
    """``bytes_per_round`` (+ the codec identity fields on non-identity
    rows — absent fields keep pre-codec baselines matching in the gate)."""
    from repro.core.aggregation import resolve_wire_codec, wire_bytes_per_round
    d = {"bytes_per_round": int(wire_bytes_per_round(
        fed, uplink_rows, _m_total(params)))}
    wc = resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
    if wc != "identity":
        d["wire_codec"] = wc
        d["error_feedback"] = bool(fed.error_feedback)
        if wc == "topk":
            d["codec_topk_frac"] = fed.codec_topk_frac
        if wc == "sketch":
            d["codec_sketch_dim"] = fed.codec_sketch_dim
    return d


def _timed_rows(jobs, reps=9):
    """Fill each job's row with its timing metrics from ONE interleaved
    session covering EVERY gated row — jobs from different suites must be
    pooled here before timing, so between-run drift of the whole session
    is common mode across all rows (which the CI gate's median
    normalization cancels) instead of group-local drift it cannot see.
    jobs: [(row, thunk, rounds_per_dispatch)]."""
    times = _time_interleaved([t for _, t, _ in jobs], reps=reps)
    for (row, _, n), sec_total in zip(jobs, times):
        sec = sec_total / n
        row["sec_per_round"] = round(sec, 5)
        row["rounds_per_sec"] = round(1.0 / sec, 2)


def _build_cohort(fast=True, rates=(0.25, 0.5, 1.0)):
    """Dense vs gathered-cohort rows. Returns (rows, jobs, posts): parity
    is asserted here, timing fields are filled by ``_timed_rows``, and the
    posts compute speedup_vs_dense once the clocks are in."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)

    rows, jobs, posts = [], [], []
    for rate in rates:
        k = round(CLIENTS * rate)
        # topk_align with a huge eps band pins inclusion to exactly k
        # (priority + the k - P best-matched non-priority clients)
        base = FedConfig(
            num_clients=CLIENTS,
            num_priority=N_PRIORITY,
            rounds=100,
            local_epochs=5,
            epsilon=1e9,
            warmup_frac=0.0,
            align_stat="loss",
            selection="topk_align",
            topk=k - N_PRIORITY,
            batch_size=32,
            seed=0,
        )
        state = engine.init_state(params, base, CLIENTS)
        dense_fn = jax.jit(engine.make_round_fn(loss_fn, base))
        cohort_fn = jax.jit(engine.make_round_fn(loss_fn, base.replace(max_cohort=k)))
        args = (state, data, pm, w, jax.random.PRNGKey(0), jnp.int32(1))
        std, sd = dense_fn(*args)
        stc, sc = cohort_fn(*args)

        # correctness before timing is reported: identical gates + params
        np.testing.assert_array_equal(np.asarray(sd["gates"]), np.asarray(sc["gates"]))
        for a, b in zip(jax.tree.leaves(std.params), jax.tree.leaves(stc.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

        included = float(np.asarray(sd["gates"]).sum())
        pair = []
        for path, fn, trained in (("dense", dense_fn, CLIENTS), ("cohort", cohort_fn, k)):
            row = {
                "path": path,
                "clients": CLIENTS,
                "max_cohort": 0 if path == "dense" else k,
                "target_inclusion_rate": rate,
                "measured_inclusion_rate": round(included / CLIENTS, 4),
                "clients_trained": trained,
                "wasted_local_epoch_frac": round((trained - included) / trained, 4),
            }
            row.update(_wire_row_fields(base, params, trained))
            rows.append(row)
            pair.append(row)
            jobs.append((row, lambda fn=fn, args=args: fn(*args), 1))

        def post(pair=pair):
            for row in pair:
                row["speedup_vs_dense"] = round(pair[0]["sec_per_round"] / row["sec_per_round"], 2)

        posts.append(post)
    return rows, jobs, posts


def run_cohort(fast=True, rates=(0.25, 0.5, 1.0)):
    return _run_builders([lambda: _build_cohort(fast=fast, rates=rates)])


def _make_round_scan(round_fn, data, pm, w, n=SCAN_ROUNDS):
    """One jitted program of ``n`` state-threaded rounds — the scanned-
    driver shape EVERY multi-round timing row measures (server-opt
    ablation, threading overhead, async throughput), so a change to the
    timing protocol lands everywhere at once."""

    @jax.jit
    def scan_state(state, rng):
        def body(carry, i):
            st, key = carry
            key, rkey = jax.random.split(key)
            st, _ = round_fn(st, data, pm, w, rkey, i)
            return (st, key), None

        (state, rng), _ = jax.lax.scan(body, (state, rng), jnp.arange(n, dtype=jnp.int32))
        return state

    return scan_state


def _build_server_opt(fast=True):
    """Server-optimizer ablation (max_cohort off, dense rounds) + the
    FederationState threading-overhead assertion.

    The overhead baseline runs the SAME round math inside the same
    lax.scan, but only the params cross the round boundary (opt moments /
    backlog / EMAs are re-fed from the initial state every round), so the
    delta between the two programs is exactly the cost of threading the
    full state through the scan carry. BOTH programs are timed as gated
    rows inside the pooled interleaved session — never as a private
    back-to-back pair minutes away from the other clocks — and the <5%
    assertion re-measures once before failing, so a transient load spike
    on a shared CI box cannot masquerade as overhead."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)
    base = FedConfig(
        num_clients=CLIENTS,
        num_priority=N_PRIORITY,
        rounds=100,
        local_epochs=2,
        epsilon=1e9,
        warmup_frac=0.0,
        align_stat="loss",
        batch_size=32,
        seed=0,
        max_cohort=0,
    )

    rows, jobs = [], []
    sgd_round_fn = sgd_state0 = None
    opt_rows = {}
    for opt in ("sgd", "momentum", "adam", "yogi"):
        fed = base.replace(server_opt=opt, server_lr=1.0)
        round_fn = engine.make_round_fn(loss_fn, fed)
        state0 = engine.init_state(params, fed, CLIENTS)
        if opt == "sgd":
            sgd_round_fn, sgd_state0 = round_fn, state0
        scan = _make_round_scan(round_fn, data, pm, w)
        row = {
            "path": f"server_opt:{opt}",
            "clients": CLIENTS,
            "max_cohort": 0,
            "scan_rounds": SCAN_ROUNDS,
        }
        row.update(_wire_row_fields(fed, params, CLIENTS))
        rows.append(row)
        opt_rows[opt] = row
        jobs.append((row, lambda f=scan, s=state0: f(s, jax.random.PRNGKey(0)), SCAN_ROUNDS))

    def post_opt():
        sgd_sec = opt_rows["sgd"]["sec_per_round"]
        for row in opt_rows.values():
            row["slowdown_vs_sgd"] = round(row["sec_per_round"] / sgd_sec, 3)

    # --- state-threading overhead: full FederationState carry vs params-only.
    round_fn, state0 = sgd_round_fn, sgd_state0
    scan_full_state = _make_round_scan(round_fn, data, pm, w)

    @jax.jit
    def scan_params_only(p, rng):
        def body(carry, i):
            pp, key = carry
            key, rkey = jax.random.split(key)
            st, _ = round_fn(state0.replace(params=pp), data, pm, w, rkey, i)
            return (st.params, key), None

        (p, rng), _ = jax.lax.scan(body, (p, rng), jnp.arange(SCAN_ROUNDS, dtype=jnp.int32))
        return p

    thunk_full = lambda: scan_full_state(state0, jax.random.PRNGKey(0))
    thunk_params = lambda: scan_params_only(params, jax.random.PRNGKey(0))
    pair = []
    thread_rows = (
        ("state_thread:full_state", thunk_full),
        ("state_thread:params_only", thunk_params),
    )
    for path, thunk in thread_rows:
        row = {
            "path": path,
            "clients": CLIENTS,
            "max_cohort": 0,
            "scan_rounds": SCAN_ROUNDS,
        }
        row.update(_wire_row_fields(base, params, CLIENTS))
        rows.append(row)
        pair.append(row)
        jobs.append((row, thunk, SCAN_ROUNDS))

    summary = {
        "path": "state_threading_overhead",
        "clients": CLIENTS,
        "max_cohort": 0,
        "scan_rounds": SCAN_ROUNDS,
    }
    rows.append(summary)

    def post_overhead():
        sec_full = pair[0]["sec_per_round"]
        sec_params = pair[1]["sec_per_round"]
        overhead = sec_full / sec_params - 1.0
        if overhead >= 0.05:
            # one retry, re-measured back-to-back, before failing: the
            # pooled session absorbs drift but not a spike that landed on
            # exactly one of the two programs. The re-measured times also
            # REPLACE the pair's gated metrics — otherwise the emitted
            # rows keep the spiked clock the summary just disowned, and
            # committing that run as the baseline embeds the spike
            sec_full, sec_params = _time_interleaved([thunk_full, thunk_params])
            sec_full /= SCAN_ROUNDS
            sec_params /= SCAN_ROUNDS
            overhead = sec_full / sec_params - 1.0
            for row, sec in zip(pair, (sec_full, sec_params)):
                row["sec_per_round"] = round(sec, 5)
                row["rounds_per_sec"] = round(1.0 / sec, 2)
        summary["sec_per_round_full_state"] = round(sec_full, 5)
        summary["sec_per_round_params_only"] = round(sec_params, 5)
        summary["overhead_frac"] = round(overhead, 4)
        assert overhead < 0.05, (
            f"FederationState threading added {overhead:.1%} to the scanned "
            f"round (budget: <5% at max_cohort off)"
        )

    return rows, jobs, [post_opt, post_overhead]


def run_server_opt(fast=True):
    return _run_builders([lambda: _build_server_opt(fast=fast)])


def _async_base(**kw):
    # cohort-gathered rounds at 25% inclusion — the regime where overlapped
    # cohorts matter (free clients gate in and out round to round)
    k = CLIENTS // 4
    d = dict(
        num_clients=CLIENTS,
        num_priority=N_PRIORITY,
        rounds=100,
        local_epochs=2,
        epsilon=1e9,
        warmup_frac=0.0,
        align_stat="loss",
        selection="topk_align",
        topk=k - N_PRIORITY,
        max_cohort=k,
        batch_size=32,
        seed=0,
    )
    d.update(kw)
    return FedConfig(**d)


def _async_fed(mode, depth, decay=0.5, **kw):
    # ready mode runs min_lag=1: fast cohorts land one round late (the
    # variable-lag win), with depth as spare capacity for stragglers
    return _async_base(**kw).replace(
        backend="scan_async",
        async_depth=depth,
        async_mode=mode,
        min_lag=1,
        staleness_decay=decay if depth else 1.0,
    )


def _build_async(fast=True, depths=ASYNC_DEPTHS, convergence=True):
    """scan_async vs vmap_spatial: per-round throughput of the fifo pipe vs
    the variable-lag ``ready`` buffer at each depth (the readiness pop and
    buffer compaction are the only extra work per round — the rows pin
    that they stay cheap), plus rounds-to-target-loss (how many extra
    rounds staleness costs on the synth federation, and how the
    drift-adaptive discount rescues the oscillating decay-0.9 depth-2
    pipe).

    The depth-0 async round is asserted BIT-identical to the synchronous
    round before any timing row is emitted. Throughput is measured on a
    SCANNED program of ASYNC_SCAN_ROUNDS rounds (median-of-reps,
    interleaved with every other gated row) — single cohort rounds here
    are ~40ms, far too noisy for the 15% CI regression gate."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)
    base = _async_base()

    sync_fn = engine.make_round_fn(loss_fn, base, backend="vmap_spatial")
    state = engine.init_state(params, base, CLIENTS)
    args = (state, data, pm, w, jax.random.PRNGKey(0), jnp.int32(1))
    st_sync, t_sync = jax.jit(sync_fn)(*args)

    # correctness before timing: depth 0 IS the synchronous round
    fed0 = base.replace(backend="scan_async", async_depth=0)
    afn0 = engine.make_round_fn(loss_fn, fed0)
    st_a, t_a = jax.jit(afn0)(engine.init_state(params, fed0, CLIENTS), *args[1:])
    np.testing.assert_array_equal(np.asarray(t_sync["gates"]), np.asarray(t_a["gates"]))
    for a, b in zip(jax.tree.leaves(st_sync.params), jax.tree.leaves(st_a.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    variants = [
        ("async:sync", base, None),
        ("async:depth0", fed0, 0),
    ]
    for depth in depths:
        for mode in ("fifo", "ready"):
            fed = _async_fed(mode, depth)
            variants.append((f"async:{mode}:depth{depth}", fed, depth))

    prebuilt = {"async:sync": sync_fn, "async:depth0": afn0}
    rows, jobs, timed = [], [], []
    for label, fed, depth in variants:
        fn = prebuilt.get(label) or engine.make_round_fn(loss_fn, fed)
        s = engine.init_state(params, fed, CLIENTS)
        scan = _make_round_scan(fn, data, pm, w, n=ASYNC_SCAN_ROUNDS)
        row = {
            "path": label,
            "clients": CLIENTS,
            "max_cohort": base.max_cohort,
            "async_depth": depth,
            "scan_rounds": ASYNC_SCAN_ROUNDS,
        }
        if depth:
            row["async_mode"] = fed.async_mode
            if fed.async_mode == "ready":
                row["min_lag"] = fed.min_lag
        row.update(_wire_row_fields(fed, params, base.max_cohort))
        rows.append(row)
        timed.append(row)
        jobs.append((row, lambda f=scan, s=s: f(s, jax.random.PRNGKey(0)), ASYNC_SCAN_ROUNDS))

    def post():
        sec_sync = timed[0]["sec_per_round"]
        for row in timed:
            row["async_speedup_vs_sync"] = round(sec_sync / row["sec_per_round"], 3)

    if not convergence:
        return rows, jobs, [post]

    # --- rounds-to-target-loss: the convergence price of staleness.
    # Each run scans R rounds inside one jitted program; the target is the
    # synchronous run's final pre-round loss plus 5% headroom. The
    # decay-0.9 depth-2 fifo pipe is the ROADMAP's oscillation case; the
    # adaptive row shows the drift-measured discount damping it.
    R = 16 if fast else 40
    conv = [("sync", _async_base(local_epochs=1), None)]
    for depth in depths:
        for mode in ("fifo", "ready"):
            conv.append(
                (
                    f"{mode}:depth{depth}",
                    _async_fed(mode, depth, local_epochs=1),
                    depth,
                )
            )
    conv.append(("fifo:depth2:decay0.9", _async_fed("fifo", 2, decay=0.9, local_epochs=1), 2))
    conv.append(
        (
            "adaptive:depth2:decay0.9",
            _async_fed("fifo", 2, decay=0.9, local_epochs=1).replace(adaptive_staleness=True),
            2,
        )
    )

    losses = {}
    for label, fed, depth in conv:
        rf = engine.make_round_fn(loss_fn, fed)
        state0 = engine.init_state(params, fed, CLIENTS)

        @jax.jit
        def scan_losses(state, rng, rf=rf):
            def body(carry, i):
                st, key = carry
                key, rkey = jax.random.split(key)
                st, stats = rf(st, data, pm, w, rkey, i)
                return (st, key), stats["global_loss"]

            (state, rng), gl = jax.lax.scan(body, (state, rng), jnp.arange(R, dtype=jnp.int32))
            return gl

        losses[label] = (np.asarray(scan_losses(state0, jax.random.PRNGKey(0))), fed, depth)

    target = float(losses["sync"][0][-1]) * 1.05
    for label, (gl, fed, depth) in losses.items():
        hit = np.nonzero(gl <= target)[0]
        row = {
            "path": f"async_rounds_to_target:{label}",
            "clients": CLIENTS,
            "async_depth": depth,
            "scan_rounds": R,
            "target_loss": round(target, 5),
            "final_loss": round(float(gl[-1]), 5),
            "rounds_to_target": int(hit[0]) if hit.size else None,
        }
        if depth:
            row["async_mode"] = fed.async_mode
            row["staleness_decay"] = fed.staleness_decay
            row["adaptive_staleness"] = fed.adaptive_staleness
            if fed.async_mode == "ready":
                row["min_lag"] = fed.min_lag
        rows.append(row)
    return rows, jobs, [post]


def run_async(fast=True, depths=ASYNC_DEPTHS):
    return _run_builders([lambda: _build_async(fast=fast, depths=depths)])


# ---------------------------------------------------------------- aggregators
AGG_KNOBS = dict(trim_frac=0.3, dp_clip=1.0, dp_noise=0.0, outlier_cos=0.0,
                 sketch_dim=512)
AGGREGATOR_NAMES = ("mean", "trimmed_mean", "median", "dp", "cosine_filter")


def _agg_base(fast=True, **kw):
    d = dict(num_clients=CLIENTS, num_priority=N_PRIORITY, rounds=100,
             epsilon=1e9, warmup_frac=0.0, align_stat="loss", selection="all",
             batch_size=32, seed=0, max_cohort=0, **AGG_KNOBS)
    d.update(kw)
    return FedConfig(**d)


AGG_SCAN_ROUNDS = 4  # aggregator rounds are ~1s (local_epochs=18); 4 per
# dispatch keeps the pooled session's total time bounded while each
# dispatch still sits well inside the CI gate's tolerance


def _build_aggregators(fast=True):
    """Aggregator-ablation timing rows: full dense rounds under each
    registered aggregator. ``local_epochs=18`` keeps the round
    training-dominated — the regime the <=10% budget is stated for (a
    production round trains for seconds-to-minutes; aggregation is
    milliseconds): the robust reductions add a coordinate-wise
    compare/exchange sort (or a clip/noise pass) over [C, M_total] but
    zero extra training work, so rounds/sec must stay within 10% of the
    plain mean. The assertion re-measures once (same retry protocol as
    the threading-overhead pin) before failing."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)

    rows, jobs, agg_rows, thunks = [], [], {}, {}
    for name in AGGREGATOR_NAMES:
        fed = _agg_base(fast=fast, local_epochs=18, aggregator=name)
        round_fn = engine.make_round_fn(loss_fn, fed)
        state0 = engine.init_state(params, fed, CLIENTS)
        scan = _make_round_scan(round_fn, data, pm, w, n=AGG_SCAN_ROUNDS)
        row = {
            "path": f"aggregator:{name}",
            "aggregator": name,
            "clients": CLIENTS,
            "max_cohort": 0,
            "scan_rounds": AGG_SCAN_ROUNDS,
        }
        row.update(_wire_row_fields(fed, params, CLIENTS))
        rows.append(row)
        agg_rows[name] = row
        thunk = lambda f=scan, s=state0: f(s, jax.random.PRNGKey(0))
        thunks[name] = thunk
        jobs.append((row, thunk, AGG_SCAN_ROUNDS))

    def post():
        def fill(times=None):
            if times is not None:
                for name, sec_total in zip(AGGREGATOR_NAMES, times):
                    sec = sec_total / AGG_SCAN_ROUNDS
                    agg_rows[name]["sec_per_round"] = round(sec, 5)
                    agg_rows[name]["rounds_per_sec"] = round(1.0 / sec, 2)
            sec_mean = agg_rows["mean"]["sec_per_round"]
            worst = 0.0
            for row in agg_rows.values():
                slow = row["sec_per_round"] / sec_mean - 1.0
                row["slowdown_vs_mean"] = round(slow + 1.0, 3)
                worst = max(worst, slow)
            return worst

        worst = fill()
        if worst >= 0.10:
            # one re-measure (replacing the gated metrics) before failing:
            # the pooled session absorbs drift, not a spike on one thunk
            worst = fill(_time_interleaved([thunks[n] for n in AGGREGATOR_NAMES]))
        assert worst < 0.10, (
            f"a robust/private aggregator costs {worst:.1%} rounds/sec over "
            "the plain mean (budget: <10% on training-dominated rounds)")

    return rows, jobs, [post]


def run_aggregators(fast=True):
    return _run_builders([lambda: _build_aggregators(fast=fast)])


# --------------------------------------------------------------- wire codecs
# sketch runs error_feedback=False: the CountSketch hash/sign planes are
# run-constant (wire_sketch_streams — every client and round shares them),
# so re-encoding the EF residual amplifies it by the bucket occupancy
# M/dim each round (encode(decode(s)) = occupancy * s) — a geometric
# blow-up the finite-residual guard freezes but cannot undo. The biased
# no-EF sketch is the stable operating point; per-round re-randomized
# hashes (the Sketched-SGD fix) would break the run-constant stream
# contract the backend-identity tests pin.
CODEC_VARIANTS = (
    ("identity", {}),
    ("int8", {}),
    ("topk", dict(codec_topk_frac=0.05)),
    ("sketch", dict(codec_sketch_dim=1024, error_feedback=False)),
)


def _build_codec(fast=True):
    """Wire-codec frontier: analytic uplink bytes/round against
    rounds-to-target-loss for every registered codec (error feedback on),
    plus gated ``codec:*`` throughput rows — the decode runs fused inside
    the one fedagg launch, so a codec round must not fall off the
    rounds/sec cliff a materialized [C, M_total] f32 decode buffer would
    cause.

    The in-bench frontier assertion is the PR's headline: int8+EF reaches
    the identity wire's target loss with <=1% extra rounds while paying
    ~4x fewer uplink bytes. "~4x": the exact analytic is 4M/(M+4) — the
    one f32 scale per client row keeps it strictly below 4.0 (3.9991 at
    this bench's M=18186, 4.0000 at production M) — so the floor asserted
    here is 3.9, far above the 2.0 a payload-dtype regression (int8 ->
    f16) would produce."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)
    R = 16 if fast else 40
    from repro.core.aggregation import wire_bytes_per_round

    rows, jobs, feds, frontier = [], [], {}, {}
    losses = {}
    for name, kw in CODEC_VARIANTS:
        fed = _agg_base(fast=fast, local_epochs=1, wire_codec=name, **kw)
        feds[name] = fed
        rf = engine.make_round_fn(loss_fn, fed)
        state0 = engine.init_state(params, fed, CLIENTS)

        @jax.jit
        def scan_losses(state, rng, rf=rf):
            def body(carry, i):
                st, key = carry
                key, rkey = jax.random.split(key)
                st, stats = rf(st, data, pm, w, rkey, i)
                return (st, key), stats["global_loss"]

            (state, rng), gl = jax.lax.scan(body, (state, rng),
                                            jnp.arange(R, dtype=jnp.int32))
            return gl

        losses[name] = np.asarray(scan_losses(state0, jax.random.PRNGKey(0)))

        row = {
            "path": f"codec:{name}",
            "clients": CLIENTS,
            "max_cohort": 0,
            "scan_rounds": SCAN_ROUNDS,
        }
        row.update(_wire_row_fields(fed, params, CLIENTS))
        rows.append(row)
        scan = _make_round_scan(rf, data, pm, w)
        jobs.append((row, lambda f=scan, s=state0: f(s, jax.random.PRNGKey(0)),
                     SCAN_ROUNDS))

    id_bytes = int(wire_bytes_per_round(feds["identity"], CLIENTS,
                                        _m_total(params)))
    target = float(losses["identity"][-1]) * 1.05
    for name, _ in CODEC_VARIANTS:
        gl = losses[name]
        hit = np.nonzero(gl <= target)[0]
        row = {
            "path": f"codec_frontier:{name}",
            "clients": CLIENTS,
            "scan_rounds": R,
            "target_loss": round(target, 5),
            "final_loss": round(float(gl[-1]), 5),
            "rounds_to_target": int(hit[0]) if hit.size else None,
        }
        row.update(_wire_row_fields(feds[name], params, CLIENTS))
        row["compression_vs_identity"] = round(
            id_bytes / row["bytes_per_round"], 4)
        frontier[name] = row
        rows.append(row)

    def post():
        r_id = frontier["identity"]["rounds_to_target"]
        r_i8 = frontier["int8"]["rounds_to_target"]
        comp = frontier["int8"]["compression_vs_identity"]
        assert r_id is not None, (
            "identity wire never reached its own +5% target — the codec "
            "frontier rows have no baseline to compare against")
        assert comp >= 3.9, (
            f"int8 uplink compression is {comp:.4f}x — the analytic "
            "4M/(M+4) bound says ~4x; below 3.9 the wire payload widened")
        assert r_i8 is not None and r_i8 <= int(np.ceil(r_id * 1.01)), (
            f"int8+EF took {r_i8} rounds to the identity wire's target vs "
            f"{r_id} for identity — over the <=1% regression budget")

    return rows, jobs, [post]


def run_codec(fast=True):
    return _run_builders([lambda: _build_codec(fast=fast)])


# ------------------------------------------------------------------ byzantine
def _attack_mask(frac):
    n_att = round(CLIENTS * frac)
    m = np.zeros(CLIENTS, bool)
    m[-n_att:] = True                       # non-priority tail clients
    return jnp.asarray(m)


def _scaled_delta_transform(mask, factor=-100.0):
    """Model-replacement boosting (sign-flipped x100 delta) on the masked
    clients — injected through ``make_round_fn(delta_transform=...)``, the
    seam an attacker's poisoned update enters the round at."""
    def tf(client_params, global_params, idx):
        m = mask[idx]

        def leaf(cp, gp):
            mm = m.reshape(m.shape + (1,) * (cp.ndim - 1))
            return jnp.where(mm, gp[None] + factor * (cp - gp[None]), cp)

        return jax.tree.map(leaf, client_params, global_params)
    return tf


def _build_byzantine(fast=True, fracs=(0.1, 0.25)):
    """Convergence under Byzantine clients: label-flip (data poisoning) and
    scaled-delta (x(-100) model-replacement boosting) attackers at 10%/25%
    of the population, under every registered aggregator, with
    ``selection="all"`` modeling the gate-slip regime (attackers pass the
    alignment gate). Rows report the priority loss after R rounds against
    the clean-mean target (x1.05 headroom); no rounds/sec, so the CI
    regression gate skips them.

    Asserted before any row is emitted: at 25% scaled-delta attackers,
    trimmed_mean / median / cosine_filter each reach the target that mean
    (NaN-divergent under the boosted deltas) misses."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)
    R = 20 if fast else 40

    def scan_losses(fed, d, transform=None):
        rf = engine.make_round_fn(loss_fn, fed, delta_transform=transform)
        state0 = engine.init_state(params, fed, CLIENTS)

        @jax.jit
        def scan(state, rng):
            def body(carry, i):
                st, key = carry
                key, rkey = jax.random.split(key)
                st, stats = rf(st, d, pm, w, rkey, i)
                return (st, key), stats["global_loss"]

            (_, _), gl = jax.lax.scan(body, (state, rng),
                                      jnp.arange(R, dtype=jnp.int32))
            return gl

        return np.asarray(scan(state0, jax.random.PRNGKey(0)))

    clean = scan_losses(_agg_base(fast=fast, local_epochs=1), data)
    # x1.15, not the async rows' x1.05: the robust reductions are
    # UNWEIGHTED order statistics over non-IID clients — a different
    # estimator that trails the weighted mean's loss by ~5% at any round
    # count (raising R moves the clean target down just as fast), so the
    # tighter band made the median assert a coin flip. 15% headroom gives
    # trimmed/median/cosine ~13-23% margin while mean still departs to
    # NaN — the contrast the rows exist to pin.
    target = float(clean[-1]) * 1.15

    rows = [{
        "path": "byzantine:clean:mean",
        "aggregator": "mean",
        "clients": CLIENTS,
        "attack": "none",
        "attack_frac": 0.0,
        "scan_rounds": R,
        "target_loss": round(target, 5),
        "final_priority_loss": round(float(clean[-1]), 5),
        "defended": True,
    }]
    hit = {}
    for frac in fracs:
        mask = _attack_mask(frac)
        flipped = dict(data)
        y = np.asarray(data["y"]).copy()
        y[np.asarray(mask)] = 9 - y[np.asarray(mask)]     # synth labels 0..9
        flipped["y"] = jnp.asarray(y)
        for attack in ("scaled_delta", "label_flip"):
            for name in AGGREGATOR_NAMES:
                fed = _agg_base(fast=fast, local_epochs=1, aggregator=name)
                if attack == "scaled_delta":
                    gl = scan_losses(fed, data,
                                     transform=_scaled_delta_transform(mask))
                else:
                    gl = scan_losses(fed, flipped)
                final = float(gl[-1])
                defended = bool(np.isfinite(final) and final <= target)
                hit[(attack, frac, name)] = defended
                rows.append({
                    "path": f"byzantine:{attack}:frac{frac}:{name}",
                    "aggregator": name,
                    "clients": CLIENTS,
                    "attack": attack,
                    "attack_frac": frac,
                    "scan_rounds": R,
                    "target_loss": round(target, 5),
                    "final_priority_loss": (round(final, 5)
                                            if np.isfinite(final) else None),
                    "defended": defended,
                })

    # the headline robustness claim, pinned before the rows are emitted
    assert not hit[("scaled_delta", 0.25, "mean")], (
        "plain mean unexpectedly survived 25% scaled-delta attackers — the "
        "attack rows no longer demonstrate anything")
    for name in ("trimmed_mean", "median", "cosine_filter"):
        assert hit[("scaled_delta", 0.25, name)], (
            f"{name} failed to reach the priority-loss target under 25% "
            "scaled-delta attackers")
    return rows, [], []


def run_byzantine(fast=True):
    return _run_builders([lambda: _build_byzantine(fast=fast)])


# ---------------------------------------------------------------------- chaos
def _chaos_fed(crash=0.0, deadline=float("inf"), **kw):
    """Event-clocked ready-mode pipeline with Bernoulli crash faults: the
    PR's 'actual asynchronous-FL simulator' configuration — per-client
    lognormal completion times drive per-slot countdown timers, crashed
    clients lose their delta post-train and re-enqueue via the backlog."""
    return _async_fed("ready", 4, decay=0.8, local_epochs=1, **kw).replace(
        latency_mode="lognormal", round_deadline=deadline,
        failure_model="crash", crash_rate=crash)


def _build_chaos(fast=True, crash_rates=(0.0, 0.1, 0.25)):
    """Failure-model rows (convergence/distribution only — no rounds/sec,
    so the CI regression gate skips them; the gate DOES pin that the rows
    keep existing).

    ``chaos:staleness:*`` — the measured staleness DISTRIBUTION of the
    event-clocked ready buffer vs crash rate: with per-slot countdown
    timers, staleness is the simulated cohort completion time (lognormal
    draws), not a fixed pipeline depth, and crashes thin the landed
    cohorts without shifting the clock.

    ``chaos:rounds_to_target:*`` — the convergence price of crash faults
    at 10%/25%, with vs without a finite round_deadline: the deadline
    force-lands slow cohorts with only their finished members' mass
    (graceful degradation), trading per-round mass for bounded latency;
    lost clients re-enqueue through the backlog and win ties on return."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)
    R = 24 if fast else 48

    def scan_stats(fed):
        rf = engine.make_round_fn(loss_fn, fed)
        state0 = engine.init_state(params, fed, CLIENTS)

        @jax.jit
        def scan(state, rng):
            def body(carry, i):
                st, key = carry
                key, rkey = jax.random.split(key)
                st, stats = rf(st, data, pm, w, rkey, i)
                return (st, key), (stats["global_loss"], stats["staleness"],
                                   stats["applied_valid"],
                                   stats["lost_clients"])

            (_, _), out = jax.lax.scan(body, (state, rng),
                                       jnp.arange(R, dtype=jnp.int32))
            return out

        gl, stale, valid, lost = (np.asarray(a)
                                  for a in scan(state0, jax.random.PRNGKey(0)))
        return gl, stale, valid, lost

    def row_base(fed, path):
        row = {
            "path": path,
            "clients": CLIENTS,
            "scan_rounds": R,
            "async_depth": fed.async_depth,
            "async_mode": fed.async_mode,
            "min_lag": fed.min_lag,
            "latency_mode": fed.latency_mode,
            "failure_model": fed.failure_model,
            "crash_rate": fed.crash_rate,
        }
        if fed.round_deadline != float("inf"):
            row["round_deadline"] = fed.round_deadline
        return row

    rows = []
    # --- staleness distribution vs crash rate (same clock, thinner cohorts)
    results = {}
    for crash in crash_rates:
        fed = _chaos_fed(crash=crash)
        gl, stale, valid, lost = scan_stats(fed)
        results[crash] = (gl, lost)
        landed = stale[valid > 0]
        assert np.isfinite(gl[-1]), (
            f"chaos staleness run (crash={crash}) lost convergence entirely")
        row = row_base(fed, f"chaos:staleness:crash{crash:g}")
        row.update(
            applied_rounds=int((valid > 0).sum()),
            staleness_mean=round(float(landed.mean()), 3) if landed.size else None,
            staleness_p50=float(np.percentile(landed, 50)) if landed.size else None,
            staleness_p90=float(np.percentile(landed, 90)) if landed.size else None,
            staleness_max=int(landed.max()) if landed.size else None,
            lost_clients_total=int(lost.sum()),
            final_loss=round(float(gl[-1]), 5),
        )
        rows.append(row)
    # the event clock's whole point: staleness is a DISTRIBUTION (the
    # lognormal draws spread cohort completion times), not a constant lag
    assert rows[0]["applied_rounds"] > 0 and rows[0]["staleness_max"] >= 1

    # --- rounds-to-target under crash, with vs without a deadline
    target = float(results[0.0][0][-1]) * 1.15
    for crash in [c for c in crash_rates if c > 0]:
        for label, deadline in (("nodeadline", float("inf")),
                                ("deadline", 2.0)):
            fed = _chaos_fed(crash=crash, deadline=deadline)
            gl, stale, valid, lost = scan_stats(fed)
            hit = np.nonzero(gl <= target)[0]
            row = row_base(
                fed, f"chaos:rounds_to_target:crash{crash:g}:{label}")
            row.update(
                target_loss=round(target, 5),
                final_loss=(round(float(gl[-1]), 5)
                            if np.isfinite(gl[-1]) else None),
                rounds_to_target=int(hit[0]) if hit.size else None,
                lost_clients_total=int(lost.sum()),
            )
            rows.append(row)
            assert np.isfinite(gl[-1]), (
                f"crash={crash} {label}: the guard-free chaos run must "
                "still end finite (crashes lose mass, they don't poison)")
    return rows, [], []


def run_chaos(fast=True):
    return _run_builders([lambda: _build_chaos(fast=fast)])


# ------------------------------------------------------------ candidate pool
POOL_P = 64                       # candidate pool size for the scaling rows
POOL_CLIENTS = (1_000, 10_000, 100_000)   # log axis; 1e5 is the memory
# bound of the host-resident [C, samples, 60] federation, not of the round
POOL_DENSE_CLIENTS = (256, 512, 1024)     # dense contrast: O(C) rounds


def _pool_data(C, samples=16, seed=0):
    """Direct synthetic federation — make_synth_federation materializes
    per-client mixtures client by client, too slow at C=1e5; the pool rows
    only need consistently-labeled rows of the right SHAPE."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((C, samples, 60), dtype=np.float32)
    w_true = rng.standard_normal((60, 10), dtype=np.float32)
    y = np.einsum("csd,dk->csk", x, w_true).argmax(-1).astype(np.int32)
    pm = np.zeros(C, bool)
    pm[:N_PRIORITY] = True
    w = np.full(C, 1.0 / C, np.float32)
    return ({"x": jnp.asarray(x), "y": jnp.asarray(y)},
            jnp.asarray(pm), jnp.asarray(w))


def _pool_scan(round_fn, n=SCAN_ROUNDS):
    """Like ``_make_round_scan`` but the federation enters as a traced
    ARGUMENT: the usual closure capture would embed the [C, samples, 60]
    client tensor as an XLA literal — a 384MB constant at C=1e5 that
    stalls compilation for minutes."""

    @jax.jit
    def scan_state(state, data, pm, w, rng):
        def body(carry, i):
            st, key = carry
            key, rkey = jax.random.split(key)
            st, _ = round_fn(st, data, pm, w, rkey, i)
            return (st, key), None

        (state, rng), _ = jax.lax.scan(body, (state, rng),
                                       jnp.arange(n, dtype=jnp.int32))
        return state

    return scan_state


def _build_pool(fast=True):
    """Candidate-pool population scaling (``FedConfig.candidate_pool``).

    ``pool:rounds_per_sec:C*`` sweeps the POPULATION size C over a log
    axis at a fixed pool P=64: every round samples P candidates
    (Gumbel top-k, priority pinned in-pool) and runs eval/gate/train/
    fedagg on the [P] slice only, so round time must stay FLAT in C —
    asserted < 1.3x from the smallest to the largest C, while the
    ``pool:dense:C*`` contrast rows scale ~linearly (asserted > 1.5x over
    a 4x client range). The per-round O(C) work that remains (the [C]
    Gumbel draw + top_k and the [C]-row state scatter) is exactly what
    the flatness assertion budgets.

    ``pool_rounds_to_target:*`` prices the sampling: at C=256, dense
    rounds train all 256 clients, pooled rounds 64/round — the row pair
    reports how many extra rounds the pool needs to the dense run's +5%
    target (priority clients are always in-pool, so the priority loss
    keeps stepping every round).

    Parity before timing: at C=256 the candidate_pool=0 and
    candidate_pool=C rounds are asserted BIT-identical to the dense
    round before any pool row is emitted."""
    loss_fn = make_loss_fn(mlp2_apply)
    params = init_mlp2(jax.random.PRNGKey(42), in_dim=60, hidden=256,
                      num_classes=10)

    def fed_for(C, pool, **kw):
        d = dict(num_clients=C, num_priority=N_PRIORITY, rounds=100,
                 local_epochs=5, epsilon=1e9, warmup_frac=0.0,
                 align_stat="loss", selection="all", batch_size=16, seed=0,
                 candidate_pool=pool)
        d.update(kw)
        return FedConfig(**d)

    # --- correctness before timing: disabled / >= C pools ARE the dense round
    data, pm, w = _pool_data(256)
    fed = fed_for(256, 0)
    args = (engine.init_state(params, fed, 256), data, pm, w,
            jax.random.PRNGKey(0), jnp.int32(1))
    sd, td = jax.jit(engine.make_round_fn(loss_fn, fed))(*args)
    sf, tf = jax.jit(engine.make_round_fn(loss_fn, fed_for(256, 256)))(*args)
    np.testing.assert_array_equal(np.asarray(td["gates"]),
                                  np.asarray(tf["gates"]))
    for a, b in zip(jax.tree.leaves(sd), jax.tree.leaves(sf)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    rows, jobs, pool_rows, dense_rows = [], [], [], []
    for C in POOL_CLIENTS:
        data, pm, w = _pool_data(C)
        fedp = fed_for(C, POOL_P)
        scan = _pool_scan(engine.make_round_fn(loss_fn, fedp))
        s0 = engine.init_state(params, fedp, C)
        row = {
            "path": f"pool:rounds_per_sec:C{C}",
            "clients": C,
            "candidate_pool": POOL_P,
            "max_cohort": 0,
            "scan_rounds": SCAN_ROUNDS,
        }
        row.update(_wire_row_fields(fedp, params, POOL_P))
        rows.append(row)
        pool_rows.append(row)
        jobs.append((row, lambda f=scan, s=s0, d=data, p=pm, ww=w:
                     f(s, d, p, ww, jax.random.PRNGKey(0)), SCAN_ROUNDS))

    for C in POOL_DENSE_CLIENTS:
        data, pm, w = _pool_data(C)
        fedd = fed_for(C, 0)
        scan = _pool_scan(engine.make_round_fn(loss_fn, fedd))
        s0 = engine.init_state(params, fedd, C)
        row = {
            "path": f"pool:dense:C{C}",
            "clients": C,
            "max_cohort": 0,
            "scan_rounds": SCAN_ROUNDS,
        }
        row.update(_wire_row_fields(fedd, params, C))
        rows.append(row)
        dense_rows.append(row)
        jobs.append((row, lambda f=scan, s=s0, d=data, p=pm, ww=w:
                     f(s, d, p, ww, jax.random.PRNGKey(0)), SCAN_ROUNDS))

    def post_flat():
        secs = [r["sec_per_round"] for r in pool_rows]
        for r in pool_rows:
            r["slowdown_vs_smallest_population"] = round(
                r["sec_per_round"] / secs[0], 3)
        ratio = max(secs) / min(secs)
        assert ratio < 1.3, (
            f"pooled round time varies {ratio:.2f}x across C in "
            f"{POOL_CLIENTS} — candidate_pool no longer decouples round "
            "cost from population size (budget: < 1.3x)")
        dsecs = [r["sec_per_round"] for r in dense_rows]
        for r in dense_rows:
            r["slowdown_vs_smallest_population"] = round(
                r["sec_per_round"] / dsecs[0], 3)
        assert dsecs[-1] / dsecs[0] > 1.5, (
            f"dense rounds only grew {dsecs[-1] / dsecs[0]:.2f}x over a "
            f"{POOL_DENSE_CLIENTS[-1] // POOL_DENSE_CLIENTS[0]}x client "
            "range — the contrast rows no longer demonstrate O(C) scaling")

    # --- the sampling price: pool-vs-dense rounds-to-target at C=256
    R = 16 if fast else 40
    data, pm, w = _pool_data(256)
    conv = {}
    for label, fed in (("dense", fed_for(256, 0, local_epochs=1)),
                       ("pool", fed_for(256, POOL_P, local_epochs=1))):
        rf = engine.make_round_fn(loss_fn, fed)
        s0 = engine.init_state(params, fed, 256)

        @jax.jit
        def scan_losses(state, rng, rf=rf):
            def body(carry, i):
                st, key = carry
                key, rkey = jax.random.split(key)
                st, stats = rf(st, data, pm, w, rkey, i)
                return (st, key), stats["global_loss"]

            (state, rng), gl = jax.lax.scan(body, (state, rng),
                                            jnp.arange(R, dtype=jnp.int32))
            return gl

        conv[label] = np.asarray(scan_losses(s0, jax.random.PRNGKey(0)))

    target = float(conv["dense"][-1]) * 1.05
    for label in ("dense", "pool"):
        gl = conv[label]
        hit = np.nonzero(gl <= target)[0]
        row = {
            "path": f"pool_rounds_to_target:{label}",
            "clients": 256,
            "scan_rounds": R,
            "target_loss": round(target, 5),
            "final_loss": round(float(gl[-1]), 5),
            "rounds_to_target": int(hit[0]) if hit.size else None,
        }
        if label == "pool":
            row["candidate_pool"] = POOL_P
        rows.append(row)
    assert np.isfinite(conv["pool"][-1]), (
        "the pooled C=256 run diverged — priority clients should keep the "
        "priority loss finite from inside every round's pool")

    return rows, jobs, [post_flat]


def run_pool(fast=True):
    return _run_builders([lambda: _build_pool(fast=fast)])


def _run_builders(builders):
    """Build every suite first, then time ALL gated rows in one interleaved
    session (see ``_timed_rows``), then fill the derived ratios."""
    rows, jobs, posts = [], [], []
    for build in builders:
        r, j, p = build()
        rows += r
        jobs += j
        posts += p
    _timed_rows(jobs)
    for post in posts:
        post()
    return rows


def run(fast=True):
    return _run_builders(
        [
            lambda: _build_cohort(fast=fast),
            lambda: _build_server_opt(fast=fast),
            lambda: _build_async(fast=fast),
            lambda: _build_aggregators(fast=fast),
            lambda: _build_codec(fast=fast),
            lambda: _build_byzantine(fast=fast),
            lambda: _build_chaos(fast=fast),
            lambda: _build_pool(fast=fast),
        ]
    )


def run_quick(fast=True):
    """Trimmed smoke subset for `benchmarks/run.py --only round_pipeline_quick`
    and `bench_round.py --quick`: one cohort rate + the depth-0 async parity
    row — seconds, not minutes, but still asserting both correctness pins."""
    return _run_builders(
        [
            lambda: _build_cohort(fast=fast, rates=(0.25,)),
            lambda: _build_async(fast=fast, depths=(), convergence=False),
        ]
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--quick", action="store_true", help="trimmed smoke subset (round_pipeline_quick)"
    )
    # --quick defaults to its own file: writing the smoke subset over the
    # committed full baseline would silently un-gate every vanished row
    ap.add_argument(
        "--out",
        default=None,
        help="output path (default BENCH_round.json, or BENCH_round.quick.json under --quick)",
    )
    args = ap.parse_args()
    out = args.out or ("BENCH_round.quick.json" if args.quick else "BENCH_round.json")
    rows = run_quick() if args.quick else run(fast=not args.full)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    for r in rows:
        print(r)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
