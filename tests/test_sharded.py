"""Pod-scale round-step semantics on the single host device: spatial and
temporal engines must agree with each other and train the model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import FedConfig
from repro.fl import sharded
from repro.launch.train import build_batches, run as train_run
from repro.data.tokens import make_token_federation
from repro.models import get_model

CFG = get_smoke("qwen1_5_0_5b").replace(remat=False)
MODEL = get_model(CFG)
FED = FedConfig(local_epochs=2, epsilon=1e9, lr=0.05)


def _batch(C=4, b=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    fd = make_token_federation(seed=seed, vocab=CFG.vocab_size, n_clients=C,
                               n_priority=2, seq_len=S,
                               tokens_per_client=(S + 1) * 8)
    return build_batches(CFG, fd, clients=C, per_client=b, seq=S, rng=rng)


def test_spatial_round_trains():
    step = jax.jit(sharded.make_spatial_round(MODEL, FED, 4))
    params = MODEL.init(jax.random.PRNGKey(0))
    batch = _batch()
    p1, s1 = step(params, batch)
    p2, s2 = step(p1, batch)
    assert float(s2["server_loss"]) < float(s1["server_loss"])
    assert np.all(np.asarray(s1["gates"]) == 1.0)      # eps = inf


def test_spatial_equals_temporal():
    """Same federation semantics whether clients are space- or
    time-multiplexed (weights equal => identical aggregation)."""
    batch = _batch()
    params = MODEL.init(jax.random.PRNGKey(0))
    ps, ss = jax.jit(sharded.make_spatial_round(MODEL, FED, 4))(params, batch)
    pt, st = jax.jit(sharded.make_temporal_round(MODEL, FED, 4))(params, batch)
    np.testing.assert_allclose(np.asarray(ss["local_losses"]),
                               np.asarray(st["local_losses"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


def test_gating_excludes_misaligned():
    fed = FedConfig(local_epochs=1, epsilon=0.05, lr=0.05)
    step = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))
    params = MODEL.init(jax.random.PRNGKey(0))
    batch = _batch()
    # corrupt the last client's labels to force misalignment after warm start
    bad = jax.random.randint(jax.random.PRNGKey(9),
                             batch["clients"]["labels"][3:].shape, 0,
                             CFG.vocab_size)
    batch["clients"]["labels"] = batch["clients"]["labels"].at[3:].set(bad)
    # train until losses separate; the corrupted client must eventually
    # fall outside the eps band while priority gates stay 1
    excluded = False
    for _ in range(10):
        params, stats = step(params, batch)
        gates = np.asarray(stats["gates"])
        assert gates[0] == 1.0 and gates[1] == 1.0      # priority always
        if gates[3] == 0.0:
            excluded = True
            break
    assert excluded, np.asarray(stats["local_losses"])


def test_round_idx_drives_eps_schedule():
    """The sharded rounds follow the eps schedule instead of freezing it at
    t=0: a decaying eps admits everyone early and gates non-priority
    clients out in late rounds — on BOTH execution modes."""
    fed = FedConfig(local_epochs=1, epsilon=0.5, lr=0.05,
                    epsilon_schedule="exp", epsilon_decay=0.9)
    batch = _batch()
    params = MODEL.init(jax.random.PRNGKey(0))
    for make in (sharded.make_spatial_round, sharded.make_temporal_round):
        step = jax.jit(make(MODEL, fed, 4))
        _, s0 = step(params, batch, jnp.int32(0))
        _, s9 = step(params, batch, jnp.int32(9))
        assert np.asarray(s0["gates"]).sum() == 4.0          # eps_0 = 0.5
        late = np.asarray(s9["gates"])                        # eps_9 ~ 2e-10
        assert np.all(late[:2] == 1.0)                        # priority kept
        assert late[2:].sum() == 0.0, late


def test_spatial_cohort_matches_dense_and_temporal():
    """Gather-train (max_cohort) spatial round and cond-skip temporal round
    both reproduce the dense spatial round, including when the eps schedule
    has gated clients out (cohort padding slots / skipped scan iterations)."""
    fed = FedConfig(local_epochs=2, epsilon=0.5, lr=0.05,
                    epsilon_schedule="exp", epsilon_decay=0.5)
    batch = _batch()
    params = MODEL.init(jax.random.PRNGKey(0))
    for r in (0, 6):
        pd, sd = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))(
            params, batch, jnp.int32(r))
        pc, sc = jax.jit(sharded.make_spatial_round(
            MODEL, fed.replace(max_cohort=4), 4))(params, batch, jnp.int32(r))
        pt, st = jax.jit(sharded.make_temporal_round(MODEL, fed, 4))(
            params, batch, jnp.int32(r))
        np.testing.assert_array_equal(np.asarray(sd["gates"]),
                                      np.asarray(sc["gates"]))
        np.testing.assert_array_equal(np.asarray(sd["gates"]),
                                      np.asarray(st["gates"]))
        for a, b in zip(jax.tree.leaves(pd), jax.tree.leaves(pc)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=5e-5)
        for a, b in zip(jax.tree.leaves(pd), jax.tree.leaves(pt)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=5e-5)


def test_spatial_cohort_overflow_keeps_best_matched():
    """K < #included: the spatial gather drops the worst loss-matched
    non-priority clients and reports the effective gates."""
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05, max_cohort=3)
    step = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))
    params = MODEL.init(jax.random.PRNGKey(0))
    _, stats = step(params, _batch())
    gates = np.asarray(stats["gates"])
    assert gates.sum() == 3.0
    assert np.all(gates[:2] == 1.0)                           # priority kept
    # the surviving non-priority client is the better loss-matched one
    losses = np.asarray(stats["local_losses"])
    server = float(stats["server_loss"])
    kept, dropped = (2, 3) if gates[2] == 1.0 else (3, 2)
    assert abs(losses[kept] - server) <= abs(losses[dropped] - server)


def test_train_driver_end_to_end():
    params, hist = train_run(arch="qwen1.5-0.5b", smoke=True, rounds=3,
                             clients=4, n_priority=2, per_client=2, seq=32,
                             verbose=False)
    assert hist[-1]["server_loss"] < hist[0]["server_loss"] + 0.5
