"""Serving path: prefill -> pad -> decode continuation matches teacher
forcing; generation is deterministic and in-vocab."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.serve import generate, pad_caches
from repro.models import get_model
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "minicpm3_4b", "xlstm_125m"])
def test_prefill_then_decode_matches_teacher_forced(arch):
    cfg = get_smoke(arch).replace(remat=False)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, S2 = 2, 12, 18
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S2), 0, cfg.vocab_size)
    hidden, _, _ = T.forward(params, toks, cfg, mode="train")
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ref_logits = hidden.astype(jnp.float32) @ w.astype(jnp.float32)

    caches, logits = model.prefill(params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits[:, S - 1]),
                               atol=5e-4, rtol=5e-3)
    caches = pad_caches(model, caches, B, S2)
    for t in range(S, S2):
        logits, caches = model.decode_step(params, caches, toks[:, t:t + 1],
                                           jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, t]),
                                   atol=5e-4, rtol=5e-3)


def test_windowed_prefill_ring_roll():
    """Prefill longer than the window: ring slots must line up with decode."""
    cfg = get_smoke("qwen1_5_0_5b").replace(remat=False, sliding_window=8)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, S2 = 1, 13, 17                 # prefill 13 > window 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S2), 0, cfg.vocab_size)
    hidden, _, _ = T.forward(params, toks, cfg, mode="train")
    ref_logits = hidden.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    caches, logits = model.prefill(params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits[:, S - 1]),
                               atol=5e-4, rtol=5e-3)
    for t in range(S, S2):
        logits, caches = model.decode_step(params, caches, toks[:, t:t + 1],
                                           jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, t]),
                                   atol=5e-4, rtol=5e-3)


def test_generate_shapes_and_determinism():
    cfg = get_smoke("qwen1_5_0_5b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab_size)
    out1 = generate(model, params, prompt, 6)
    out2 = generate(model, params, prompt, 6)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab_size


def test_whisper_prefill_decode():
    cfg = get_smoke("whisper_medium").replace(remat=False)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, S2 = 2, 6, 10
    frames = jax.random.normal(jax.random.PRNGKey(4), (B, cfg.num_frames, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S2), 0, cfg.vocab_size)
    from repro.models import encdec
    enc_out = encdec.encode(params, frames, cfg)
    hidden, _ = encdec.decode_forward(params, toks, enc_out, cfg, mode="train")
    ref_logits = (hidden.astype(jnp.float32)
                  @ params["embed"].T.astype(jnp.float32))
    caches, logits = model.prefill(params, {"tokens": toks[:, :S], "frames": frames})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits[:, S - 1]),
                               atol=5e-4, rtol=5e-3)
    caches = pad_caches(model, caches, B, S2)
    for t in range(S, S2):
        logits, caches = model.decode_step(params, caches, toks[:, t:t + 1],
                                           jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits[:, t]),
                                   atol=5e-4, rtol=5e-3)
