"""Model registry: maps a ModelConfig to its functional implementation."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.models import encdec, transformer


@dataclass(frozen=True)
class Model:
    """Functional model bundle; cfg is pre-bound into every fn."""
    init: Callable            # (key) -> params
    loss_fn: Callable         # (params, batch) -> (loss, metrics)
    prefill: Callable         # (params, batch) -> (caches, last_logits)
    decode_step: Callable     # (params, caches, tokens, pos) -> (logits, caches)
    make_cache: Callable      # (batch_size, cache_len) -> caches
    cfg: Any


def get_model(cfg) -> Model:
    mod = encdec if cfg.encdec else transformer
    return Model(
        init=lambda key: mod.init(key, cfg),
        loss_fn=lambda params, batch: mod.loss_fn(params, batch, cfg),
        prefill=lambda params, batch: mod.prefill(params, batch, cfg),
        decode_step=lambda params, caches, tokens, pos: mod.decode_step(
            params, caches, tokens, pos, cfg),
        make_cache=lambda batch_size, cache_len: mod.make_cache(cfg, batch_size, cache_len),
        cfg=cfg,
    )
