"""Flash-attention backward Pallas kernels (two-pass dq / dk+dv) vs
jax.grad of the naive oracle, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention_fwd_pallas,
                                           flash_attention_pallas)


@pytest.mark.parametrize("B,Sq,H,KV,hd,window", [
    (1, 128, 4, 4, 32, 0),      # MHA
    (2, 128, 8, 2, 32, 0),      # GQA
    (1, 128, 4, 1, 32, 0),      # MQA
    (1, 128, 4, 2, 32, 48),     # sliding window
])
def test_flash_backward_matches_autodiff(B, Sq, H, KV, hd, window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, KV, hd))

    def loss_pal(q, k, v):
        o = flash_attention_pallas(q, k, v, causal=True, window=window,
                                   block_q=64, block_kv=64, interpret=True)
        return jnp.sum(o ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True, window=window) ** 2)

    gp = jax.grad(loss_pal, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gp, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4, err_msg=name)


def test_fwd_lse_matches_logsumexp():
    """The saved LSE must equal log-sum-exp of the masked scaled scores."""
    key = jax.random.PRNGKey(1)
    B, S, H, hd = 1, 64, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    _, lse = flash_attention_fwd_pallas(q, k, v, causal=True, block_q=32,
                                        block_kv=32, interpret=True)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jax.nn.logsumexp(s, axis=-1)          # [B,H,S]
    got = lse.reshape(B, H, 1, S)[:, :, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
