"""FedALIGN's selection rule (the paper's core contribution, §3.1).

A non-priority client k is included in the aggregation of round t iff

    |F(w_t) - F_k(w_t)| < eps_t

evaluated at the *received* global model w_t: the client is only willing to
participate when the model is already good on its data
(F_k <= F + eps, the incentive side), and the server only accepts updates
whose loss matches the global loss (the alignment side).

Priority clients are always included (subject to participation sampling).
"""
from __future__ import annotations

import jax.numpy as jnp


def epsilon_at(fed, round_idx):
    """eps_t schedule. The paper's fine-tuning knob (§3.2): start permissive,
    optionally decay toward 0 to eliminate the rho_T bias in late rounds."""
    t = jnp.asarray(round_idx, jnp.float32)
    eps0 = jnp.float32(fed.epsilon)
    if fed.epsilon_schedule == "constant":
        return eps0
    if fed.epsilon_schedule == "exp":
        return eps0 * (1.0 - fed.epsilon_decay) ** t
    if fed.epsilon_schedule == "linear":
        return jnp.maximum(eps0 * (1.0 - fed.epsilon_decay * t), 0.0)
    if fed.epsilon_schedule == "step":
        # halve every 1/decay rounds
        k = jnp.floor(t * fed.epsilon_decay)
        return eps0 * 0.5 ** k
    raise ValueError(fed.epsilon_schedule)


def inclusion_gates(local_losses, global_loss, eps, priority_mask, *,
                    warmup=False, participation_mask=None, selection="fedalign",
                    topk=4, sim_threshold=0.0, delta_cos=None):
    """I_{k,t} per client. local_losses: [C] F_k(w_t); global_loss: scalar
    F(w_t); priority_mask: [C] bool.

    Back-compat wrapper over the SelectionStrategy registry in fl/engine.py
    (the single gating implementation). ``selection`` names any registered
    strategy: fedalign | all | priority_only | topk_align | grad_sim | ...
    This wrapper is STATELESS — strategies needing the cross-round
    FederationState EMAs (``welfare``) raise here; thread a state through
    ``engine.make_round_fn`` instead.
    """
    from repro.fl import engine
    ctx = engine.SelectionContext(
        align_vals=local_losses, global_align=global_loss, eps=eps,
        priority_mask=priority_mask, participation=participation_mask,
        warmup=warmup, delta_cos=delta_cos, topk=topk,
        sim_threshold=sim_threshold)
    return engine.compute_gates(ctx, selection)


def global_loss_from_locals(local_losses, priority_mask, weights):
    """F(w) = sum_{k in P} p_k F_k(w); weights normalized so priority mass = 1."""
    pri = priority_mask.astype(jnp.float32)
    num = jnp.sum(pri * weights * local_losses)
    den = jnp.maximum(jnp.sum(pri * weights), 1e-30)
    return num / den
