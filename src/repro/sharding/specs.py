"""Partition-spec assignment for parameters, batches and caches.

A name-rule + divisibility-fallback engine: leaf names carry layout intent
(column-parallel for input projections, row-parallel for output
projections, expert/tensor parallel for MoE); whenever the preferred dim is
not divisible by the mesh axis, the engine falls back to the largest
divisible dim, then to replication. This keeps every one of the 10
architectures lowering on the same (data, model) / (pod, data, model)
meshes without per-arch hand specs — per-arch overrides then become pure
performance knobs (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> preferred dim (negative = from the end) for the MODEL axis
_MODEL_DIM_RULES: list[tuple[str, int]] = [
    (r"^(wq|wk|wv|bq|bk|bv|wq_b|wkv_b|w_gate|w_up|b_up|w_in|w_gates|b_gates|"
     r"w_dtproj|lm_head|conv_w|conv_b)$", -1),
    (r"^(wo|w_out|w_xproj|w_if)$", 0),
    (r"^(w_down|b_down)$", 0),          # 2D [dff, d]; 3D handled below
    (r"^(embed|pos_dec|pos_enc)$", 0),  # vocab/position dim; fallback -> d
    (r"^(dt_bias|D|gn_scale)$", 0),
]

_REPLICATE = re.compile(r"^(scale|bias|w_router|A_log|r_gates|b_if|wq_a|wkv_a)$")

COLLECTIVE_AXES_DOC = """model axis: tensor parallel; data axis: client/DP
(+ FSDP for flagged archs); pod axis: extra client parallelism (params are
replicated across pods, gradients/updates cross pods only in the FedALIGN
aggregation all-reduce)."""


def dp_axes(mesh: Mesh) -> tuple:
    """Mesh axes carrying clients / data parallelism."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _stack_offset(path) -> int:
    """Leaves under 'periods' / stacked inits carry a leading stack axis."""
    for k in path:
        if getattr(k, "key", None) in ("periods", "enc_blocks", "dec_blocks"):
            return 1
    return 0


def _leaf_name(path) -> str:
    for k in reversed(path):
        key = getattr(k, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _try_assign(spec: list, shape, dim: int, axis: str, size: int) -> bool:
    if dim < 0:
        dim += len(shape)
    if 0 <= dim < len(shape) and spec[dim] is None \
            and shape[dim] % size == 0 and shape[dim] >= size:
        spec[dim] = axis
        return True
    return False


def _fallback_assign(spec: list, shape, axis: str, size: int,
                     skip: tuple = ()) -> bool:
    cands = [i for i in range(len(shape))
             if spec[i] is None and i not in skip
             and shape[i] % size == 0 and shape[i] >= size]
    if not cands:
        return False
    i = max(cands, key=lambda j: shape[j])
    spec[i] = axis
    return True


def _param_spec(path, leaf, mesh: Mesh, *, fsdp: bool,
                expert_parallel: bool) -> P:
    name = _leaf_name(path)
    off = _stack_offset(path)
    shape = leaf.shape[off:]
    spec: list = [None] * len(shape)
    msize = mesh.shape["model"]

    if not _REPLICATE.match(name) and len(shape) > 0:
        placed = False
        # MoE expert tensors [E, d, f] / [E, f, d]
        if len(shape) == 3 and name in ("w_gate", "w_up", "w_down"):
            if expert_parallel and shape[0] % msize == 0:
                placed = _try_assign(spec, shape, 0, "model", msize)
            if not placed:
                dim = 1 if name == "w_down" else 2     # the dff dim
                placed = _try_assign(spec, shape, dim, "model", msize)
        if not placed:
            for pat, dim in _MODEL_DIM_RULES:
                if re.match(pat, name):
                    placed = _try_assign(spec, shape, dim, "model", msize)
                    break
        if not placed:
            placed = _fallback_assign(spec, shape, "model", msize)
        if fsdp and len(shape) >= 2 and "data" in mesh.axis_names:
            _fallback_assign(spec, shape, "data", mesh.shape["data"])

    return P(*([None] * off + spec))


def auto_param_specs(param_shapes, mesh: Mesh, *, fsdp: bool = False,
                     expert_parallel: bool = False):
    """param_shapes: pytree of ShapeDtypeStruct/arrays -> pytree of P."""
    paths_leaves = jax.tree_util.tree_flatten_with_path(param_shapes)[0]
    treedef = jax.tree_util.tree_structure(param_shapes)
    specs = [_param_spec(p, l, mesh, fsdp=fsdp, expert_parallel=expert_parallel)
             for p, l in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def auto_batch_specs(batch_shapes, mesh: Mesh, *, batch_dim: int = 0):
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    dp = dp_axes(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= mesh.shape[a]

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) > batch_dim and shape[batch_dim] % dpsize == 0 \
                and shape[batch_dim] >= dpsize:
            spec[batch_dim] = dp
        return P(*spec)
    return jax.tree.map(one, batch_shapes)


def auto_tree_specs(shapes, mesh: Mesh, *, prefer_batch_dim: int = 0,
                    model_dim_order: str = "largest"):
    """Generic (e.g. KV caches): batch dim over dp when divisible, model on
    a remaining divisible dim, else dp on largest (long caches).

    model_dim_order:
      'largest' — largest divisible dim (decode caches: shards the long cache axis)
      'last'    — innermost dims first (prefill cache OUTPUTS: k/v leave the
                  projections sharded on KV*hd, so S-sharding the stored
                  cache would force an in-loop reshard — granite: 2.6x
                  collective regression, see EXPERIMENTS.md SSPerf)
    """
    dp = dp_axes(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= mesh.shape[a]
    msize = mesh.shape["model"]

    def one(path, leaf):
        shape = leaf.shape
        off = _stack_offset(path)
        body = shape[off:]
        spec: list = [None] * len(body)
        used_dp = False
        if len(body) > prefer_batch_dim and body[prefer_batch_dim] % dpsize == 0 \
                and body[prefer_batch_dim] >= dpsize:
            spec[prefer_batch_dim] = dp
            used_dp = True
        if len(body) > 1:
            if model_dim_order == "last":
                placed = False
                for dim in range(len(body) - 1, prefer_batch_dim, -1):
                    if _try_assign(spec, body, dim, "model", msize):
                        placed = True
                        break
                if not placed:
                    _fallback_assign(spec, body, "model", msize,
                                     skip=(prefer_batch_dim,))
            else:
                _fallback_assign(spec, body, "model", msize,
                                 skip=(prefer_batch_dim,))
        if not used_dp and len(body) > 1:
            _fallback_assign(spec, body, dp, dpsize, skip=(prefer_batch_dim,))
        return P(*([None] * off + spec))

    paths_leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    treedef = jax.tree_util.tree_structure(shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in paths_leaves])


def shaped_with(shapes, specs, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree (for .lower)."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)


def federation_state_specs(fed, param_specs):
    """PartitionSpec pytree for a ``fl.engine.FederationState``.

    Server-optimizer moments are params-shaped and inherit the matching
    param's spec (FSDP'd moments for FSDP'd params); the [C] client-state
    vectors (backlog, utility EMAs) and scalar step counters replicate —
    they are a few bytes and every pod reads them in the gate. The
    ``scan_async`` in-flight buffer (``fed.async_depth`` stacked aggregated
    deltas) is params-shaped behind its leading ring-buffer axis, so every
    delta slot shards exactly like the param it will eventually update —
    the buffer adds D x params of sharded bytes, never a replicated copy.

    ``fed.candidate_pool`` changes NOTHING here on purpose: pooling adds
    no FederationState leaves — the dense [C] client vectors keep their
    replicated specs and are touched only by the pool wrapper's gather /
    scatter, so the same spec tree covers pooled and dense runs (the
    resume-safety of the pool knobs rides the checkpoint fingerprint
    instead, see ``fl.simulator._state_fingerprint``)."""
    from repro.core.aggregation import resolve_server_opt
    from repro.fl.engine import FederationState

    name = resolve_server_opt(fed.server_opt)
    rep = P()
    if name == "sgd" or (name == "momentum" and fed.server_momentum == 0.0):
        # optim.sgd collapses momentum=0 to the stateless update -> ()
        opt_specs = ()
    elif name == "momentum":
        opt_specs = {"m": param_specs}
    else:                                   # adam / yogi: m, v, step counter
        opt_specs = {"m": param_specs, "v": param_specs, "t": rep}
    if fed.async_depth > 0:
        # per-slot ages ([D] i32) replicate like the validity mask: every
        # pod reads them in the readiness pop
        inflight_specs = {
            "delta": jax.tree.map(
                lambda sp: P(*([None] + list(sp))), param_specs,
                is_leaf=lambda x: isinstance(x, P)),
            "valid": rep,
            "age": rep,
        }
        if fed.latency_mode != "none":
            # event-clock countdowns ([D] i32) replicate like the ages
            inflight_specs["timer"] = rep
    else:
        inflight_specs = ()
    # the drift-reference sketch is [sketch_dim] — a few KB — so it
    # replicates; only the delta slots are params-sized and sharded
    last_delta_specs = (rep if fed.async_depth > 0 and fed.adaptive_staleness
                        else ())
    # event-clock latency leaves are [C] f32 client vectors — replicated
    # like the backlog/EMAs; the divergence-guard skip counter is a scalar
    latency_specs = ({"compute": rep, "net": rep}
                     if fed.latency_mode != "none" else ())
    skips_specs = rep if fed.divergence_guard else ()
    # wire-codec error-feedback accumulators are params-shaped behind a
    # leading [C] client axis — exactly the in-flight delta layout, and
    # for the same reason: C x params of residual rows must shard like
    # the params they re-enter, never hold a replicated copy per pod
    from repro.core.aggregation import resolve_wire_codec
    if (resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
            != "identity" and fed.error_feedback):
        ef_specs = jax.tree.map(
            lambda sp: P(*([None] + list(sp))), param_specs,
            is_leaf=lambda x: isinstance(x, P))
    else:
        ef_specs = ()
    return FederationState(params=param_specs, opt_state=opt_specs,
                           backlog=rep, util_ema=rep, incl_ema=rep,
                           inflight=inflight_specs,
                           last_delta=last_delta_specs,
                           latency=latency_specs,
                           nonfinite_skips=skips_specs,
                           ef_accum=ef_specs)
