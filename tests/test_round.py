"""Round-engine semantics: FedALIGN vs baselines, warm-up, FedProx,
partial participation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.round import init_state, make_round_fn
from repro.data.synth import make_synth_federation
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=1, n_priority=4, n_nonpriority=4,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])


def run_round(fed, state=None, r=0, seed=0):
    """One round through the simulator adapter; returns (state', stats).
    ``state`` may be a FederationState (chained rounds) or None (fresh)."""
    fn = jax.jit(make_round_fn(LOSS, fed))
    if state is None:
        state = init_state(INIT(jax.random.PRNGKey(0)), fed, C)
    return fn(state, DATA, PM, W, jax.random.PRNGKey(seed), jnp.int32(r))


def test_eps_zero_equals_priority_only():
    fed_a = FedConfig(rounds=10, warmup_frac=0.0, epsilon=0.0, local_epochs=2,
                      selection="fedalign", align_stat="loss")
    fed_b = fed_a.replace(selection="priority_only")
    sa, _ = run_round(fed_a)
    sb, _ = run_round(fed_b)
    for la, lb in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)


def test_eps_inf_equals_all():
    fed_a = FedConfig(rounds=10, warmup_frac=0.0, epsilon=1e9, local_epochs=2,
                      selection="fedalign", align_stat="loss")
    fed_b = fed_a.replace(selection="all")
    sta, sa = run_round(fed_a)
    stb, sb = run_round(fed_b)
    assert np.all(np.asarray(sa["gates"]) == 1.0)
    for la, lb in zip(jax.tree.leaves(sta.params), jax.tree.leaves(stb.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)


def test_warmup_excludes_nonpriority():
    fed = FedConfig(rounds=10, warmup_frac=0.5, epsilon=1e9, local_epochs=1,
                    selection="fedalign", align_stat="loss")
    _, stats = run_round(fed, r=0)       # warm-up round
    gates = np.asarray(stats["gates"])
    np.testing.assert_array_equal(gates, np.asarray(PM, np.float32))
    _, stats = run_round(fed, r=6)       # post warm-up
    assert np.asarray(stats["gates"]).sum() > np.asarray(PM).sum()


def test_round_reduces_global_loss():
    fed = FedConfig(rounds=10, warmup_frac=0.0, epsilon=0.2, local_epochs=3,
                    lr=0.1)
    _, s0 = run_round(fed, r=0)
    st1, _ = run_round(fed, r=0)
    _, s1 = run_round(fed, st1, r=1)
    assert float(s1["global_loss"]) < float(s0["global_loss"])


def test_fedprox_differs_from_fedavg():
    fed_a = FedConfig(rounds=10, warmup_frac=0.0, epsilon=0.2, local_epochs=3,
                      algorithm="fedavg")
    fed_p = fed_a.replace(algorithm="fedprox", prox_mu=1.0)
    params = INIT(jax.random.PRNGKey(0))
    # move params off-init so the prox pull is non-trivial
    params = jax.tree.map(lambda x: x + 0.5, params)
    sa, _ = run_round(fed_a, init_state(params, fed_a, C))
    sp, _ = run_round(fed_p, init_state(params, fed_p, C))
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sp.params))]
    assert max(diffs) > 1e-6
    # prox solution stays closer to the global model
    da = sum(float(jnp.sum((a - g) ** 2)) for a, g in
             zip(jax.tree.leaves(sa.params), jax.tree.leaves(params)))
    dp = sum(float(jnp.sum((a - g) ** 2)) for a, g in
             zip(jax.tree.leaves(sp.params), jax.tree.leaves(params)))
    assert dp < da


def test_partial_participation_masks_gates():
    fed = FedConfig(rounds=10, warmup_frac=0.0, epsilon=1e9, local_epochs=1,
                    participation=0.5, align_stat="loss")
    seen_excluded = False
    for seed in range(5):
        _, stats = run_round(fed, seed=seed)
        gates = np.asarray(stats["gates"])
        assert gates[np.asarray(PM)].sum() >= 1     # priority never empty
        if gates.sum() < len(gates):
            seen_excluded = True
    assert seen_excluded
