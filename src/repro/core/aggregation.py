"""FedALIGN renormalized gated aggregation (paper eq. (15)):

    w <- sum_k p_k I_k w_k / sum_k p_k I_k

over client-stacked parameter pytrees. The default ``fused`` path flattens
the WHOLE pytree into one [C, M_total] buffer and invokes the ``fedagg``
kernel (Pallas on TPU, its jnp lowering on CPU) ONCE per round instead of
once per leaf — one kernel launch, one contraction, and under pjit with the
client axis sharded over (pod, data) exactly one all-reduce: FedALIGN's
entire server-side communication. Accumulation is f32 regardless of leaf
dtype, so fused and per-leaf outputs agree to the cast.

This module also owns two registries:

- the **ServerOptimizer registry**: the fused aggregated delta is a
  pseudo-gradient, and ``aggregate_updates`` applies the configured
  server-side update rule (FedOpt, Reddi et al., arXiv:2003.00295) to it —
  ``sgd`` (FedAvg), ``momentum`` (FedAvgM), ``adam`` (FedAdam), ``yogi``
  (FedYogi) — reusing the update rules from ``optim/optimizers.py``.
  Optimizer moments live in ``fl.engine.FederationState.opt_state`` and
  thread through the round scan.
- the **Aggregator registry** (``FedConfig.aggregator``): how the gated
  client deltas are REDUCED before the server step. ``mean`` is the paper
  rule above; ``trimmed_mean`` / ``median`` are the coordinate-wise
  Byzantine-robust order statistics (Yin et al., arXiv:1803.01498),
  ``dp`` is DP-FedAvg clip+noise (McMahan et al., arXiv:1710.06963), and
  ``cosine_filter`` zeroes the gates of delta-sketch outliers before the
  plain mean. A registered aggregator is a PREPARE function producing
  gate/weight rewrites and in-kernel operands — the reduction itself stays
  one fused fedagg kernel launch per round for every variant.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.optim import optimizers as _opt
from repro.utils import fold_in_name


def check_client_weights(weights, *, where="client weights"):
    """Validate CONCRETE client weights at the aggregation boundary.

    A negative p_k silently sign-flips that client's contribution (the
    renormalized mean subtracts it); a NaN/inf poisons the whole aggregate.
    Neither is ever a legitimate data fraction, so both fail loudly here.
    Traced values (inside jit) pass through unchecked — jitted callers
    validate at their host-side entry points (fl/simulator, launch/train)
    where the weights are still concrete.
    """
    if isinstance(weights, jax.core.Tracer):
        return weights
    import numpy as np
    w = np.asarray(weights)
    if not np.all(np.isfinite(w)):
        bad = np.flatnonzero(~np.isfinite(w))
        raise ValueError(
            f"{where} must be finite: clients {bad.tolist()} are NaN/inf. "
            "Check the shard spec / data-fraction computation that produced "
            "them — a NaN weight poisons every aggregated parameter.")
    if np.any(w < 0):
        bad = np.flatnonzero(w < 0)
        raise ValueError(
            f"{where} must be non-negative: clients {bad.tolist()} have "
            f"negative weight (min {w.min()}). A negative data fraction "
            "sign-flips that client's update in the renormalized mean; fix "
            "the shard spec instead of aggregating with it.")
    return weights


def flatten_stacked(client_params, dtype=jnp.float32):
    """Client-stacked pytree ([C, ...] leaves) -> one [C, M_total] buffer."""
    leaves = jax.tree.leaves(client_params)
    C = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.reshape(C, -1).astype(dtype) for leaf in leaves], axis=1)


def aggregate_clients(client_params, weights, gates, *, use_pallas=False,
                      fused=True, interpret=False, aggregator="mean",
                      fed=None, key=None):
    """client_params: pytree with leading client axis C on every leaf.

    fused=True (default): one fedagg call on the [C, M_total] flattening;
    fused=False: one fedagg call per leaf (the pre-fusion path, kept as the
    parity reference and for incremental/per-leaf sharded layouts).

    ``aggregator`` names a registered Aggregator (mean | trimmed_mean |
    median | dp | cosine_filter). Non-mean aggregators read their knobs off
    ``fed`` and interpret the client rows as DELTAS (clip norms, outlier
    cosines); ``dp`` additionally needs a PRNG ``key`` for its per-round
    noise draw. Whatever the variant, the reduction stays one fedagg call
    (fused) or one per leaf — the robust work happens inside the kernel,
    plus an O(C * sketch_dim) gate pre-pass for cosine_filter."""
    check_client_weights(weights)
    leaves, treedef = jax.tree.flatten(client_params)
    if not leaves:
        return client_params
    C = leaves[0].shape[0]

    name = resolve_aggregator(aggregator)
    if name != "mean":
        if fed is None:
            raise ValueError(
                f"aggregator={name!r} reads its knobs (trim_frac/dp_clip/"
                "dp_noise/outlier_cos/sketch_dim) off a FedConfig: pass fed=")
        weights, gates, kernel_kw, noise = get_aggregator(name)(
            fed, client_params, weights, gates, key)
    else:
        kernel_kw, noise = {}, None

    if not fused:
        # per-leaf path: the dp noise vector is ONE [M_total] draw sliced at
        # each leaf's offset, so per-leaf == fused bit-for-bit per coordinate
        sizes = [leaf.size // C for leaf in leaves]
        offs, off = [], 0
        for size in sizes:
            offs.append(off)
            off += size
        agg_leaves = []
        for leaf, size, off in zip(leaves, sizes, offs):
            kw = dict(kernel_kw)
            if noise is not None:
                kw["noise"] = noise[off:off + size]
            out = kops.fedagg(leaf.reshape(C, -1), weights, gates,
                              use_pallas=use_pallas, interpret=interpret, **kw)
            agg_leaves.append(out.reshape(leaf.shape[1:]))
        return jax.tree.unflatten(treedef, agg_leaves)

    # keep a uniform leaf dtype on the wire (bf16 deltas stay bf16 in the
    # [C, M_total] buffer and its collective); mixed-dtype trees go f32.
    # fedagg accumulates in f32 either way, so fused == per-leaf numerics.
    dtypes = {leaf.dtype for leaf in leaves}
    buf_dtype = dtypes.pop() if len(dtypes) == 1 else jnp.float32
    sizes = [leaf.size // C for leaf in leaves]
    buf = flatten_stacked(client_params, dtype=buf_dtype)
    out = kops.fedagg(buf, weights, gates, use_pallas=use_pallas,
                      interpret=interpret, noise=noise, **kernel_kw)
    agg_leaves, off = [], 0
    for leaf, size in zip(leaves, sizes):
        agg_leaves.append(
            out[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, agg_leaves)


# ================================================================ aggregators
AGGREGATORS: dict[str, Callable] = {}


def register_aggregator(name: str, *, needs_key=False, in_kernel=True):
    """Register a client-delta Aggregator under ``name``.

    The registered callable is a PREPARE step
    ``prepare(fed, client_deltas, weights, gates, key)
        -> (weights, gates, kernel_kw, noise)``
    run once per round before the fused fedagg call: it may rewrite the
    weight/gate vectors (cosine_filter), attach extra in-kernel operands
    (dp's per-client clip scales), and return a [M_total] noise vector that
    the fused/per-leaf dispatcher slices per leaf. ``kernel_kw`` is passed
    straight to ``kernels.ops.fedagg`` — the reduction itself runs inside
    the kernel (``in_kernel`` aggregators add zero extra HBM passes over
    the [C, M_total] buffer). ``needs_key=True`` marks stochastic
    aggregators: the round loop derives a per-round key
    (``aggregator_key``) only for those, so deterministic traces are
    untouched."""
    def deco(prepare):
        prepare.agg_name = name
        prepare.needs_key = needs_key
        prepare.in_kernel = in_kernel
        AGGREGATORS[name] = prepare
        return prepare
    return deco


def resolve_aggregator(name) -> str:
    """Canonical registry name ('none' / None is the plain gated mean)."""
    return "mean" if name in (None, "none") else name


def get_aggregator(name: str) -> Callable:
    name = resolve_aggregator(name)
    try:
        return AGGREGATORS[name]
    except KeyError:
        raise ValueError(f"unknown aggregator {name!r}; "
                         f"registered: {sorted(AGGREGATORS)}") from None


def aggregator_key(fed, round_idx):
    """Per-round PRNG key for stochastic aggregators (dp's noise draw).

    Derived from ``fed.seed`` via ``fold_in_name`` (crc32 — deterministic
    across processes) + the round index, and computed IDENTICALLY by the
    engine round and both sharded pod rounds, so every backend draws the
    same noise and stays bit-comparable."""
    base = fold_in_name(jax.random.PRNGKey(fed.seed), "aggregator_noise")
    return jax.random.fold_in(base, round_idx)


def inclusion_mass(fed, weights, gates):
    """The configured aggregator's denominator mass for a round — the
    aggregate can be nonzero iff this is > 0 (the zero-inclusion
    ServerOptimizer skip keys off it). mean/dp/cosine_filter renormalize
    by sum p_k I_k; trimmed_mean/median are unweighted order statistics
    over the included clients, so their mass is the included COUNT (a
    zero-weight included client still moves the median)."""
    name = resolve_aggregator(getattr(fed, "aggregator", "mean"))
    if name in ("trimmed_mean", "median"):
        return jnp.sum((gates > 0).astype(jnp.float32))
    return jnp.sum(weights.astype(jnp.float32) * gates.astype(jnp.float32))


def check_aggregator_config(fed):
    """Validate the aggregator knobs whose bad values would corrupt the
    aggregate silently (like check_async_config for the async knobs)."""
    name = resolve_aggregator(fed.aggregator)
    get_aggregator(name)
    if name == "trimmed_mean" and not 0.0 <= fed.trim_frac < 0.5:
        raise ValueError(
            f"FedConfig.trim_frac={fed.trim_frac} outside [0, 0.5): trimming "
            "half or more from each side leaves no survivors for any n")
    if name == "dp":
        if fed.dp_clip <= 0:
            raise ValueError(
                f"FedConfig.dp_clip={fed.dp_clip} must be > 0: the clip bound "
                "is the DP sensitivity; 0 would zero every client delta")
        if fed.dp_noise < 0:
            raise ValueError(
                f"FedConfig.dp_noise={fed.dp_noise} must be >= 0 "
                "(noise multiplier z; 0 = clip-only)")
    if name == "cosine_filter":
        if not -1.0 <= fed.outlier_cos <= 1.0:
            raise ValueError(
                f"FedConfig.outlier_cos={fed.outlier_cos} outside [-1, 1]: "
                "it is compared against cosine similarities")
        if fed.sketch_dim <= 0:
            raise ValueError(
                "cosine_filter scores clients on sketch_dim CountSketches; "
                f"FedConfig.sketch_dim={fed.sketch_dim} must be > 0")


def _delta_sq_norms(client_deltas):
    """Per-client squared L2 norm over the WHOLE delta pytree -> [C] f32."""
    leaves = jax.tree.leaves(client_deltas)
    C = leaves[0].shape[0]
    tot = jnp.zeros((C,), jnp.float32)
    for leaf in leaves:
        x = leaf.reshape(C, -1).astype(jnp.float32)
        tot = tot + jnp.sum(x * x, axis=1)
    return tot


@register_aggregator("mean")
def _agg_mean(fed, client_deltas, weights, gates, key):
    # the paper's renormalized gated weighted mean — the kernel default
    return weights, gates, {}, None


@register_aggregator("trimmed_mean")
def _agg_trimmed(fed, client_deltas, weights, gates, key):
    return weights, gates, dict(aggregator="trimmed_mean",
                                trim_frac=float(fed.trim_frac)), None


@register_aggregator("median")
def _agg_median(fed, client_deltas, weights, gates, key):
    return weights, gates, dict(aggregator="median"), None


@register_aggregator("dp", needs_key=True)
def _agg_dp(fed, client_deltas, weights, gates, key):
    """DP-FedAvg: clip each client delta to L2 <= dp_clip (a per-client
    multiplicative factor folded into the kernel's weighted contraction),
    add N(0, (dp_noise * dp_clip / inclusion_mass)^2) per coordinate.

    The noise is drawn OUTSIDE the kernel (one [M_total] jax.random draw
    per round) so the Pallas kernel and the jnp lowering see the very same
    vector — the in-kernel TPU PRNG would break CPU/TPU parity. dp_noise
    is the raw noise multiplier z; ``dp_epsilon`` below composes the
    per-round mechanisms over a run into an (epsilon, delta) report."""
    if key is None:
        raise ValueError(
            "aggregator='dp' draws per-round Gaussian noise and needs the "
            "round key: thread key=aggregator_key(fed, round_idx) through "
            "aggregate_clients/aggregate_delta")
    norms = jnp.sqrt(_delta_sq_norms(client_deltas))
    row_scale = jnp.minimum(1.0, fed.dp_clip / jnp.maximum(norms, 1e-12))
    M = sum(leaf.size for leaf in jax.tree.leaves(client_deltas))
    C = jax.tree.leaves(client_deltas)[0].shape[0]
    noise = jax.random.normal(key, (M // C,), jnp.float32)
    kw = dict(aggregator="dp", row_scale=row_scale,
              noise_scale=float(fed.dp_noise) * float(fed.dp_clip))
    return weights, gates, kw, noise


# ============================================================ DP accounting
# RDP orders to minimize over: dense where the optimum usually lands for
# z in [0.3, 10] over 1..1e5 rounds, sparse log-spaced tail for tiny z.
DP_RDP_ORDERS = tuple([1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0,
                       10.0, 12.0, 16.0, 20.0, 24.0, 32.0, 48.0, 64.0,
                       96.0, 128.0, 192.0, 256.0, 384.0, 512.0])


def dp_epsilon(noise_multiplier: float, steps: int, delta: float,
               orders=DP_RDP_ORDERS):
    """(epsilon, best_order) for ``steps`` compositions of the Gaussian
    mechanism with noise multiplier z (= FedConfig.dp_noise), at the given
    target ``delta`` — the budget the ``dp`` aggregator actually spends.

    Renyi DP of one Gaussian mechanism at order alpha is alpha / (2 z^2)
    (Mironov 2017, arXiv:1702.07476 Prop. 7); RDP composes additively over
    rounds, and converts to (eps, delta)-DP via
    eps = min_alpha [ steps * alpha / (2 z^2) + log(1/delta) / (alpha - 1) ]
    (ibid. Prop. 3). This is the standard moments-accountant bound for
    full-batch participation (no subsampling amplification — every gated
    client contributes each round, which is FedALIGN's regime); it is
    conservative when participation sampling thins cohorts.

    z <= 0 means no noise: epsilon is infinite. Sanity anchor: z=1, one
    step, delta=1e-5 -> eps ~ 5.3."""
    if steps <= 0:
        return 0.0, None
    if noise_multiplier <= 0:
        return float("inf"), None
    if not (0.0 < delta < 1.0):
        raise ValueError(f"dp_epsilon needs a target delta in (0, 1), "
                         f"got {delta}")
    z2 = float(noise_multiplier) ** 2
    log1d = math.log(1.0 / float(delta))
    best, best_order = float("inf"), None
    for a in orders:
        if a <= 1.0:
            continue
        eps = steps * a / (2.0 * z2) + log1d / (a - 1.0)
        if eps < best:
            best, best_order = eps, a
    return best, best_order


def dp_report(fed, rounds: int):
    """(epsilon, delta) actually spent by a run of ``rounds`` rounds under
    this config, or None when the run is not differentially private
    (aggregator != 'dp', or clip-only dp_noise=0)."""
    if resolve_aggregator(getattr(fed, "aggregator", "mean")) != "dp":
        return None
    if fed.dp_noise <= 0:
        return None
    eps, _ = dp_epsilon(float(fed.dp_noise), int(rounds), float(fed.dp_delta))
    return eps, float(fed.dp_delta)


@register_aggregator("cosine_filter", in_kernel=False)
def _agg_cosine(fed, client_deltas, weights, gates, key):
    """Zero the gate of clients whose delta DIRECTION disagrees with the
    cohort: cosines are estimated on sketch_dim CountSketches (one O(M)
    pass per client, reusing engine.delta_sketch), so the similarity pass
    is O(C * sketch_dim) — never [C, C] on full deltas. The reference is
    the gated weighted mean of the per-client NORMALIZED sketches (the
    mean direction): normalizing first means a norm-boosted Byzantine
    client cannot buy reference mass, which a raw-delta mean would grant
    it. Clients with cos < fed.outlier_cos are dropped for the round; the
    reduction then proceeds as the plain gated mean (same single kernel
    launch, this is purely a gate rewrite)."""
    from repro.fl.engine import delta_sketch
    skey = fold_in_name(jax.random.PRNGKey(fed.seed), "aggregator_cosine_sketch")
    sk = jax.vmap(lambda d: delta_sketch(d, skey, fed.sketch_dim))(client_deltas)
    norms = jnp.sqrt(jnp.sum(sk * sk, axis=1))
    dirs = sk / jnp.maximum(norms, 1e-12)[:, None]
    wg = (weights * gates).astype(jnp.float32)
    # mask excluded rows before the weighted mean: a non-finite delta
    # behind gate 0 sketches to NaN and 0 * NaN would poison the reference
    ref = (jnp.einsum("c,cd->d", wg, jnp.where((wg > 0)[:, None], dirs, 0.0))
           / jnp.maximum(jnp.sum(wg), 1e-30))
    ref = ref / jnp.maximum(jnp.sqrt(jnp.sum(ref * ref)), 1e-12)
    cos = dirs @ ref
    keep = (cos >= fed.outlier_cos).astype(gates.dtype)
    return weights, gates * keep, {}, None


# ========================================================= server optimizers
SERVER_OPTIMIZERS: dict[str, Callable] = {}


def register_server_optimizer(name: str):
    """Register ``factory(fed) -> optim.optimizers.Optimizer`` under ``name``.

    The factory reads its hyper-parameters off the FedConfig (duck-typed:
    anything with the ``server_*`` attributes works); the resulting
    Optimizer's ``init(params)`` builds the moment pytree carried in
    ``FederationState.opt_state`` and ``update`` consumes the aggregated
    delta as a pseudo-gradient."""
    def deco(factory):
        factory.opt_name = name
        SERVER_OPTIMIZERS[name] = factory
        return factory
    return deco


def resolve_server_opt(name) -> str:
    """Canonical registry name ('none', the legacy no-op, is plain sgd)."""
    return "sgd" if name in (None, "none") else name


def get_server_optimizer(name: str) -> Callable:
    name = resolve_server_opt(name)
    try:
        return SERVER_OPTIMIZERS[name]
    except KeyError:
        raise ValueError(f"unknown server optimizer {name!r}; "
                         f"registered: {sorted(SERVER_OPTIMIZERS)}") from None


def server_optimizer(fed):
    """The configured ServerOptimizer instance for ``fed.server_opt``."""
    return get_server_optimizer(fed.server_opt)(fed)


@register_server_optimizer("sgd")
def _server_sgd(fed):
    # w <- w + server_lr * agg_delta: FedAvg at server_lr=1 (the paper rule)
    return _opt.sgd(0.0)


@register_server_optimizer("momentum")
def _server_momentum(fed):
    # FedAvgM: momentum over aggregated deltas
    return _opt.sgd(momentum=fed.server_momentum)


@register_server_optimizer("adam")
def _server_adam(fed):
    return _opt.adam(fed.server_b1, fed.server_b2, fed.server_eps)


@register_server_optimizer("yogi")
def _server_yogi(fed):
    return _opt.yogi(fed.server_b1, fed.server_b2, fed.server_eps)


def apply_server_opt(fed, global_params, opt_state, agg_delta, *, scale=1.0):
    """One server-optimizer step on an already-aggregated global delta.

    Returns (new_params, new_opt_state). The delta enters the optimizer as
    the pseudo-gradient g = -agg_delta, so ``sgd`` at server_lr recovers
    w + server_lr * delta exactly and ``momentum`` reproduces the legacy
    FedAvgM recursion m <- beta m + delta, w <- w + server_lr m.

    ``scale`` pre-multiplies the delta (in f32, after the wire-dtype cast):
    the staleness discount of the ``scan_async`` backend enters the
    optimizer here — one call PER POPPED in-flight slot, each with that
    slot's own scale (the constant ``staleness_decay ** async_depth``
    under the fifo pipe; ``staleness_decay ** age``, optionally times the
    measured-drift cosine, under the variable-lag ``ready`` buffer) — so a
    stale delta's momentum/second-moment contribution is discounted too,
    not just its parameter step. ``scale`` may be a traced scalar (the
    measured-age discounts are); only the python-literal 1.0 skips the
    multiply entirely — the synchronous path is untouched."""
    opt = server_optimizer(fed)
    if isinstance(scale, (int, float)) and float(scale) == 1.0:
        grads = jax.tree.map(lambda d: -d.astype(jnp.float32), agg_delta)
    else:
        grads = jax.tree.map(lambda d: -d.astype(jnp.float32) * scale,
                             agg_delta)
    return opt.update(grads, opt_state, global_params, fed.server_lr)


def aggregate_delta(global_params, client_params, weights, gates, *,
                    fed, interpret=False, key=None):
    """Delta-form gated aggregation WITHOUT the server step:

        d <- agg(cast(w_k - w, fed.agg_dtype))      (ONE fused fedagg call)

    Returns the aggregated global delta (leaves in ``fed.agg_dtype``),
    reduced by the configured ``fed.aggregator`` (``key`` feeds stochastic
    aggregators — pass ``aggregator_key(fed, round_idx)`` when
    ``get_aggregator(fed.aggregator).needs_key``). This is the seam the
    ``scan_async`` backend buffers: an in-flight cohort is exactly one of
    these deltas awaiting its (staleness-discounted) ``apply_server_opt``
    some rounds later — the robust/private reduction happens at PUSH time,
    so every aggregator commutes with the async buffer. ``client_params``
    may live in cohort space [K, ...] (zero gates drop padding slots)."""
    ad = jnp.dtype(fed.agg_dtype)
    deltas = jax.tree.map(lambda ck, g: (ck - g[None]).astype(ad),
                          client_params, global_params)
    return aggregate_clients(deltas, weights, gates,
                             use_pallas=fed.use_pallas,
                             fused=fed.fused_agg, interpret=interpret,
                             aggregator=getattr(fed, "aggregator", "mean"),
                             fed=fed, key=key)


def aggregate_updates(global_params, client_params, weights, gates, *,
                      fed, opt_state=(), interpret=False, key=None):
    """Delta-form gated aggregation + the configured server optimizer:

        d  <- aggregate_delta(...)                  (ONE fused fedagg call)
        w, moments <- ServerOptimizer(fed.server_opt)(w, moments, d)

    Returns (new_params, new_opt_state). ``fed.agg_dtype`` selects the
    reduced-precision delta wire format; accumulation is f32 either way."""
    agg = aggregate_delta(global_params, client_params, weights, gates,
                          fed=fed, interpret=interpret, key=key)
    return apply_server_opt(fed, global_params, opt_state, agg)
