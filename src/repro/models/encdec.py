"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is STUBBED per the assignment:
``input_specs()`` feeds precomputed frame embeddings [B, T_frames, d]
(already conv-downsampled). We implement the transformer backbone: a
bidirectional encoder over frames and a causal decoder with self- +
cross-attention. Whisper idioms kept: pre-LayerNorm, GELU MLP, learned
positional embeddings, no RoPE.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.attention import cross_attention, init_cross_attn
from repro.utils import fold_in_name


def _init_self_attn(key, cfg):
    return init_cross_attn(key, cfg)   # same 4-matrix shape, H == KV


def _init_enc_block(key, cfg):
    return {
        "norm1": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "attn": _init_self_attn(fold_in_name(key, "attn"), cfg),
        "norm2": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "mlp": L.init_gelu_mlp(fold_in_name(key, "mlp"), cfg.d_model, cfg.d_ff, cfg.pdtype),
    }


def _init_dec_block(key, cfg):
    return {
        "norm1": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "self_attn": _init_self_attn(fold_in_name(key, "sa"), cfg),
        "norm_x": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "cross_attn": init_cross_attn(fold_in_name(key, "xa"), cfg),
        "norm2": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "mlp": L.init_gelu_mlp(fold_in_name(key, "mlp"), cfg.d_model, cfg.d_ff, cfg.pdtype),
    }


def init(key, cfg):
    enc_keys = jax.random.split(fold_in_name(key, "enc"), cfg.encoder_layers)
    dec_keys = jax.random.split(fold_in_name(key, "dec"), cfg.num_layers)
    return {
        "embed": L.embed_init(fold_in_name(key, "embed"),
                              (cfg.vocab_size, cfg.d_model), cfg.pdtype),
        "pos_enc": L.embed_init(fold_in_name(key, "pe"),
                                (cfg.num_frames, cfg.d_model), cfg.pdtype),
        "pos_dec": L.embed_init(fold_in_name(key, "pd"),
                                (max(cfg.num_frames, 65536), cfg.d_model), cfg.pdtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": L.init_layernorm(cfg.d_model, cfg.pdtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "dec_norm": L.init_layernorm(cfg.d_model, cfg.pdtype),
    }


def _self_attn(p, x, cfg, *, causal, positions=None, mode="train", cache=None):
    """Non-roped MHA used by both stacks; decode maintains a kv cache."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    cd = cfg.cdtype
    q = (x @ p["wq"].astype(cd)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(cd)).reshape(B, S, H, hd)
    v = (x @ p["wv"].astype(cd)).reshape(B, S, H, hd)
    new_cache = None
    if mode == "decode":
        pos = positions[-1]
        slot = pos.astype(jnp.int32)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        kv_len = jnp.minimum(pos + 1, kc.shape[1]).astype(jnp.int32)
        out = kops.decode_attention(q, kc, vc, kv_len=kv_len, use_pallas=cfg.use_pallas)
        new_cache = {"k": kc, "v": vc, "len": kv_len}
    else:
        out = kops.flash_attention(q, k, v, causal=causal,
                                   block_kv=cfg.attn_block_kv, use_pallas=cfg.use_pallas)
        if mode == "prefill":
            new_cache = {"k": k, "v": v, "len": jnp.asarray(S, jnp.int32)}
    y = out.reshape(B, S, H * hd) @ p["wo"].astype(cd)
    return y, new_cache


def encode(params, frames, cfg):
    """frames: [B, T, d] stubbed conv-frontend output."""
    cd = cfg.cdtype
    T = frames.shape[1]
    x = frames.astype(cd) + params["pos_enc"][:T].astype(cd)[None]

    def block(x, p):
        h, _ = _self_attn(p["attn"], L.layernorm(p["norm1"], x), cfg, causal=False)
        x = x + h
        x = x + L.gelu_mlp_apply(p["mlp"], L.layernorm(p["norm2"], x), cd)
        return x, None

    x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    return L.layernorm(params["enc_norm"], x)


def _cross_kv(p, enc, cfg):
    """Precompute cross-attention K/V from encoder states (once per request)."""
    B, T, _ = enc.shape
    H, hd = cfg.num_heads, cfg.head_dim
    cd = cfg.cdtype
    k = (enc @ p["wk"].astype(cd)).reshape(B, T, H, hd)
    v = (enc @ p["wv"].astype(cd)).reshape(B, T, H, hd)
    return {"k": k, "v": v}


def _cross_attn_cached(p, x, ckv, cfg):
    """Cross-attention against precomputed K/V (decode: no 1500-frame
    re-projection per generated token)."""
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    cd = cfg.cdtype
    q = (x @ p["wq"].astype(cd)).reshape(B, S, H, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   ckv["k"].astype(jnp.float32)) * hd ** -0.5
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, ckv["v"].astype(jnp.float32)).astype(cd)
    return out.reshape(B, S, H * hd) @ p["wo"].astype(cd)


def decode_forward(params, tokens, enc_out, cfg, *, mode, positions=None, caches=None):
    cd = cfg.cdtype
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)
    x = params["embed"][tokens].astype(cd) + params["pos_dec"][positions].astype(cd)[None]

    def block(carry, scanned):
        xc = carry
        p, cache = scanned
        c_sa = cache["self"] if cache is not None else None
        h, new_sa = _self_attn(p["self_attn"], L.layernorm(p["norm1"], xc), cfg,
                               causal=True, positions=positions, mode=mode, cache=c_sa)
        xc = xc + h
        xq = L.layernorm(p["norm_x"], xc)
        if mode == "train":                 # recompute K/V (fused, remat-friendly)
            xc = xc + cross_attention(p["cross_attn"], xq, enc_out, cfg)
            return (xc + L.gelu_mlp_apply(p["mlp"], L.layernorm(p["norm2"], xc), cd),
                    {"self": new_sa})
        # prefill/decode: cross K/V cached once per request — decoding must
        # not re-project the 1500 encoder frames per generated token
        ckv = cache["cross"] if (cache is not None and cache.get("cross")
                                 is not None) else _cross_kv(p["cross_attn"],
                                                             enc_out, cfg)
        xc = xc + _cross_attn_cached(p["cross_attn"], xq, ckv, cfg)
        xc = xc + L.gelu_mlp_apply(p["mlp"], L.layernorm(p["norm2"], xc), cd)
        return xc, {"self": new_sa, "cross": ckv}

    if mode == "train" and cfg.remat:
        block = jax.checkpoint(block)

    if caches is None:
        x, out_caches = jax.lax.scan(
            lambda c, p: block(c, (p, None)), x, params["dec_blocks"])
    else:
        x, out_caches = jax.lax.scan(block, x, (params["dec_blocks"], caches["dec"]))
    x = L.layernorm(params["dec_norm"], x)
    return x, ({"dec": out_caches, "enc_out": enc_out} if mode != "train" else None)


def loss_fn(params, batch, cfg):
    """batch: frames [B,T,d], tokens/labels/mask [B,S]."""
    enc_out = encode(params, batch["frames"], cfg)
    hidden, _ = decode_forward(params, batch["tokens"], enc_out, cfg, mode="train")
    s_loss, s_cnt = L.chunked_softmax_xent(hidden, params["embed"], batch["labels"],
                                           batch["mask"], cfg.loss_chunk)
    loss = s_loss / jnp.maximum(s_cnt, 1)
    return loss, {"task_loss": loss, "aux_loss": jnp.float32(0), "tokens": s_cnt}


def make_cache(cfg, batch_size, cache_len):
    B, H, hd = batch_size, cfg.num_heads, cfg.head_dim
    cd = cfg.cdtype
    one = {"self": {"k": jnp.zeros((B, cache_len, H, hd), cd),
                    "v": jnp.zeros((B, cache_len, H, hd), cd),
                    "len": jnp.zeros((), jnp.int32)},
           "cross": {"k": jnp.zeros((B, cfg.num_frames, H, hd), cd),
                     "v": jnp.zeros((B, cfg.num_frames, H, hd), cd)}}
    dec = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one)
    return {"dec": dec, "enc_out": jnp.zeros((B, cfg.num_frames, cfg.d_model), cd)}


def prefill(params, batch, cfg):
    enc_out = encode(params, batch["frames"], cfg)
    hidden, caches = decode_forward(params, batch["tokens"], enc_out, cfg, mode="prefill")
    logits = hidden[:, -1].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return caches, logits


def decode_step(params, caches, tokens, pos, cfg):
    positions = jnp.asarray(pos).reshape(1)
    hidden, new_caches = decode_forward(params, tokens, caches["enc_out"], cfg,
                                        mode="decode", positions=positions, caches=caches)
    logits = hidden[:, -1].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, new_caches
