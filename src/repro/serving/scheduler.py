"""Wave-based batched serving scheduler.

A fixed pool of B slots decodes in LOCK-STEP: one jitted ``decode_step``
per tick over the whole batch (the exact shape the dry-run lowers), with a
single shared position counter — the KV-cache write slot is uniform across
the batch, which is what keeps shapes static and TPU-friendly.

Requests are admitted in WAVES: up to B requests start together at pos 0;
each slot feeds its own prompt token per tick (teacher forcing) until its
prompt is exhausted, then feeds back its last sampled token. Short-prompt
slots therefore start generating while long-prompt slots are still
prefilling — prefill and decode are interleaved inside one program, but
positions never diverge. A wave ends when every slot is done; the next
wave admits fresh requests.

(True per-slot-position continuous batching needs per-row cache indices —
a vmapped cache write — noted as the production extension; the scheduler
interface would not change.)
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Drives ``model.decode_step`` over a fixed slot pool in waves."""

    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 eos_id: int | None = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._step = jax.jit(model.decode_step)
        self.ticks = 0

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        self.queue.append(req)

    def idle(self) -> bool:
        return not self.queue

    def run(self, max_ticks: int = 100_000) -> list[Request]:
        while self.queue and self.ticks < max_ticks:
            self._run_wave(max_ticks)
        return self.finished

    # ---------------------------------------------------------------- engine
    def _run_wave(self, max_ticks: int):
        wave = [self.queue.popleft() for _ in range(min(self.B, len(self.queue)))]
        caches = self.model.make_cache(self.B, self.max_len)
        prompts = [deque(int(x) for x in r.prompt) for r in wave]
        active = [True] * len(wave)
        pos = 0
        while any(active) and pos < self.max_len and self.ticks < max_ticks:
            toks = np.zeros((self.B, 1), np.int32)
            for i, r in enumerate(wave):
                if not active[i]:
                    continue
                toks[i, 0] = (prompts[i].popleft() if prompts[i]
                              else r.out_tokens[-1] if r.out_tokens else 0)
            logits, caches = self._step(self.params, caches,
                                        jnp.asarray(toks), jnp.int32(pos))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            self.ticks += 1
            pos += 1
            for i, r in enumerate(wave):
                if not active[i] or prompts[i]:
                    continue                            # done or still prefilling
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                hit_eos = self.eos_id is not None and tok == self.eos_id
                if hit_eos or len(r.out_tokens) >= r.max_new_tokens \
                        or pos >= self.max_len:
                    r.done = True
                    active[i] = False
                    self.finished.append(r)
        for i, r in enumerate(wave):                    # max_len cutoffs
            if active[i]:
                r.done = True
                self.finished.append(r)
