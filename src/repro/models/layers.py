"""Core layers (raw JAX): norms, RoPE, dense/SwiGLU FFN, chunked softmax-xent.

All layers are functional: ``init_*`` build param pytrees, ``*_apply`` run
them. Compute runs in ``cfg.compute_dtype``; params live in
``cfg.param_dtype``; reductions (norms, softmax, loss) run in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import fold_in_name


# --------------------------------------------------------------------------- init
def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else 1
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norm
def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]               # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- ffn
def init_swiglu(key, d_model, d_ff, dtype):
    ks = {n: fold_in_name(key, n) for n in ("gate", "up", "down")}
    return {
        "w_gate": dense_init(ks["gate"], (d_model, d_ff), dtype),
        "w_up": dense_init(ks["up"], (d_model, d_ff), dtype),
        "w_down": dense_init(ks["down"], (d_ff, d_model), dtype),
    }


def swiglu_apply(p, x, cdtype):
    g = x @ p["w_gate"].astype(cdtype)
    u = x @ p["w_up"].astype(cdtype)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(cdtype)


def init_gelu_mlp(key, d_model, d_ff, dtype):
    ks = {n: fold_in_name(key, n) for n in ("up", "down")}
    return {
        "w_up": dense_init(ks["up"], (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks["down"], (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp_apply(p, x, cdtype):
    h = jax.nn.gelu(x @ p["w_up"].astype(cdtype) + p["b_up"].astype(cdtype))
    return h @ p["w_down"].astype(cdtype) + p["b_down"].astype(cdtype)


# ----------------------------------------------------------------- chunked loss
def chunked_softmax_xent(hidden, w_embed, labels, mask, chunk: int):
    """Cross-entropy without materializing [B,S,V] logits.

    hidden: [B, S, d] (compute dtype); w_embed: [V, d]; labels/mask: [B, S].
    Scans over sequence chunks; per-chunk logits are [B, chunk, V].
    Returns (sum_loss, sum_mask) as fp32 scalars.
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:                         # pad sequence; padded rows carry mask 0
        pad = chunk - S % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    n = S // chunk
    h = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)         # [n,B,c,D]
    y = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    m = mask.reshape(B, n, chunk).transpose(1, 0, 2)
    we = w_embed

    def body(carry, inp):
        s_loss, s_cnt = carry
        hc, yc, mc = inp
        logits = (hc @ we.T.astype(hc.dtype)).astype(jnp.float32)    # [B,c,V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (s_loss + jnp.sum(nll), s_cnt + jnp.sum(mc)), None

    (s_loss, s_cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (h, y, m))
    return s_loss, s_cnt
