"""Paper Figure 6 (App C.4): priority-count and local-epoch sweeps — the
FedALIGN advantage shrinks as the priority set grows (homogenization)."""
from __future__ import annotations

from benchmarks.common import fed_suite
from repro.data.shards import make_benchmark_federation


def run(fast=True, seeds=(0,)):
    rounds = 15 if fast else 150
    rows = []
    for n_pri, E in [(2, 5), (6, 5), (18, 5), (6, 3)]:
        fedn = make_benchmark_federation("fmnist", seed=0, n_priority=n_pri,
                                         samples_per_client=150 if fast else None)
        out = fed_suite(fedn, "logreg",
                        dict(num_clients=fedn.x.shape[0], num_priority=n_pri,
                             rounds=rounds, local_epochs=E, epsilon=0.2,
                             lr=0.1, warmup_frac=0.1, batch_size=32),
                        seeds=seeds, selections=("fedalign", "priority_only"))
        for r in out:
            r["n_priority"], r["E"] = n_pri, E
        rows += out
    return rows


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "acc_curve"})
