"""whisper-medium [audio] — encoder-decoder, conv frontend STUBBED.
[arXiv:2212.04356]

24L d_model=1024 16H d_ff=4096 vocab=51865; 24 encoder + 24 decoder layers.
``input_specs()`` provides precomputed frame embeddings [B, 1500, d] (the
mel + 2xconv frontend output length for 30s audio).
long_500k is SKIPPED for this arch (bounded decoder context; see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    encdec=True,
    num_layers=24,                    # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    num_frames=1500,
    rope_theta=10_000.0,              # unused (learned positions)
    tie_embeddings=True,
    source="arXiv:2212.04356",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, encoder_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, vocab_size=512, num_frames=32,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
