"""Shared benchmark plumbing: each bench_*.py exposes run(fast=True) ->
list[dict] rows; benchmarks/run.py times them and emits CSV."""
from __future__ import annotations

import jax

from repro.configs.base import FedConfig
from repro.fl.simulator import run_federation
from repro.models.small import SMALL_MODELS, make_loss_fn


def fed_suite(dataset_fed, model_name, fed_kwargs, *, selections=("fedalign",
              "priority_only", "all"), seeds=(0,), eval_every=5, init_seed=42):
    """Run the three paper baselines over seeds; return summary rows."""
    init_fn, apply_fn = SMALL_MODELS[model_name]
    loss_fn = make_loss_fn(apply_fn)
    import sys, time
    rows = []
    for sel in selections:
        for seed in seeds:
            t0 = time.time()
            print(f"#   fed_suite: {model_name} sel={sel} seed={seed} "
                  f"rounds={fed_kwargs['rounds']} ...", file=sys.stderr, flush=True)
            fed = FedConfig(**{**fed_kwargs, "selection": sel, "seed": seed})
            hist = run_federation(loss_fn, init_fn(jax.random.PRNGKey(init_seed)),
                                  fed, dataset_fed, eval_every=eval_every)
            print(f"#   ... done in {time.time()-t0:.0f}s", file=sys.stderr, flush=True)
            s = hist.summary()
            rows.append({
                "selection": sel, "seed": seed,
                "final_acc": round(s["final_acc"], 4),
                "best_acc": round(s["best_acc"], 4),
                "mean_included": round(s["mean_included"], 2),
                "final_loss": round(s["final_loss"], 4),
                "acc_curve": [round(a, 4) for a in hist.test_acc],
            })
    return rows


def post_warmup_rounds_to(acc_target, acc_curve, eval_every):
    """Convergence-speed proxy: evals until reaching the target accuracy."""
    for i, a in enumerate(acc_curve):
        if a >= acc_target:
            return i * eval_every
    return None
