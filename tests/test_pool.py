"""Candidate-pool population scaling (``fed.candidate_pool``).

Pins (1) the parity contract — ``candidate_pool=0`` (disabled) and
``candidate_pool >= C`` are BIT-identical to the dense round for every
strategy on every backend, and for the sharded pod rounds; (2) the
scatter contract — a client outside the round's pool keeps its backlog /
EMA / error-feedback state leaves bit-identical through the round,
including under ``scan_async`` mid-flight checkpoint/resume; (3) the
sampler — priority clients are always in-pool, weighting tilts are
sampled from the round PRNG stream only; (4) the unified config API —
``validate_config`` fan-in, the generic ``utils.Registry`` behind every
registry, and the shared launcher CLI surface."""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, validate_config
from repro.configs.cli import add_fed_args, fed_from_args
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.fl.simulator import (load_federation_state, run_federation,
                                save_federation_state)
from repro.models.small import SMALL_MODELS, make_loss_fn
from repro.utils import Registry

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=11, n_priority=3, n_nonpriority=9,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))

STRATEGIES = sorted(engine.STRATEGIES)
POOL = 6                                    # 3 priority + 3 sampled of 9


def _run(fed, backend, r=2, seed=1, state=None, rounds=1):
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    if state is None:
        state = engine.init_state(PARAMS, fed, C)
    for i in range(rounds):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(seed + i),
                          jnp.int32(r + i))
    return state, stats


def _assert_bit_identical(a, b):
    (sa, ta), (sb, tb) = a, b
    np.testing.assert_array_equal(np.asarray(ta["gates"]),
                                  np.asarray(tb["gates"]))
    for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert la.dtype == lb.dtype
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _base(**kw):
    base = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                epsilon=0.5, warmup_frac=0.0, align_stat="loss")
    base.update(kw)
    return FedConfig(**base)


# ================================================ disabled / >= C parity
@pytest.mark.parametrize("backend", engine.BACKENDS)
@pytest.mark.parametrize("selection", STRATEGIES)
def test_pool_disabled_and_full_are_dense(selection, backend):
    """candidate_pool=0 and candidate_pool >= C take the dense python
    branch: the round is LITERALLY the legacy trace, so every state leaf
    and the gates are bit-identical — per strategy, per backend."""
    fed = _base(selection=selection, topk=2, sim_threshold=0.0,
                welfare_floor=0.05)
    dense = _run(fed, backend)
    _assert_bit_identical(dense, _run(fed.replace(candidate_pool=0), backend))
    _assert_bit_identical(dense, _run(fed.replace(candidate_pool=C), backend))
    _assert_bit_identical(dense,
                          _run(fed.replace(candidate_pool=C + 7), backend))


def test_pool_parity_with_server_optimizer_and_cohort():
    """The dense pin survives composition: adam moments + max_cohort +
    participation masks, three threaded rounds."""
    fed = _base(server_opt="adam", server_lr=0.5, max_cohort=8,
                participation=0.7, epsilon=1e9)
    dense = _run(fed, "vmap_spatial", rounds=3)
    pooled = _run(fed.replace(candidate_pool=C), "vmap_spatial", rounds=3)
    _assert_bit_identical(dense, pooled)


# ================================================ scatter correctness
@pytest.mark.parametrize("backend", ["vmap_spatial", "scan_temporal"])
def test_out_of_pool_client_state_untouched(backend):
    """A client outside the round's pool must end the round with
    bit-identical backlog / util_ema / incl_ema rows."""
    fed = _base(candidate_pool=POOL, epsilon=1e9)
    state0 = engine.init_state(PARAMS, fed, C)
    # age the ledgers so "unchanged" is not just "still zero"
    state0 = state0.replace(
        backlog=jnp.arange(C, dtype=state0.backlog.dtype),
        util_ema=jnp.linspace(0.1, 0.9, C).astype(state0.util_ema.dtype),
        incl_ema=jnp.linspace(0.9, 0.1, C).astype(state0.incl_ema.dtype))
    state, stats = _run(fed, backend, state=state0)
    pool_idx = np.asarray(stats["pool_idx"])
    assert pool_idx.shape == (POOL,)
    out = np.setdiff1d(np.arange(C), pool_idx)
    assert out.size == C - POOL
    for name in ("backlog", "util_ema", "incl_ema"):
        np.testing.assert_array_equal(np.asarray(getattr(state, name))[out],
                                      np.asarray(getattr(state0, name))[out])
    # stats scatter back to dense [C] rows: out-of-pool slots are zero
    for name in ("local_losses", "gates"):
        np.testing.assert_array_equal(np.asarray(stats[name])[out], 0.0)


def test_out_of_pool_ef_accum_untouched():
    """With a lossy wire codec + error feedback, only in-pool clients'
    residual accumulator rows may move."""
    fed = _base(candidate_pool=POOL, epsilon=1e9, wire_codec="int8",
                error_feedback=True, lr=0.2)
    state0 = engine.init_state(PARAMS, fed, C)
    state, stats = _run(fed, "vmap_spatial", state=state0, seed=4)
    out = np.setdiff1d(np.arange(C), np.asarray(stats["pool_idx"]))
    for l0, l1 in zip(jax.tree.leaves(state0.ef_accum),
                      jax.tree.leaves(state.ef_accum)):
        np.testing.assert_array_equal(np.asarray(l1)[out],
                                      np.asarray(l0)[out])
    # ...and at least one in-pool row accrued residual (int8 is lossy)
    moved = sum(float(np.abs(np.asarray(l1) - np.asarray(l0)).sum())
                for l0, l1 in zip(jax.tree.leaves(state0.ef_accum),
                                  jax.tree.leaves(state.ef_accum)))
    assert moved > 0.0


def test_priority_always_in_pool():
    """Every round's pool contains every priority client, whatever the
    weighting; non-priority membership varies with the round key."""
    pri = np.nonzero(np.asarray(PM))[0]
    seen = set()
    for weighting in ("uniform", "backlog", "ema"):
        fed = _base(candidate_pool=POOL, pool_weighting=weighting,
                    epsilon=1e9)
        for seed in range(4):
            _, stats = _run(fed, "vmap_spatial", seed=seed, r=seed)
            pool_idx = np.asarray(stats["pool_idx"])
            assert set(pri) <= set(pool_idx.tolist())
            np.testing.assert_array_equal(pool_idx, np.sort(pool_idx))
            seen.add(tuple(pool_idx.tolist()))
    assert len(seen) > 1                    # the sampler actually samples


def test_pool_scan_async_mid_flight_resume(tmp_path):
    """Interrupt a POOLED scan_async run with cohorts still in flight;
    the resumed run must be bit-identical to the uninterrupted one —
    pool draws included (the pool key rides the carried PRNG stream)."""
    path = str(tmp_path / "pool_async.msgpack")
    fed = _base(candidate_pool=POOL, rounds=8, epsilon=0.3, lr=0.1,
                batch_size=32, server_opt="yogi", server_lr=0.3,
                backend="scan_async", async_depth=2, staleness_decay=0.9)
    full = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=4)

    half = run_federation(LOSS, PARAMS, fed.replace(rounds=5), FEDN,
                          eval_every=4)
    assert float(jnp.sum(half.state.inflight["valid"])) > 0.0
    save_federation_state(path, half.state, half.rng, 5, fed=fed)
    like = engine.init_state(PARAMS, fed, C)
    state, rng, step = load_federation_state(path, like, fed=fed)
    assert step == 5
    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=4,
                             state=state, rng=rng, start_round=step)
    for a, b in zip(jax.tree.leaves(full.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pool_fingerprint_guards_resume(tmp_path):
    """Resuming a pooled checkpoint under different pool knobs would
    advance different clients' rows from the resume round on — the
    fingerprint catches the mismatch."""
    path = str(tmp_path / "pool_fp.msgpack")
    fed = _base(candidate_pool=POOL, epsilon=1e9)
    state, _ = _run(fed, "vmap_spatial")
    save_federation_state(path, state, jax.random.PRNGKey(3), 1, fed=fed)
    like = engine.init_state(PARAMS, fed, C)
    with pytest.raises(ValueError, match="candidate_pool"):
        load_federation_state(path, like, fed=fed.replace(candidate_pool=0))
    with pytest.raises(ValueError, match="pool_weighting"):
        load_federation_state(
            path, like, fed=fed.replace(pool_weighting="backlog"))
    # matching knobs load clean
    got, _, step = load_federation_state(path, like, fed=fed)
    assert step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ================================================ pod rounds
def _pod_fixture():
    from repro.configs import get_smoke
    from repro.launch.train import build_batches
    from repro.data.tokens import make_token_federation
    from repro.models import get_model
    cfg = get_smoke("qwen1_5_0_5b").replace(remat=False)
    model = get_model(cfg)
    rng = np.random.default_rng(0)
    fd = make_token_federation(seed=0, vocab=cfg.vocab_size, n_clients=4,
                               n_priority=2, seq_len=32,
                               tokens_per_client=33 * 8)
    batch = build_batches(cfg, fd, clients=4, per_client=2, seq=32, rng=rng)
    return model, batch


@pytest.mark.parametrize("make", ["make_spatial_round", "make_temporal_round"])
def test_pod_round_pool_parity_and_invariance(make):
    """Pod rounds: candidate_pool >= C is bit-identical to dense, and a
    pooled P < C round leaves out-of-pool client rows untouched (pool key
    comes from the named deterministic per-round stream)."""
    from repro.fl import sharded
    model, batch = _pod_fixture()
    mk = getattr(sharded, make)
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05)
    state = engine.init_state(model.init(jax.random.PRNGKey(0)), fed, 4)

    sd, td = jax.jit(mk(model, fed, 4))(state, batch)
    sf, tf = jax.jit(mk(model, fed.replace(candidate_pool=4), 4))(state, batch)
    for a, b in zip(jax.tree.leaves(sd), jax.tree.leaves(sf)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(td["gates"]),
                                  np.asarray(tf["gates"]))

    fedp = fed.replace(candidate_pool=3)
    state0 = state.replace(backlog=jnp.arange(4, dtype=state.backlog.dtype))
    sp, tp = jax.jit(mk(model, fedp, 4))(state0, batch)
    pool_idx = np.asarray(tp["pool_idx"])
    assert pool_idx.shape == (3,)
    assert {0, 1} <= set(pool_idx.tolist())            # priority in-pool
    out = np.setdiff1d(np.arange(4), pool_idx)
    for name in ("backlog", "util_ema", "incl_ema"):
        np.testing.assert_array_equal(np.asarray(getattr(sp, name))[out],
                                      np.asarray(getattr(state0, name))[out])
    # same round twice -> same pool (the named stream is deterministic)
    _, tp2 = jax.jit(mk(model, fedp, 4))(state0, batch)
    np.testing.assert_array_equal(pool_idx, np.asarray(tp2["pool_idx"]))


# ================================================ unified config API
def test_validate_config_runs_every_hook():
    """One entry point covers aggregator, async, clock, codec AND pool
    validation."""
    validate_config(_base())                            # clean config: no-op
    with pytest.raises(ValueError, match="unknown aggregator"):
        validate_config(_base(aggregator="nope"))
    with pytest.raises(ValueError, match="min_lag"):
        validate_config(_base(backend="scan_async", async_depth=2,
                              async_mode="ready", min_lag=5))
    with pytest.raises(ValueError, match="pool_weighting"):
        validate_config(_base(candidate_pool=POOL, pool_weighting="nope"))
    with pytest.raises(ValueError, match="smaller than num_priority"):
        validate_config(_base(candidate_pool=2))


def test_deprecated_check_aliases_still_work():
    """The old per-subsystem check_* names stay importable and callable."""
    from repro.core.aggregation import (check_aggregator_config,
                                        check_codec_config)
    from repro.fl.engine import check_async_config, check_clock_config
    fed = _base()
    for check in (check_aggregator_config, check_codec_config,
                  check_async_config, check_clock_config):
        check(fed)
    with pytest.raises(ValueError):
        check_aggregator_config(_base(aggregator="nope"))


def test_registry_error_texts_and_aliases():
    """Every registry rides utils.Registry yet keeps its legacy naming:
    error texts enumerate registrations, aliases pin the legacy synonyms."""
    from repro.core import aggregation
    with pytest.raises(ValueError, match=r"unknown selection strategy 'x'"):
        engine.get_strategy("x")
    with pytest.raises(ValueError, match=r"unknown failure model 'x'"):
        engine.get_failure_model("x")
    with pytest.raises(ValueError, match=r"unknown aggregator 'x'"):
        aggregation.get_aggregator("x")
    with pytest.raises(ValueError, match=r"unknown wire codec 'x'"):
        aggregation.get_wire_codec("x")
    assert engine.resolve_failure_model(None) == "none"
    assert engine.resolve_failure_model("") == "none"
    assert aggregation.resolve_aggregator(None) == "mean"
    assert aggregation.resolve_wire_codec("none") == "identity"
    assert aggregation.resolve_server_opt(None) == "sgd"
    assert "fedalign" in engine.STRATEGIES.names()
    assert "mean" in aggregation.AGGREGATORS.names()


def test_registry_rejects_duplicates_and_stamps_attrs():
    reg = Registry("widget", aliases={None: "a"})

    @reg.register("a", color="red")
    def widget_a():
        return "a"

    assert reg.lookup(None) is widget_a and widget_a.color == "red"
    with pytest.raises(ValueError, match="duplicate widget 'a'"):
        reg.register("a")(lambda: None)
    with pytest.raises(ValueError, match="unknown widget 'b'"):
        reg.lookup("b")
    assert reg.names() == ["a"]


# ================================================ shared CLI surface
def _fed_flag_set(parser):
    return {s for a in parser._actions for s in a.option_strings} \
        - {"-h", "--help"}


def test_launchers_share_the_federation_flag_set():
    """train and dryrun must expose the SAME federation flags — the whole
    point of configs.cli is that the two CLIs can no longer drift."""
    from repro.launch import train
    saved = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch import dryrun
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    ref = _fed_flag_set(add_fed_args(argparse.ArgumentParser()))
    assert {"--candidate-pool", "--pool-weighting", "--aggregator",
            "--async-depth", "--wire-codec"} <= ref
    assert ref <= _fed_flag_set(train.build_parser())
    assert ref <= _fed_flag_set(dryrun.build_parser())


def test_fed_from_args_default_is_empty():
    """A default command line produces NO overrides: the launcher's config
    stays literally untouched (bit-identical trace guarantee)."""
    ap = add_fed_args(argparse.ArgumentParser())
    assert fed_from_args(ap.parse_args([])) == {}
    kw = fed_from_args(ap.parse_args(
        ["--candidate-pool", "128", "--pool-weighting", "backlog"]))
    assert kw == {"candidate_pool": 128, "pool_weighting": "backlog"}
    fed = FedConfig(**kw)
    assert fed.candidate_pool == 128 and fed.pool_weighting == "backlog"
