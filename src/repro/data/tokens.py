"""Federated token streams for the LM-scale architectures.

Synthetic language modelling data with *controllable client alignment*:
each client draws from a Zipf-like unigram-with-bigram-structure source;
priority clients share one source distribution, non-priority clients
interpolate between the priority source and an independent one with a
per-client misalignment level — giving FedALIGN something real to select
on at LM scale.
"""
from __future__ import annotations

import numpy as np


def _zipf_probs(vocab, s=1.1, rng=None, perm=True):
    p = 1.0 / np.arange(1, vocab + 1) ** s
    p /= p.sum()
    if perm and rng is not None:
        p = p[rng.permutation(vocab)]
    return p


def _markov_stream(rng, n, vocab, unigram, shift):
    """Cheap bigram structure: next-token dist = unigram rolled by a
    source-specific shift of the previous token (deterministic mixing)."""
    toks = rng.choice(vocab, size=n, p=unigram)
    prev = np.roll(toks, 1)
    mix = (prev * shift) % vocab
    use_mix = rng.random(n) < 0.3
    return np.where(use_mix, mix, toks).astype(np.int32)


def make_token_federation(seed=0, vocab=512, n_clients=8, n_priority=4,
                          tokens_per_client=8192, seq_len=128,
                          misalign_max=1.0, misalign_skew=1.5):
    """Returns dict with tokens [C, n_seq, seq_len+1] (input+shifted label),
    priority_mask, weights, misalignment levels."""
    rng = np.random.default_rng(seed)
    pri_unigram = _zipf_probs(vocab, rng=rng)
    alt_unigram = _zipf_probs(vocab, rng=rng)
    n_seq = tokens_per_client // (seq_len + 1)
    C = n_clients

    streams, levels = [], []
    for c in range(C):
        if c < n_priority:
            lvl = 0.0
            unigram = pri_unigram
            shift = 3
        else:
            rank = (c - n_priority) / max(C - n_priority - 1, 1)
            lvl = min(1.0, misalign_max * rank ** misalign_skew)
            unigram = (1 - lvl) * pri_unigram + lvl * alt_unigram
            shift = 3 if lvl < 0.5 else 7
        streams.append(_markov_stream(rng, n_seq * (seq_len + 1), vocab,
                                      unigram, shift).reshape(n_seq, seq_len + 1))
        levels.append(lvl)

    priority_mask = np.zeros(C, bool)
    priority_mask[:n_priority] = True
    weights = np.full(C, 1.0 / n_priority, np.float32)
    # held-out global (priority-source) eval stream
    test = _markov_stream(rng, 64 * (seq_len + 1), vocab, pri_unigram, 3
                          ).reshape(64, seq_len + 1)
    return dict(tokens=np.stack(streams), priority_mask=priority_mask,
                weights=weights, misalignment=np.asarray(levels, np.float32),
                test_tokens=test)
