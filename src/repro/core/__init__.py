from repro.core.alignment import (epsilon_at, global_loss_from_locals,  # noqa: F401
                                  inclusion_gates)
from repro.core.aggregation import (SERVER_OPTIMIZERS, aggregate_clients,  # noqa: F401
                                    aggregate_delta, aggregate_updates,
                                    apply_server_opt, get_server_optimizer,
                                    register_server_optimizer,
                                    server_optimizer)
from repro.core.round import init_state, make_round_fn  # noqa: F401
