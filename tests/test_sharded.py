"""Pod-scale round-step semantics on the single host device: spatial and
temporal engines must agree with each other, thread the same
FederationState, and train the model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import FedConfig
from repro.fl import engine, sharded
from repro.launch.train import build_batches, run as train_run
from repro.data.tokens import make_token_federation
from repro.models import get_model

CFG = get_smoke("qwen1_5_0_5b").replace(remat=False)
MODEL = get_model(CFG)
FED = FedConfig(local_epochs=2, epsilon=1e9, lr=0.05)


def _batch(C=4, b=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    fd = make_token_federation(seed=seed, vocab=CFG.vocab_size, n_clients=C,
                               n_priority=2, seq_len=S,
                               tokens_per_client=(S + 1) * 8)
    return build_batches(CFG, fd, clients=C, per_client=b, seq=S, rng=rng)


def _state(fed, C=4, seed=0):
    return engine.init_state(MODEL.init(jax.random.PRNGKey(seed)), fed, C)


def test_spatial_round_trains():
    step = jax.jit(sharded.make_spatial_round(MODEL, FED, 4))
    state = _state(FED)
    batch = _batch()
    s1, t1 = step(state, batch)
    s2, t2 = step(s1, batch)
    assert float(t2["server_loss"]) < float(t1["server_loss"])
    assert np.all(np.asarray(t1["gates"]) == 1.0)      # eps = inf


def test_spatial_equals_temporal():
    """Same federation semantics whether clients are space- or
    time-multiplexed (weights equal => identical aggregation), including
    the carried state (backlog, EMAs)."""
    batch = _batch()
    state = _state(FED)
    ss, ts = jax.jit(sharded.make_spatial_round(MODEL, FED, 4))(state, batch)
    st, tt = jax.jit(sharded.make_temporal_round(MODEL, FED, 4))(state, batch)
    np.testing.assert_allclose(np.asarray(ts["local_losses"]),
                               np.asarray(tt["local_losses"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(st)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-5)


def test_gating_excludes_misaligned():
    fed = FedConfig(local_epochs=1, epsilon=0.05, lr=0.05)
    step = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))
    state = _state(fed)
    batch = _batch()
    # corrupt the last client's labels to force misalignment after warm start
    bad = jax.random.randint(jax.random.PRNGKey(9),
                             batch["clients"]["labels"][3:].shape, 0,
                             CFG.vocab_size)
    batch["clients"]["labels"] = batch["clients"]["labels"].at[3:].set(bad)
    # train until losses separate; the corrupted client must eventually
    # fall outside the eps band while priority gates stay 1
    excluded = False
    for _ in range(10):
        state, stats = step(state, batch)
        gates = np.asarray(stats["gates"])
        assert gates[0] == 1.0 and gates[1] == 1.0      # priority always
        if gates[3] == 0.0:
            excluded = True
            break
    assert excluded, np.asarray(stats["local_losses"])


def test_round_idx_drives_eps_schedule():
    """The sharded rounds follow the eps schedule instead of freezing it at
    t=0: a decaying eps admits everyone early and gates non-priority
    clients out in late rounds — on BOTH execution modes."""
    fed = FedConfig(local_epochs=1, epsilon=0.5, lr=0.05,
                    epsilon_schedule="exp", epsilon_decay=0.9)
    batch = _batch()
    state = _state(fed)
    for make in (sharded.make_spatial_round, sharded.make_temporal_round):
        step = jax.jit(make(MODEL, fed, 4))
        _, s0 = step(state, batch, jnp.int32(0))
        _, s9 = step(state, batch, jnp.int32(9))
        assert np.asarray(s0["gates"]).sum() == 4.0          # eps_0 = 0.5
        late = np.asarray(s9["gates"])                        # eps_9 ~ 2e-10
        assert np.all(late[:2] == 1.0)                        # priority kept
        assert late[2:].sum() == 0.0, late


def test_spatial_cohort_matches_dense_and_temporal():
    """Gather-train (max_cohort) spatial round and cond-skip temporal round
    both reproduce the dense spatial round, including when the eps schedule
    has gated clients out (cohort padding slots / skipped scan iterations)."""
    fed = FedConfig(local_epochs=2, epsilon=0.5, lr=0.05,
                    epsilon_schedule="exp", epsilon_decay=0.5)
    batch = _batch()
    state = _state(fed)
    for r in (0, 6):
        sd, td = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))(
            state, batch, jnp.int32(r))
        sc, tc = jax.jit(sharded.make_spatial_round(
            MODEL, fed.replace(max_cohort=4), 4))(state, batch, jnp.int32(r))
        st, tt = jax.jit(sharded.make_temporal_round(MODEL, fed, 4))(
            state, batch, jnp.int32(r))
        np.testing.assert_array_equal(np.asarray(td["gates"]),
                                      np.asarray(tc["gates"]))
        np.testing.assert_array_equal(np.asarray(td["gates"]),
                                      np.asarray(tt["gates"]))
        for a, b in zip(jax.tree.leaves(sd), jax.tree.leaves(sc)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-5, rtol=5e-5)
        for a, b in zip(jax.tree.leaves(sd), jax.tree.leaves(st)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("server_opt", ["momentum", "adam", "yogi"])
def test_sharded_server_optimizers_thread_state(server_opt):
    """Two chained rounds with a stateful server optimizer: moments must
    advance (t counter / non-zero m) and spatial==temporal still holds."""
    fed = FED.replace(server_opt=server_opt, server_lr=0.5)
    batch = _batch()
    state = _state(fed)
    sp = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))
    tp = jax.jit(sharded.make_temporal_round(MODEL, fed, 4))
    s1, _ = sp(state, batch, jnp.int32(0))
    s2, _ = sp(s1, batch, jnp.int32(1))
    if server_opt in ("adam", "yogi"):
        assert int(s2.opt_state["t"]) == 2
    m_norm = sum(float(jnp.sum(jnp.abs(l)))
                 for l in jax.tree.leaves(s2.opt_state["m"]))
    assert m_norm > 0.0
    t1, _ = tp(state, batch, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(t1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-5)


def test_spatial_cohort_overflow_keeps_best_matched():
    """K < #included: the spatial gather drops the worst loss-matched
    non-priority clients, reports the effective gates, and books the
    dropped client into the backlog ledger."""
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05, max_cohort=3)
    step = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))
    state, stats = step(_state(fed), _batch())
    gates = np.asarray(stats["gates"])
    assert gates.sum() == 3.0
    assert np.all(gates[:2] == 1.0)                           # priority kept
    # the surviving non-priority client is the better loss-matched one
    losses = np.asarray(stats["local_losses"])
    server = float(stats["server_loss"])
    kept, dropped = (2, 3) if gates[2] == 1.0 else (3, 2)
    assert abs(losses[kept] - server) <= abs(losses[dropped] - server)
    np.testing.assert_array_equal(
        np.asarray(state.backlog),
        np.asarray([0, 0, 0, 0]) + (np.arange(4) == dropped))


def test_temporal_grad_sim_streams_sketches():
    """The temporal (FSDP) round supports grad_sim via CountSketch scoring:
    its gates match the spatial round scored on the SAME sketches, and the
    aggregated params agree across the modes."""
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05,
                    selection="grad_sim", sim_threshold=0.0,
                    grad_sim_sketch=True, sketch_dim=512)
    batch = _batch()
    state = _state(fed)
    st, tt = jax.jit(sharded.make_temporal_round(MODEL, fed, 4))(state, batch)
    ss, ts = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))(state, batch)
    gates = np.asarray(tt["gates"])
    assert set(np.unique(gates)).issubset({0.0, 1.0})
    assert np.all(gates[:2] == 1.0)                           # priority in
    np.testing.assert_array_equal(gates, np.asarray(ts["gates"]))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(ss)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-5)


def test_temporal_grad_sim_requires_sketch_opt_in():
    """Exact delta cosines don't exist for streamed clients: without the
    explicit grad_sim_sketch opt-in the temporal round refuses instead of
    silently gating differently from the spatial round."""
    fed = FedConfig(local_epochs=1, epsilon=1e9, selection="grad_sim")
    with pytest.raises(ValueError, match="grad_sim_sketch"):
        sharded.make_temporal_round(MODEL, fed, 4)


def test_pod_rounds_identity_codec_knobs_inert():
    """Both pod rounds under the identity wire: the codec-rate and
    error-feedback knobs must not perturb a single bit of the round (the
    codec-off branch is literally the legacy trace) and no ef_accum
    leaves join the state."""
    batch = _batch()
    state = _state(FED)
    knobbed = FED.replace(error_feedback=False, codec_topk_frac=0.5,
                          codec_sketch_dim=7)
    for make in (sharded.make_spatial_round, sharded.make_temporal_round):
        sa, ta = jax.jit(make(MODEL, FED, 4))(state, batch)
        sb, tb = jax.jit(make(MODEL, knobbed, 4))(state, batch)
        assert sa.ef_accum == () and sb.ef_accum == ()
        np.testing.assert_array_equal(np.asarray(ta["gates"]),
                                      np.asarray(tb["gates"]))
        for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pod_rounds_compressed_wire_ef_advances():
    """Both pod rounds run the int8 wire end to end: finite server loss
    and a non-zero EF accumulator after one round (the temporal round
    must switch to the gathered path — its streamed (num, den) mean carry
    never materializes the per-client rows a codec encodes)."""
    fed = FED.replace(wire_codec="int8")
    batch = _batch()
    state = _state(fed)
    for make in (sharded.make_spatial_round, sharded.make_temporal_round):
        s1, t1 = jax.jit(make(MODEL, fed, 4))(state, batch)
        assert np.isfinite(float(t1["server_loss"]))
        total = sum(float(jnp.sum(jnp.abs(e)))
                    for e in jax.tree.leaves(s1.ef_accum))
        assert total > 0.0


def test_sharded_cohort_select_is_engine_cohort_select():
    """The pod rounds must not grow their own gather copy: the overflow /
    backlog policy lives in engine.cohort_select ONLY."""
    import inspect
    src = inspect.getsource(sharded)
    assert "engine.cohort_select" in src
    assert "argsort" not in src and "lexsort" not in src


def test_train_driver_end_to_end():
    params, hist = train_run(arch="qwen1.5-0.5b", smoke=True, rounds=3,
                             clients=4, n_priority=2, per_client=2, seq=32,
                             verbose=False)
    assert hist[-1]["server_loss"] < hist[0]["server_loss"] + 0.5
