"""Round-pipeline benchmark: dense train-everyone vs gate-before-train
cohort execution (``FedConfig.max_cohort``), plus the server-optimizer
ablation (sgd vs momentum/adam/yogi on the aggregated delta) and the
FederationState threading overhead of the scanned driver.

Times full engine rounds at C=64 clients on a small MLP across inclusion
rates, reporting rounds/sec and the wasted-local-epoch fraction (clients
that paid E local epochs but were dropped at aggregation). Every timing
pair is also a correctness pair: the cohort round must reproduce the dense
round exactly before its timing row is emitted, and the state-threading
row ASSERTS that carrying the full FederationState through a lax.scan of
rounds costs <5% over a params-only carry at ``max_cohort`` off.

    PYTHONPATH=src python benchmarks/bench_round.py [--full] [--out PATH]

emits ``BENCH_round.json`` (uploaded as the BENCH_round CI artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import init_mlp2, make_loss_fn, mlp2_apply

CLIENTS = 64
N_PRIORITY = 2
SCAN_ROUNDS = 8          # rounds per scanned program in the overhead row


def _time_round(fn, state, data, pm, w, iters):
    key = jax.random.PRNGKey(0)
    out = fn(state, data, pm, w, key, jnp.int32(1))
    jax.block_until_ready(out)                       # compile + warm-up
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(state, data, pm, w, key, jnp.int32(1))
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def _time_scan(fn, *args, reps=3):
    """Best-of-reps wall time of an already-jitted scanned program."""
    out = fn(*args)
    jax.block_until_ready(out)                       # compile + warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _setup(samples):
    fedn = make_synth_federation(seed=0, n_priority=N_PRIORITY,
                                 n_nonpriority=CLIENTS - N_PRIORITY,
                                 samples_per_client=samples)
    data = {"x": jnp.asarray(fedn.x), "y": jnp.asarray(fedn.y)}
    pm = jnp.asarray(fedn.priority_mask)
    w = jnp.asarray(fedn.weights)
    init_fn = lambda key: init_mlp2(key, in_dim=60, hidden=256, num_classes=10)
    loss_fn = make_loss_fn(mlp2_apply)
    params = init_fn(jax.random.PRNGKey(42))
    return data, pm, w, loss_fn, params


def run_cohort(fast=True):
    samples = 64 if fast else 256
    iters = 3 if fast else 8
    data, pm, w, loss_fn, params = _setup(samples)

    rows = []
    for rate in (0.25, 0.5, 1.0):
        k = round(CLIENTS * rate)
        # topk_align with a huge eps band pins inclusion to exactly k
        # (priority + the k - P best-matched non-priority clients)
        base = FedConfig(num_clients=CLIENTS, num_priority=N_PRIORITY,
                         rounds=100, local_epochs=5, epsilon=1e9,
                         warmup_frac=0.0, align_stat="loss",
                         selection="topk_align", topk=k - N_PRIORITY,
                         batch_size=32, seed=0)
        state = engine.init_state(params, base, CLIENTS)
        dense_fn = jax.jit(engine.make_round_fn(loss_fn, base))
        cohort_fn = jax.jit(engine.make_round_fn(loss_fn,
                                                 base.replace(max_cohort=k)))
        sec_d, (std, sd) = _time_round(dense_fn, state, data, pm, w, iters)
        sec_c, (stc, sc) = _time_round(cohort_fn, state, data, pm, w, iters)

        # correctness before timing is reported: identical gates + params
        np.testing.assert_array_equal(np.asarray(sd["gates"]),
                                      np.asarray(sc["gates"]))
        for a, b in zip(jax.tree.leaves(std.params), jax.tree.leaves(stc.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)

        included = float(np.asarray(sd["gates"]).sum())
        for path, sec, trained in (("dense", sec_d, CLIENTS),
                                   ("cohort", sec_c, k)):
            rows.append({
                "path": path,
                "clients": CLIENTS,
                "max_cohort": 0 if path == "dense" else k,
                "target_inclusion_rate": rate,
                "measured_inclusion_rate": round(included / CLIENTS, 4),
                "clients_trained": trained,
                "wasted_local_epoch_frac": round((trained - included)
                                                 / trained, 4),
                "sec_per_round": round(sec, 5),
                "rounds_per_sec": round(1.0 / sec, 2),
                "speedup_vs_dense": round(sec_d / sec, 2),
            })
    return rows


def run_server_opt(fast=True):
    """Server-optimizer ablation (max_cohort off, dense rounds) + the
    FederationState threading-overhead assertion.

    The overhead baseline runs the SAME round math inside the same
    lax.scan, but only the params cross the round boundary (opt moments /
    backlog / EMAs are re-fed from the initial state every round), so the
    delta between the two programs is exactly the cost of threading the
    full state through the scan carry."""
    samples = 64 if fast else 256
    data, pm, w, loss_fn, params = _setup(samples)
    base = FedConfig(num_clients=CLIENTS, num_priority=N_PRIORITY,
                     rounds=100, local_epochs=2, epsilon=1e9,
                     warmup_frac=0.0, align_stat="loss", batch_size=32,
                     seed=0, max_cohort=0)

    rows = []
    sec_by_opt = {}
    sgd_round_fn = sgd_state0 = None
    for opt in ("sgd", "momentum", "adam", "yogi"):
        fed = base.replace(server_opt=opt, server_lr=1.0)
        round_fn = engine.make_round_fn(loss_fn, fed)
        state0 = engine.init_state(params, fed, CLIENTS)
        if opt == "sgd":
            sgd_round_fn, sgd_state0 = round_fn, state0

        @jax.jit
        def scan_state(state, rng, rf=round_fn):
            def body(carry, i):
                st, key = carry
                key, rkey = jax.random.split(key)
                st, _ = rf(st, data, pm, w, rkey, i)
                return (st, key), None
            (state, rng), _ = jax.lax.scan(
                body, (state, rng), jnp.arange(SCAN_ROUNDS, dtype=jnp.int32))
            return state

        sec = _time_scan(scan_state, state0, jax.random.PRNGKey(0))
        sec_by_opt[opt] = sec
        rows.append({
            "path": f"server_opt:{opt}",
            "clients": CLIENTS,
            "max_cohort": 0,
            "scan_rounds": SCAN_ROUNDS,
            "sec_per_round": round(sec / SCAN_ROUNDS, 5),
            "rounds_per_sec": round(SCAN_ROUNDS / sec, 2),
            "slowdown_vs_sgd": None,   # filled below
        })
    for r in rows:
        r["slowdown_vs_sgd"] = round(
            sec_by_opt[r["path"].split(":")[1]] / sec_by_opt["sgd"], 3)

    # --- state-threading overhead: full FederationState carry vs params-only.
    # The full-state measurement IS the sgd ablation row above (same
    # round_fn, same scan) — only the params-only baseline is timed anew.
    round_fn, state0 = sgd_round_fn, sgd_state0

    @jax.jit
    def scan_params_only(p, rng):
        def body(carry, i):
            pp, key = carry
            key, rkey = jax.random.split(key)
            st, _ = round_fn(state0.replace(params=pp), data, pm, w, rkey, i)
            return (st.params, key), None
        (p, rng), _ = jax.lax.scan(
            body, (p, rng), jnp.arange(SCAN_ROUNDS, dtype=jnp.int32))
        return p

    sec_full = sec_by_opt["sgd"]
    sec_params = _time_scan(scan_params_only, params, jax.random.PRNGKey(0))
    overhead = sec_full / sec_params - 1.0
    rows.append({
        "path": "state_threading_overhead",
        "clients": CLIENTS,
        "max_cohort": 0,
        "scan_rounds": SCAN_ROUNDS,
        "sec_per_round_full_state": round(sec_full / SCAN_ROUNDS, 5),
        "sec_per_round_params_only": round(sec_params / SCAN_ROUNDS, 5),
        "overhead_frac": round(overhead, 4),
    })
    assert overhead < 0.05, (
        f"FederationState threading added {overhead:.1%} to the scanned "
        f"round (budget: <5% at max_cohort off)")
    return rows


def run(fast=True):
    return run_cohort(fast=fast) + run_server_opt(fast=fast)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_round.json")
    args = ap.parse_args()
    rows = run(fast=not args.full)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    for r in rows:
        print(r)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
