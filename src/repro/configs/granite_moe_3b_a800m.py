"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]

32L d_model=1536 24H (GQA kv=8) d_ff=512(per expert) vocab=49155,
MoE 40e top-8, no shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=True,
    num_experts=40,
    top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (family card)",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
        d_ff=128, moe_d_ff=128, num_experts=4, top_k=2, vocab_size=512,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
