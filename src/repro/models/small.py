"""The paper's own experiment models (App. B.1):

  * logreg  — 784x10 logistic regression               (FMNIST)
  * mlp2    — 784-200-200-47 two-layer network         (balanced EMNIST)
  * cnn     — 2xconv5x5 (32,64ch) + FC(512x128) + 128x10, batchnorm-free
              variant with ReLU + Kaiming init          (CIFAR-10)
  * synth_logreg — 60x10 logistic regression            (SYNTH(a,b))

All return per-example logits; ``loss_fn`` is softmax cross-entropy, the
loss the paper's FedALIGN alignment statistic uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import fold_in_name


def _kaiming(key, shape):
    fan_in = int(jnp.prod(jnp.asarray(shape[:-1])))
    return jax.random.normal(key, shape, jnp.float32) * (2.0 / fan_in) ** 0.5


# ------------------------------------------------------------------- logistic
def init_logreg(key, in_dim=784, num_classes=10):
    return {"w": jnp.zeros((in_dim, num_classes), jnp.float32),
            "b": jnp.zeros((num_classes,), jnp.float32)}


def logreg_apply(p, x):
    return x @ p["w"] + p["b"]


# ------------------------------------------------------------------------ mlp
def init_mlp2(key, in_dim=784, hidden=200, num_classes=47):
    ks = [fold_in_name(key, n) for n in ("w1", "w2", "w3")]
    return {
        "w1": _kaiming(ks[0], (in_dim, hidden)), "b1": jnp.zeros((hidden,)),
        "w2": _kaiming(ks[1], (hidden, hidden)), "b2": jnp.zeros((hidden,)),
        "w3": _kaiming(ks[2], (hidden, num_classes)), "b3": jnp.zeros((num_classes,)),
    }


def mlp2_apply(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    h = jax.nn.relu(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


# ------------------------------------------------------------------------ cnn
def init_cnn(key, num_classes=10):
    ks = [fold_in_name(key, n) for n in ("c1", "c2", "f1", "f2")]
    return {
        "c1": _kaiming(ks[0], (5, 5, 3, 32)), "cb1": jnp.zeros((32,)),
        "c2": _kaiming(ks[1], (5, 5, 32, 64)), "cb2": jnp.zeros((64,)),
        "f1": _kaiming(ks[2], (64 * 8 * 8, 128)), "fb1": jnp.zeros((128,)),
        "f2": _kaiming(ks[3], (128, num_classes)), "fb2": jnp.zeros((num_classes,)),
    }


def cnn_apply(p, x):
    """x: [B, 32, 32, 3]."""
    y = jax.lax.conv_general_dilated(x, p["c1"], (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["cb1"])
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    y = jax.lax.conv_general_dilated(y, p["c2"], (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["cb2"])
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    y = y.reshape(y.shape[0], -1)
    y = jax.nn.relu(y @ p["f1"] + p["fb1"])
    return y @ p["f2"] + p["fb2"]


# ------------------------------------------------------------------- registry
SMALL_MODELS = {
    "logreg": (lambda key: init_logreg(key), logreg_apply),
    "mlp2": (lambda key: init_mlp2(key), mlp2_apply),
    "cnn": (lambda key: init_cnn(key), cnn_apply),
    "synth_logreg": (lambda key: init_logreg(key, in_dim=60, num_classes=10), logreg_apply),
}


def make_loss_fn(apply_fn):
    """Mean softmax cross-entropy + accuracy. batch: {'x','y'}."""
    def loss_fn(params, batch):
        logits = apply_fn(params, batch["x"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        loss = jnp.mean(logz - gold)
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
        return loss, {"acc": acc}
    return loss_fn
