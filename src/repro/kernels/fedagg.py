"""Pallas TPU kernel for FedALIGN's gated weighted client aggregation.

This is the paper's server step (eq. (15)): given C client updates (flattened
to [C, M]), data fractions p_k and inclusion gates I_k, compute

    out[m] = sum_k p_k I_k u[k, m] / sum_k p_k I_k

The parameter axis M is tiled in ``block_m`` columns; each grid cell loads a
[C, block_m] update slab into VMEM plus the tiny weight/gate vectors, and
emits one [block_m] output row. The reduction over clients is a [1,C]x[C,bm]
MXU contraction. Memory-bound (arithmetic intensity ~= 1 FLOP/byte), so
block_m is sized for DMA efficiency (multiples of 512 lanes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, w_ref, g_ref, o_ref):
    wg = (w_ref[...] * g_ref[...]).astype(jnp.float32)        # [C]
    den = jnp.maximum(jnp.sum(wg), 1e-30)
    u = u_ref[...].astype(jnp.float32)                        # [C, bm]
    num = jax.lax.dot_general(wg[None, :], u, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[0]
    o_ref[...] = (num / den).astype(o_ref.dtype)


def fedagg_pallas(updates, weights, gates, *, block_m=2048, interpret=False):
    """updates: [C, M]; weights, gates: [C] -> [M]."""
    C, M = updates.shape
    block_m = min(block_m, M)
    pad = (-M) % block_m
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    Mp = M + pad
    nm = Mp // block_m

    out = pl.pallas_call(
        _kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((C, block_m), lambda im: (0, im)),
            pl.BlockSpec((C,), lambda im: (0,)),
            pl.BlockSpec((C,), lambda im: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda im: (im,)),
        out_shape=jax.ShapeDtypeStruct((Mp,), updates.dtype),
        interpret=interpret,
    )(updates, weights, gates)
    return out[:M]
