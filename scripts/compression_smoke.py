"""CI compressed-uplink smoke: real int8+EF rounds must train sanely.

Runs a short federation with the ``int8`` wire codec and error feedback
on (dryrun-style, real ``engine.make_round_fn`` rounds on the synthetic
logreg federation), then asserts the compressed wire held up:

* the final global loss is finite AND improved on round 0 — quantization
  error with EF must not stall convergence at this scale;
* the error-feedback accumulators actually advanced (non-zero residual
  mass: the codec really ran, the identity fast path was not silently
  taken);
* the measured analytic compression ratio vs the identity wire is at
  least 3.9x (exact bound is ``4M/(M+4)`` -> 4.0000 at production M;
  anything under 3.9 means the wire payload widened).

Prints the measured bytes/round + ratio and exits nonzero on failure.

    PYTHONPATH=src python scripts/compression_smoke.py
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.aggregation import wire_bytes_per_round
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import SMALL_MODELS, make_loss_fn

CLIENTS, N_PRIORITY, ROUNDS = 16, 4, 12


def main() -> int:
    init_fn, apply_fn = SMALL_MODELS["synth_logreg"]
    loss_fn = make_loss_fn(apply_fn)
    fedn = make_synth_federation(seed=3, n_priority=N_PRIORITY,
                                 n_nonpriority=CLIENTS - N_PRIORITY,
                                 samples_per_client=64)
    data = {"x": fedn.x, "y": fedn.y}
    params = init_fn(jax.random.PRNGKey(0))

    fed = FedConfig(num_clients=CLIENTS, num_priority=N_PRIORITY,
                    rounds=ROUNDS, local_epochs=1, epsilon=0.5,
                    warmup_frac=0.0, align_stat="loss",
                    wire_codec="int8", error_feedback=True)
    round_fn = jax.jit(engine.make_round_fn(loss_fn, fed))
    state = engine.init_state(params, fed, CLIENTS)

    losses = []
    key = jax.random.PRNGKey(0)
    for r in range(ROUNDS):
        key, rkey = jax.random.split(key)
        state, stats = round_fn(state, data, fedn.priority_mask, fedn.weights,
                                rkey, jnp.int32(r))
        losses.append(float(stats["global_loss"]))

    m_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    wire = wire_bytes_per_round(fed, CLIENTS, m_total)
    ident = wire_bytes_per_round(fed.replace(wire_codec="identity"),
                                 CLIENTS, m_total)
    ratio = ident / wire
    ef_mass = sum(float(jnp.sum(jnp.abs(e)))
                  for e in jax.tree.leaves(state.ef_accum))

    ok = True

    def check(cond, msg):
        nonlocal ok
        print(f"  [{'ok' if cond else 'FAIL'}] {msg}")
        ok = ok and bool(cond)

    print(f"[compression_smoke] {ROUNDS} rounds, wire_codec={fed.wire_codec}, "
          f"error_feedback={fed.error_feedback}, M={m_total}")
    print(f"[compression_smoke] uplink {wire} B/round vs identity {ident} "
          f"B/round -> {ratio:.4f}x compression")
    check(np.isfinite(losses[-1]),
          f"final global loss finite ({losses[-1]:.4f})")
    check(losses[-1] < losses[0],
          f"loss improved over the compressed wire "
          f"({losses[0]:.4f} -> {losses[-1]:.4f})")
    check(ef_mass > 0.0,
          f"error-feedback accumulators advanced (|ef| mass {ef_mass:.3e})")
    check(ratio >= 3.9,
          f"compression ratio {ratio:.4f} >= 3.9 (analytic 4M/(M+4))")
    if not ok:
        print("[compression_smoke] FAILED")
        return 1
    print("[compression_smoke] PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
