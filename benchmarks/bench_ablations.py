"""Beyond-figure ablations:

1. eps fine-tuning (paper §3.2): constant eps vs decaying-to-zero eps on
   high-noise SYNTH — decaying eliminates the rho_T bias in late rounds.
2. Straggler participation (paper App. A.4): non-priority clients appear
   only every few rounds; FedALIGN must still help.
3. Server momentum (beyond-paper FedAvgM on aggregated deltas).
4. Selection strategies (fl/engine.py registry): the paper's fedalign rule
   vs its budgeted topk_align variant and gradient-similarity grad_sim
   selection (Tupitsa et al., arXiv:2402.05050) under label noise.
"""
from __future__ import annotations

import jax

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl.simulator import run_federation
from repro.models.small import SMALL_MODELS, make_loss_fn


def run(fast=True, seeds=(0,)):
    rows = []
    rounds = 25 if fast else 150
    init_fn, apply_fn = SMALL_MODELS["synth_logreg"]
    loss_fn = make_loss_fn(apply_fn)
    fedn_hi = make_synth_federation(seed=0, n_priority=10, n_nonpriority=10,
                                    samples_per_client=200,
                                    label_noise_skew=5.0, random_data_skew=5.0)

    base = dict(num_clients=20, num_priority=10, rounds=rounds,
                local_epochs=5, lr=0.1, warmup_frac=0.1, batch_size=32)

    # 1. eps schedules under high noise
    for name, kw in [
        ("eps_const_0.4", dict(epsilon=0.4)),
        ("eps_decay_exp", dict(epsilon=0.4, epsilon_schedule="exp",
                               epsilon_decay=0.08)),
        ("eps_zero", dict(epsilon=0.0)),
    ]:
        fed = FedConfig(**base, **kw)
        h = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                           fedn_hi, eval_every=5)
        rows.append({"ablation": "eps_schedule", "setting": name,
                     "selection": "fedalign",
                     "final_acc": round(h.summary()["final_acc"], 4),
                     "mean_included": round(h.summary()["mean_included"], 2)})

    # 2. stragglers
    fedn = make_synth_federation(seed=0, n_priority=10, n_nonpriority=10,
                                 samples_per_client=200,
                                 label_noise_skew=1.5, random_data_skew=1.5)
    for name, kw in [("no_stragglers", {}),
                     ("stragglers_p4", dict(straggler_period=4))]:
        fed = FedConfig(**base, epsilon=0.2, **kw)
        h = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                           fedn, eval_every=5)
        rows.append({"ablation": "stragglers", "setting": name,
                     "selection": "fedalign",
                     "final_acc": round(h.summary()["final_acc"], 4),
                     "mean_included": round(h.summary()["mean_included"], 2)})

    # 3. server momentum
    for name, kw in [("plain_server", {}),
                     ("server_momentum", dict(server_opt="momentum",
                                              server_momentum=0.6))]:
        fed = FedConfig(**base, epsilon=0.2, **kw)
        h = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                           fedn, eval_every=5)
        rows.append({"ablation": "server_opt", "setting": name,
                     "selection": "fedalign",
                     "final_acc": round(h.summary()["final_acc"], 4),
                     "mean_included": round(h.summary()["mean_included"], 2)})

    # 4. selection strategies under noise
    for name, kw in [
        ("fedalign", dict(selection="fedalign", epsilon=0.4)),
        ("topk_align_k3", dict(selection="topk_align", epsilon=0.4, topk=3)),
        ("grad_sim_0.0", dict(selection="grad_sim", sim_threshold=0.0)),
        ("grad_sim_0.2", dict(selection="grad_sim", sim_threshold=0.2)),
    ]:
        fed = FedConfig(**base, align_stat="loss", **kw)
        h = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                           fedn_hi, eval_every=5)
        rows.append({"ablation": "selection_strategy", "setting": name,
                     "selection": kw["selection"],
                     "final_acc": round(h.summary()["final_acc"], 4),
                     "mean_included": round(h.summary()["mean_included"], 2)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
