"""End-to-end FedALIGN training driver for the LM-scale architectures.

Runs real federated rounds of a (reduced or full) architecture on whatever
devices exist — the same ``fl/sharded.py`` round step the dry-run lowers for
the production mesh, so examples/tests exercise the production code path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --rounds 20 --clients 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import FedConfig
from repro.configs.cli import add_fed_args, fed_from_args
from repro.data.tokens import make_token_federation
from repro.fl import engine, sharded
from repro.models import get_model
from repro.utils import param_count


def build_batches(cfg, fed_data, *, clients, per_client, seq, rng):
    """Assemble one round's client-stacked token batch + server batch."""
    toks = fed_data["tokens"]                       # [C, n_seq, seq+1]
    C, n_seq, _ = toks.shape
    idx = rng.integers(0, n_seq, size=(clients, per_client))
    sel = np.stack([toks[c, idx[c]] for c in range(clients)])   # [C,b,seq+1]
    test = fed_data["test_tokens"]
    sidx = rng.integers(0, test.shape[0], size=(per_client,))
    server = test[sidx]

    def split(x):
        return {"tokens": jnp.asarray(x[..., :-1]),
                "labels": jnp.asarray(x[..., 1:]),
                "mask": jnp.ones(x[..., 1:].shape, jnp.float32)}

    return {
        "clients": split(sel),
        "server": split(server),
        "priority_mask": jnp.asarray(fed_data["priority_mask"], jnp.float32),
        "weights": jnp.asarray(fed_data["weights"]),
    }


def run(arch="qwen1.5-0.5b", smoke=True, rounds=10, clients=8, n_priority=4,
        per_client=4, seq=128, lr=0.05, epsilon=0.5, local_epochs=2,
        misalign_max=1.0, log_every=1, seed=0, verbose=True, **fed_kw):
    """``fed_kw`` passes any further FedConfig knob straight through —
    e.g. ``async_depth=2, staleness_decay=0.5, backend="scan_async"`` to
    drive the pod rounds with overlapped cohorts (plus
    ``async_mode="ready", min_lag=1`` for the FedBuff-style variable-lag
    buffer and ``adaptive_staleness=True`` for the drift-measured
    discount), or ``server_opt``."""
    cfg = get_smoke(arch) if smoke else get_config(arch)
    assert not cfg.encdec, "use examples/whisper for enc-dec training"
    model = get_model(cfg)
    fed = FedConfig(num_clients=clients, num_priority=n_priority,
                    local_epochs=local_epochs, epsilon=epsilon, lr=lr,
                    **fed_kw)
    fed_data = make_token_federation(seed=seed, vocab=cfg.vocab_size,
                                     n_clients=clients, n_priority=n_priority,
                                     seq_len=seq, misalign_max=misalign_max,
                                     tokens_per_client=max(8192, per_client * (seq + 1) * 4))
    # validate while still concrete — inside the jitted round they're tracers
    from repro.core.aggregation import check_client_weights
    check_client_weights(fed_data["weights"], where="federation weights")

    round_step = jax.jit(sharded.make_round_step(model, fed, clients, fsdp=False))
    params = model.init(jax.random.PRNGKey(seed))
    # the whole cross-round carry (params + server-optimizer moments +
    # backlog + utility EMAs) threads through the driver as ONE pytree
    state = engine.init_state(params, fed, clients)
    if verbose:
        print(f"[train] {cfg.name} params={param_count(params):,} clients={clients}")
    rng = np.random.default_rng(seed)
    history = []
    halt_skips = int(fed.max_nonfinite_skips) if fed.divergence_guard else 0
    for r in range(rounds):
        batch = build_batches(cfg, fed_data, clients=clients,
                              per_client=per_client, seq=seq, rng=rng)
        t0 = time.time()
        state, stats = round_step(state, batch, jnp.int32(r))
        dt = time.time() - t0
        rec = {"round": r,
               "server_loss": float(stats["server_loss"]),
               "included": float(jnp.sum(stats["gates"])) - n_priority,
               "theta_round": float(stats["theta_round"]),
               "sec": dt}
        if "lost_clients" in stats:
            rec["lost_clients"] = float(stats["lost_clients"])
        if "skipped_nonfinite" in stats:
            rec["skipped_nonfinite"] = int(stats["skipped_nonfinite"])
        history.append(rec)
        if verbose and r % log_every == 0:
            print(f"  round {r:3d} server_loss={rec['server_loss']:.4f} "
                  f"included_nonpri={rec['included']:.0f} ({dt:.2f}s)")
        if halt_skips > 0 and rec.get("skipped_nonfinite", 0) >= halt_skips:
            print(f"[train] halting at round {r}: "
                  f"{rec['skipped_nonfinite']} consecutive non-finite "
                  f"aggregates (>= max_nonfinite_skips={halt_skips}); "
                  "params are the last finite ones")
            break
    from repro.core.aggregation import dp_report
    dp = dp_report(fed, len(history))
    if dp is not None and verbose:
        eps, delta = dp
        print(f"[train] DP budget spent: epsilon={eps:.3g} at "
              f"delta={delta:g} (z={fed.dp_noise}, "
              f"{len(history)} rounds, RDP accountant)")
    return state.params, history


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    # every federation knob — aggregator/clock/failure/guard/codec/async/
    # pool — comes from the shared surface so this CLI can never drift
    # from the dry-run's (tests/test_pool.py pins the two flag sets equal)
    add_fed_args(ap)
    return ap


def main():
    a = build_parser().parse_args()
    run(arch=a.arch, smoke=a.smoke, rounds=a.rounds, clients=a.clients,
        seq=a.seq, lr=a.lr, **fed_from_args(a))


if __name__ == "__main__":
    main()
