"""Batched serving driver: prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.models import get_model


def pad_caches(model, caches, batch, max_len):
    """Grow prefill caches to max_len along the sequence axis (attention
    k/v and MLA latent caches; recurrent states are length-free)."""
    full = jax.eval_shape(lambda: model.make_cache(batch, max_len))

    def pad(c, f):
        if c.shape == f.shape:
            return c
        pads = [(0, fs - cs) for cs, fs in zip(c.shape, f.shape)]
        return jnp.pad(c, pads)
    return jax.tree.map(pad, caches, full)


def generate(model, params, prompt, max_new, *, greedy=True, rng=None):
    """prompt: [B, S] int32 -> tokens [B, S+max_new]."""
    B, S = prompt.shape
    max_len = S + max_new
    batch = {"tokens": prompt}
    caches, logits = jax.jit(model.prefill)(params, batch)
    caches = pad_caches(model, caches, B, max_len)

    step = jax.jit(model.decode_step)
    out = [prompt]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(max_new):
        out.append(tok)
        logits, caches = step(params, caches, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    a = ap.parse_args()

    cfg = get_smoke(a.arch) if a.smoke else get_config(a.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (a.batch, a.prompt_len),
                                0, cfg.vocab_size)
    t0 = time.time()
    toks = generate(model, params, prompt, a.gen)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: generated {a.batch}x{a.gen} tokens in {dt:.2f}s")
    print(toks[0, -a.gen:])


if __name__ == "__main__":
    main()
