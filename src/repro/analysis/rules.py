"""The core fedlint rules. Each targets a bug class this repo has hit (or
the ROADMAP promises never to hit) at trace level, invisible to pytest:

  no-large-literal     — a closure-captured federation-sized tensor
                         embedded as an XLA literal (PR 9: stalled
                         compilation at C=1e5)
  donation-honored     — a donated FederationState leaf silently dropped
                         from input_output_alias (doubles peak memory)
  dtype-discipline     — an f32 upcast sneaking into the bf16 / coded
                         [C, M_total] wire buffer
  collective-budget    — a surprise all-gather (or extra all-reduce) in
                         the pod round's mean path
  recompile-stability  — a round_idx / state value baked into the trace
                         (silent per-round recompiles)

Thresholds live in ``meta`` with the defaults below; allowances for
DOCUMENTED exceptions (grad_sim's f32 scoring flatten, the coded wire's
f32 pre-encode buffer, order-statistic aggregators' client-axis gather)
are derived from the FedConfig, never hardcoded per call site.
"""
from __future__ import annotations

from repro.analysis.hlo import hlo_constants
from repro.analysis.jaxpr_walk import (closure_consts, eqn_out_avals,
                                       iter_eqns, jaxpr_fingerprint)
from repro.analysis.lint import LintViolation, lint_rule

# any single literal above this is a captured-tensor smell, not a table
DEFAULT_LITERAL_BYTES = 1 << 20          # 1 MiB
# donated buffers smaller than this may legally lose aliasing (scalars,
# tiny counters: XLA packs/reallocates them freely and nothing is at stake)
DEFAULT_MIN_DONATION_BYTES = 1 << 10     # 1 KiB
# collectives at or below this are control-plane scalars (loss sums,
# inclusion mass), not delta traffic — exempt from the budget
DEFAULT_SMALL_COLLECTIVE_BYTES = 1 << 12  # 4 KiB


def _resolved(fed, what):
    if what == "codec":
        from repro.core.aggregation import resolve_wire_codec
        return resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
    from repro.core.aggregation import resolve_aggregator
    return resolve_aggregator(getattr(fed, "aggregator", "mean"))


@lint_rule("no-large-literal")
def no_large_literal(ctx):
    """No constant bigger than ``meta['literal_threshold']`` bytes may be
    materialized inside the program — neither as a closure-captured jaxpr
    const nor as an HLO ``constant`` op (XLA constant-folds captures into
    literals: the PR 9 class). Round-invariant inputs must enter as
    arguments, where they are device buffers, not program text."""
    thresh = int(ctx.meta.get("literal_threshold", DEFAULT_LITERAL_BYTES))
    out = []
    if ctx.jaxpr is not None:
        for desc, nbytes in closure_consts(ctx.jaxpr):
            if nbytes > thresh:
                out.append(LintViolation(
                    "no-large-literal",
                    f"closure-captured constant {desc} is {nbytes} bytes "
                    f"(> {thresh}): pass it as a traced argument instead",
                    {"where": "jaxpr const", "bytes": nbytes}))
    if ctx.hlo_text is not None:
        for cname, oname, nbytes in hlo_constants(ctx.comps):
            if nbytes > thresh:
                out.append(LintViolation(
                    "no-large-literal",
                    f"HLO constant {oname} in computation {cname} is "
                    f"{nbytes} bytes (> {thresh}): a tensor was embedded "
                    "as program text (captured closure or constant-folded "
                    "input)",
                    {"where": f"{cname}/{oname}", "bytes": nbytes}))
    return out


@lint_rule("donation-honored", needs_hlo=True)
def donation_honored(ctx):
    """Every donated entry buffer above ``meta['min_donation_bytes']``
    must appear in the module's ``input_output_alias`` config. XLA drops
    an alias silently whenever the output can't reuse the buffer (dtype /
    size change on the carry), which doubles peak memory on exactly the
    state the simulator promised to update in place."""
    if not ctx.donated:
        return []
    min_bytes = int(ctx.meta.get("min_donation_bytes",
                                 DEFAULT_MIN_DONATION_BYTES))
    aliased = {e["param_number"] for e in ctx.alias_entries}
    out = []
    for p in ctx.donated:
        if p["nbytes"] >= min_bytes and p["param"] not in aliased:
            out.append(LintViolation(
                "donation-honored",
                f"donated buffer {p['path']} ({p['nbytes']} bytes, entry "
                f"parameter {p['param']}) has no input_output_alias entry: "
                "the donation was dropped (shape/dtype of the returned "
                "carry no longer matches the input)",
                {"param": p["param"], "path": p["path"],
                 "bytes": p["nbytes"]}))
    return out


@lint_rule("dtype-discipline", needs_jaxpr=True, needs_fed=True)
def dtype_discipline(ctx):
    """The [C, M_total] wire buffer must be built at the configured wire
    dtype. ``flatten_stacked`` concatenates the reshaped leaves along
    axis 1; under ``agg_dtype=bfloat16`` (identity codec) any axis-1 f32
    concatenate of wire width is an upcast that doubles the aggregation
    collective. Allowances, derived from the config: grad_sim without the
    sketch flattens deltas at f32 for its cosine scoring (one buffer);
    non-identity codecs build one f32 pre-encode buffer by design (plus
    one for the error-feedback residual) — for those the rule instead
    checks the ENCODED wire exists (int8: an int8 buffer of wire width).
    Axis-0 concatenates are kernel-internal f32 accumulation (the
    documented sort-path padding) and are exempt."""
    fed = ctx.fed
    m_total = ctx.meta.get("m_total")
    if not m_total:
        return []        # wire width unknown: nothing to anchor the walk
    m_total = int(m_total)
    codec = _resolved(fed, "codec")

    f32_wire_concats = []
    int8_wire_outputs = 0
    for eqn in iter_eqns(ctx.jaxpr):
        for aval in eqn_out_avals(eqn):
            if len(aval.shape) != 2 or aval.shape[1] != m_total:
                continue
            if (eqn.primitive.name == "concatenate"
                    and eqn.params.get("dimension") == 1
                    and str(aval.dtype) == "float32"):
                f32_wire_concats.append(tuple(aval.shape))
            if str(aval.dtype) == "int8":
                int8_wire_outputs += 1

    out = []
    if codec == "identity":
        if str(getattr(fed, "agg_dtype", "float32")) != "bfloat16":
            return []     # f32 wire is the configured wire: nothing to check
        allowance = int(fed.selection == "grad_sim"
                        and not fed.grad_sim_sketch)
        if len(f32_wire_concats) > allowance:
            out.append(LintViolation(
                "dtype-discipline",
                f"{len(f32_wire_concats)} f32 axis-1 concatenate(s) of wire "
                f"width M_total={m_total} under agg_dtype=bfloat16 "
                f"(allowance {allowance}): an upcast sneaked into the bf16 "
                "wire buffer",
                {"shapes": [list(s) for s in f32_wire_concats],
                 "allowance": allowance}))
    elif codec == "int8":
        if int8_wire_outputs == 0:
            out.append(LintViolation(
                "dtype-discipline",
                f"wire_codec=int8 but no int8 buffer of wire width "
                f"M_total={m_total} exists in the program: the encode was "
                "dropped and the wire travels uncompressed",
                {"m_total": m_total}))
    # topk/sketch travel at non-M_total widths; their rate knobs are
    # validated by check_codec_config and not re-checked here
    return out


def _is_cross_pod(op, devices_per_pod):
    """Does one collective op's replica grouping straddle a pod boundary?

    With no ``devices_per_pod`` every collective counts (single-program
    callers, handcrafted fixtures). With it, explicit replica groups are
    decoded and checked member-by-member; an empty group list means "all
    devices in one group" (cross-pod iff the module spans several pods);
    the iota form is undecodable from text and is treated as intra-pod
    sharding traffic — per-layer TP/FSDP collectives, which the budget
    deliberately does not police."""
    from repro.analysis.hlo import replica_group_members
    if devices_per_pod is None:
        return True
    members = replica_group_members(op.get("groups"))
    if members is None:
        return False
    dpp = int(devices_per_pod)
    if not members:                       # {}: one group of every device
        return op.get("all_devices_cross", True)
    return any(len({d // dpp for d in g}) > 1 for g in members)


@lint_rule("collective-budget", needs_hlo=True)
def collective_budget(ctx):
    """Pod programs (``meta['pod']``) must keep the promised collective
    schedule: the mean-path round performs exactly ONE CROSS-POD
    all-reduce of delta size per round and no cross-pod all-gathers.
    Intra-pod sharding collectives (per-layer TP reduce, FSDP param
    gathers) are the pod round's normal traffic and never count —
    cross-pod is decided per op from its replica groups against
    ``meta['devices_per_pod']`` (absent: every collective counts).
    Order-statistic aggregators (trimmed_mean/median) and non-identity
    codecs gather the client axis before reducing — the documented
    allowance. Collectives at or below ``meta['small_collective_bytes']``
    are control-plane scalars (loss sums, inclusion mass) and never
    count. Counts are taken at true trip-count multiplicity, divided by
    ``meta['rounds']`` for scanned multi-round programs. Non-pod
    (single-device) programs must contain no collectives at all."""
    from repro.analysis.hlo import aggregate
    agg = aggregate(ctx.comps, ctx.entry)
    small = int(ctx.meta.get("small_collective_bytes",
                             DEFAULT_SMALL_COLLECTIVE_BYTES))
    rounds = max(int(ctx.meta.get("rounds", 1)), 1)
    dpp = ctx.meta.get("devices_per_pod")
    multi_pod = (ctx.meta.get("devices", 0) or 0) > (dpp or 0)
    # per-op payload decides "control-plane scalar" vs delta traffic
    big = [op for op in agg["coll_ops"] if op["bytes"] > small]

    out = []
    if not ctx.meta.get("pod"):
        if big:
            kinds = sorted({op["kind"] for op in big})
            out.append(LintViolation(
                "collective-budget",
                f"single-device program contains cross-device collectives: "
                f"{kinds}",
                {"coll_n": dict(agg['coll_n'])}))
        return out

    cross = [dict(op, all_devices_cross=multi_pod) if dpp else op
             for op in big]
    cross = [op for op in cross if _is_cross_pod(op, dpp)]
    n_by_kind = {}
    for op in cross:
        n_by_kind[op["kind"]] = n_by_kind.get(op["kind"], 0) + op["n"] / rounds

    fed = ctx.fed
    gather_ok = ctx.meta.get("allow_gather")
    if gather_ok is None and fed is not None:
        gather_ok = (_resolved(fed, "aggregator")
                     in ("trimmed_mean", "median")
                     or _resolved(fed, "codec") != "identity")
    max_ar = float(ctx.meta.get("max_all_reduce", 1))
    n_ar = n_by_kind.get("all-reduce", 0)
    if n_ar > max_ar:
        out.append(LintViolation(
            "collective-budget",
            f"{n_ar:g} delta-sized cross-pod all-reduce(s) per round on "
            f"the mean path (budget {max_ar:g}): the round pays extra "
            "cross-pod synchronization",
            {"cross_pod_n": dict(n_by_kind), "rounds": rounds}))
    n_ag = n_by_kind.get("all-gather", 0)
    if n_ag > 0 and not gather_ok:
        out.append(LintViolation(
            "collective-budget",
            f"{n_ag:g} delta-sized cross-pod all-gather(s) per round: the "
            "mean path promises none (only order-statistic aggregators "
            "and coded wires may gather the client axis)",
            {"cross_pod_n": dict(n_by_kind), "rounds": rounds}))
    return out


@lint_rule("recompile-stability", needs_jaxpr=True, needs_second=True)
def recompile_stability(ctx):
    """The round traced at two different ``round_idx``/state VALUES must
    produce identical jaxprs. A mismatch means a value leaked into the
    trace as a literal, weak type, or shape — and the jit cache will
    silently recompile every round at run time."""
    h1 = jaxpr_fingerprint(ctx.jaxpr)
    h2 = jaxpr_fingerprint(ctx.jaxpr2)
    if h1 == h2:
        return []
    return [LintViolation(
        "recompile-stability",
        f"program shape depends on argument values: jaxpr fingerprints "
        f"{h1[:12]} != {h2[:12]} for two lowerings that differ only in "
        "round_idx/state values — a value was baked into the trace",
        {"fingerprint_a": h1, "fingerprint_b": h2})]
