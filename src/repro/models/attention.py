"""Attention: GQA (flash-style chunked softmax), MLA (latent KV), decode paths.

The train/prefill path is an online-softmax blockwise attention written with
``lax.scan`` so that no [S, S] score matrix is ever materialized — this is
the jnp twin of the Pallas ``flash_attention`` kernel (kernels/ops.py swaps
the Pallas version in on TPU).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm
from repro.utils import fold_in_name

NEG_INF = -1e30


# =============================================================== GQA attention
def init_gqa(key, cfg):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = {n: fold_in_name(key, n) for n in ("wq", "wk", "wv", "wo")}
    p = {
        "wq": dense_init(ks["wq"], (d, H * hd), cfg.pdtype),
        "wk": dense_init(ks["wk"], (d, KV * hd), cfg.pdtype),
        "wv": dense_init(ks["wv"], (d, KV * hd), cfg.pdtype),
        "wo": dense_init(ks["wo"], (H * hd, d), cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.pdtype)
    return p


def gqa_project(p, x, cfg):
    """x: [B,S,d] -> q [B,S,H,hd], k,v [B,S,KV,hd] (un-roped)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cd = cfg.cdtype
    q = x @ p["wq"].astype(cd)
    k = x @ p["wk"].astype(cd)
    v = x @ p["wv"].astype(cd)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(cd), k + p["bk"].astype(cd), v + p["bv"].astype(cd)
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KV, hd), v.reshape(B, S, KV, hd))


def gqa_attention_block(p, x, cfg, *, positions, mode, cache=None, dispatch=None):
    """Full GQA block. mode: 'train'|'prefill'|'decode'.

    cache (prefill out / decode in-out): dict(k, v: [B,W,KV,hd], len: scalar).
    positions: [B?, S] absolute positions (we use a shared [S] vector).
    Returns (out [B,S,d], new_cache).
    """
    from repro.kernels import ops as kops
    B, S, _ = x.shape
    cd = cfg.cdtype
    q, k, v = gqa_project(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.sliding_window
    mm_dtype = jnp.bfloat16 if cfg.attn_bf16 else None

    if mode in ("train", "prefill"):
        if cfg.seq_shard_attn:
            # sequence-parallel attention: when heads % model_axis != 0 GSPMD
            # would otherwise shard the hd CONTRACTION and all-reduce scores
            # per kv block. Instead: queries sharded over S on "model", k/v
            # gathered once per layer, attention fully local per device.
            from jax.sharding import PartitionSpec as P
            dp = cfg.dp_axes if len(cfg.dp_axes) > 1 else cfg.dp_axes[0]
            q = jax.lax.with_sharding_constraint(q, P(dp, "model", None, None))
            k = jax.lax.with_sharding_constraint(k, P(dp, None, None, None))
            v = jax.lax.with_sharding_constraint(v, P(dp, None, None, None))
        out = kops.flash_attention(q, k, v, causal=cfg.causal, window=window,
                                   block_kv=cfg.attn_block_kv,
                                   use_pallas=cfg.use_pallas, mm_dtype=mm_dtype)
        if cfg.seq_shard_attn:
            from jax.sharding import PartitionSpec as P
            dp = cfg.dp_axes if len(cfg.dp_axes) > 1 else cfg.dp_axes[0]
            out = jax.lax.with_sharding_constraint(out, P(dp, "model", None, None))
        new_cache = None
        if mode == "prefill":
            W = min(window, S) if window else S
            kc, vc = k[:, S - W:], v[:, S - W:]
            if window and S > window:
                # ring layout: absolute position p lives at slot p % W
                kc = jnp.roll(kc, S % W, axis=1)
                vc = jnp.roll(vc, S % W, axis=1)
            new_cache = {"k": kc, "v": vc,
                         "len": jnp.asarray(min(W, S), jnp.int32)}
    else:  # decode: S == 1
        W = cache["k"].shape[1]
        pos = positions[-1]                                             # scalar
        slot = (pos % W if window else pos).astype(jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        kv_len = jnp.minimum(pos + 1, W).astype(jnp.int32)
        out = kops.decode_attention(q, k_cache, v_cache, kv_len=kv_len,
                                    use_pallas=cfg.use_pallas)
        new_cache = {"k": k_cache, "v": v_cache, "len": kv_len}

    B_, S_, H, hd = out.shape
    y = out.reshape(B_, S_, H * hd) @ p["wo"].astype(cd)
    return y, new_cache


# ============================================================== MLA attention
def init_mla(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = {n: fold_in_name(key, n) for n in ("wq_a", "wq_b", "wkv_a", "wkv_b", "wo")}
    return {
        "wq_a": dense_init(ks["wq_a"], (d, qr), cfg.pdtype),
        "q_norm": init_rmsnorm(qr, cfg.pdtype),
        "wq_b": dense_init(ks["wq_b"], (qr, H * (nope + rope)), cfg.pdtype),
        "wkv_a": dense_init(ks["wkv_a"], (d, kvr + rope), cfg.pdtype),
        "kv_norm": init_rmsnorm(kvr, cfg.pdtype),
        "wkv_b": dense_init(ks["wkv_b"], (kvr, H * (nope + vd)), cfg.pdtype),
        "wo": dense_init(ks["wo"], (H * vd, d), cfg.pdtype),
    }


def mla_attention_block(p, x, cfg, *, positions, mode, cache=None, dispatch=None):
    """MLA (Multi-head Latent Attention, MiniCPM3/DeepSeek-V2 style).

    Prefill: expand latents to full k/v, run flash attention.
    Decode: 'absorbed' path — scores and context computed directly in the
    latent space; the KV cache stores only [B,W,kvr] latents + [B,W,rope]
    shared roped keys (the MLA memory win).
    """
    from repro.kernels import ops as kops
    B, S, d = x.shape
    cd = cfg.cdtype
    H = cfg.num_heads
    nope, rope, vd, kvr = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                           cfg.v_head_dim, cfg.kv_lora_rank)
    scale = (nope + rope) ** -0.5

    q = rmsnorm(p["q_norm"], x @ p["wq_a"].astype(cd)) @ p["wq_b"].astype(cd)
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"].astype(cd)                                    # [B,S,kvr+rope]
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., :kvr])                       # latent
    k_rope = apply_rope(kv_a[..., kvr:].reshape(B, S, 1, rope), positions, cfg.rope_theta)

    wkv_b = p["wkv_b"].astype(cd).reshape(kvr, H, nope + vd)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]                   # [kvr,H,nope],[kvr,H,vd]

    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, w_uk)
        v = jnp.einsum("bsr,rhv->bshv", c_kv, w_uv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to head_dim of k for the shared flash kernel, then slice back
        pad = (nope + rope) - vd
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
        out = kops.flash_attention(qfull, k, v_p, causal=cfg.causal,
                                   window=cfg.sliding_window,
                                   block_kv=cfg.attn_block_kv, use_pallas=cfg.use_pallas)
        out = out[..., :vd]
        new_cache = None
        if mode == "prefill":
            W = min(cfg.sliding_window, S) if cfg.sliding_window else S
            cc, rc = c_kv[:, S - W:], k_rope[:, S - W:, 0]
            if cfg.sliding_window and S > cfg.sliding_window:
                cc = jnp.roll(cc, S % W, axis=1)
                rc = jnp.roll(rc, S % W, axis=1)
            new_cache = {"c_kv": cc, "k_rope": rc,
                         "len": jnp.asarray(min(W, S), jnp.int32)}
    else:  # decode (absorbed)
        W = cache["c_kv"].shape[1]
        pos = positions[-1]
        slot = (pos % W if cfg.sliding_window else pos).astype(jnp.int32)
        c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, slot, 0))
        r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope[:, :, 0], (0, slot, 0))
        kv_len = jnp.minimum(pos + 1, W).astype(jnp.int32)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))                    # [B,1,H,kvr]
        s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_cache.astype(jnp.float32))
             + jnp.einsum("bqhp,bsp->bhqs", q_rope.astype(jnp.float32),
                          r_cache.astype(jnp.float32))) * scale
        valid = jnp.arange(W)[None, :] < kv_len
        s = jnp.where(valid[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhqs,bsr->bqhr", w, c_cache.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(cd)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache, "len": kv_len}

    y = out.reshape(B, S, H * vd) @ p["wo"].astype(cd)
    return y, new_cache


# ===================================================== cross-attention (enc-dec)
def init_cross_attn(key, cfg):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = {n: fold_in_name(key, n) for n in ("wq", "wk", "wv", "wo")}
    return {
        "wq": dense_init(ks["wq"], (d, H * hd), cfg.pdtype),
        "wk": dense_init(ks["wk"], (d, H * hd), cfg.pdtype),
        "wv": dense_init(ks["wv"], (d, H * hd), cfg.pdtype),
        "wo": dense_init(ks["wo"], (H * hd, d), cfg.pdtype),
    }


def cross_attention(p, x, enc, cfg):
    """x: [B,S,d] queries; enc: [B,T,d] encoder states (full, non-causal)."""
    B, S, _ = x.shape
    T = enc.shape[1]
    H, hd = cfg.num_heads, cfg.head_dim
    cd = cfg.cdtype
    q = (x @ p["wq"].astype(cd)).reshape(B, S, H, hd)
    k = (enc @ p["wk"].astype(cd)).reshape(B, T, H, hd)
    v = (enc @ p["wv"].astype(cd)).reshape(B, T, H, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * hd ** -0.5
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(cd)
    return out.reshape(B, S, H * hd) @ p["wo"].astype(cd)
