"""fedlint sweep: statically lint every federation program the repo can emit.

For each combo of selection strategy x engine backend x aggregator x wire
codec, capture the simulator's jitted multi-round chunk program
(``fl/simulator.capture_chunk_program`` — the exact ``run_chunk`` the
training loop jits, donation pattern included), trace and compile it, and
run every registered lint rule over the jaxpr and the optimized HLO.
Nothing executes: a full 108-combo sweep is pure trace/compile time and
runs on the CPU CI shard.

    PYTHONPATH=src python scripts/fedlint.py --out fedlint-report.json

Exit status is the number of combos with violations (0 = clean), so CI
can gate on it directly. ``--only-strategy/--only-backend/...`` narrow
the grid while iterating locally; ``--hlo-dir DIR`` skips the sweep and
instead runs the HLO-only rule subset over dryrun ``--dump-hlo``
artifacts (pod programs compiled elsewhere), reading each artifact's
``.lintmeta.json`` sidecar for the config facts rules key on.
"""
from __future__ import annotations

import argparse
import glob
import itertools
import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.analysis import lint_hlo_text, lint_program
from repro.analysis.hlo import read_hlo_file
from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import simulator
from repro.models.small import SMALL_MODELS, make_loss_fn

STRATEGIES = ("fedalign", "all", "priority_only", "topk_align",
              "grad_sim", "welfare")
BACKENDS = ("vmap_spatial", "scan_temporal", "scan_async")
AGGREGATORS = ("mean", "trimmed_mean", "dp")
CODECS = ("identity", "int8")

# tiny federation: the chunk closes over the client data by design, so
# the capture must stay far below the 1 MiB no-large-literal threshold
CLIENTS, N_PRIORITY, SAMPLES = 12, 4, 16


def make_fed(strategy, backend, aggregator, codec):
    """One sweep point's FedConfig, with the documented pairing fixes:
    scan_async needs a pipeline depth; grad_sim under scan_temporal needs
    the sketch (full-delta scoring is spatial-only)."""
    kw = dict(num_clients=CLIENTS, num_priority=N_PRIORITY, rounds=4,
              local_epochs=1, warmup_frac=0.0, selection=strategy,
              backend=backend, aggregator=aggregator, wire_codec=codec)
    if backend == "scan_async":
        kw.update(async_depth=2, async_mode="ready", min_lag=1)
    if strategy == "grad_sim" and backend != "vmap_spatial":
        kw.update(grad_sim_sketch=True)
    if aggregator == "dp":
        kw.update(dp_clip=1.0, dp_noise=0.5)
    return FedConfig(**kw)


def lint_combo(loss_fn, init_params, fedn, fed, label):
    fn, args, donate, meta = simulator.capture_chunk_program(
        loss_fn, init_params, fed, fedn, n=2)
    # second lowering differs only in VALUES (rng, start round): the
    # recompile-stability rule asserts the trace is identical
    args2 = (args[0], jax.random.PRNGKey(1234), jnp.int32(17))
    return lint_program(fn, args, fed, args2=args2, donate_argnums=donate,
                        meta=meta, label=label)


def run_sweep(args):
    init_fn, apply_fn = SMALL_MODELS["synth_logreg"]
    loss_fn = make_loss_fn(apply_fn)
    fedn = make_synth_federation(seed=0, n_priority=N_PRIORITY,
                                 n_nonpriority=CLIENTS - N_PRIORITY,
                                 samples_per_client=SAMPLES)
    init_params = init_fn(jax.random.PRNGKey(0))

    strategies = [args.only_strategy] if args.only_strategy else STRATEGIES
    backends = [args.only_backend] if args.only_backend else BACKENDS
    aggs = [args.only_aggregator] if args.only_aggregator else AGGREGATORS
    codecs = [args.only_codec] if args.only_codec else CODECS

    reports = []
    for strat, bk, agg, codec in itertools.product(
            strategies, backends, aggs, codecs):
        label = f"{strat}/{bk}/{agg}/{codec}"
        fed = make_fed(strat, bk, agg, codec)
        rep = lint_combo(loss_fn, init_params, fedn, fed, label)
        reports.append(rep)
        print(rep.summary(), flush=True)
    return reports


def run_hlo_dir(args):
    reports = []
    for path in sorted(glob.glob(os.path.join(args.hlo_dir, "*.hlo.txt*"))):
        tag = os.path.basename(path).split(".hlo.txt")[0]
        meta_path = os.path.join(args.hlo_dir, tag + ".lintmeta.json")
        meta = {}
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
        rep = lint_hlo_text(read_hlo_file(path), meta=meta, label=tag)
        reports.append(rep)
        print(rep.summary(), flush=True)
    if not reports:
        print(f"[fedlint] no *.hlo.txt[.gz] artifacts under {args.hlo_dir}",
              file=sys.stderr)
        return reports, 1
    return reports, 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="write the JSON report here")
    ap.add_argument("--hlo-dir", default=None,
                    help="lint dumped HLO artifacts (dryrun --dump-hlo DIR) "
                         "instead of sweeping the simulator grid")
    ap.add_argument("--only-strategy", default=None, choices=STRATEGIES)
    ap.add_argument("--only-backend", default=None, choices=BACKENDS)
    ap.add_argument("--only-aggregator", default=None, choices=AGGREGATORS)
    ap.add_argument("--only-codec", default=None, choices=CODECS)
    args = ap.parse_args()

    if args.hlo_dir:
        reports, err = run_hlo_dir(args)
        if err:
            return err
    else:
        reports = run_sweep(args)

    bad = [r for r in reports if not r.ok]
    payload = {"n_programs": len(reports), "n_dirty": len(bad),
               "reports": [r.to_dict() for r in reports]}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[fedlint] report -> {args.out}")
    print(f"[fedlint] {len(reports)} programs linted, "
          f"{len(bad)} with violations")
    return len(bad)


if __name__ == "__main__":
    sys.exit(main())
