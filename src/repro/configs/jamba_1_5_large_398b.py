"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave, MoE.
[arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period-8 layout: [attn, mamba x7]; MoE FFN on every other layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    pattern="jamba",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    moe=True,
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_every=2,
    moe_offset=1,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
    tie_embeddings=False,
    param_dtype="bfloat16",           # 398B: must be bf16 + (data,model) sharded
    source="arXiv:2403.19887",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=8, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, moe_d_ff=256, num_experts=4, top_k=2, vocab_size=512,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64, ssm_chunk=16)
