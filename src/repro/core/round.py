"""FedALIGN communication-round engine — simulator-facing adapter.

The actual round implementation (selection strategies, eps schedule,
warm-up, participation sampling, execution backends, fused aggregation,
server optimizers, cross-round state) lives in ``repro.fl.engine``; this
module keeps the historical simulator entry point so ``fl/simulator.py``
and the paper benchmarks are untouched by engine refactors.

One jitted ``round_fn`` executes a full communication round over a
persistent ``FederationState`` (params + server-optimizer moments +
overflow backlog + utility EMAs):

  1. server broadcasts w_t (implicit: vmap/scan over the client axis);
  2. every client evaluates F_k(w_t) on its local data (full batch);
  3. server loss F(w_t) = sum_{k in P} p_k F_k(w_t);
  4. gates I_{k,t} from the configured SelectionStrategy (fl/engine.py);
  5. E local epochs of minibatch SGD (or FedProx) — gate-before-train:
     for strategies gated by the eval pre-pass alone, only included
     clients train (scan cond-skip; dense [K, ...] cohort gather when
     ``fed.max_cohort > 0``, backlog-aware overflow). Delta-based
     strategies run 5 before 4;
  6. renormalized gated delta aggregation (core/aggregation.py, fused
     fedagg) + the configured ServerOptimizer step on the aggregated
     delta (sgd | momentum | adam | yogi).

Works for any (loss_fn, params) pair — the paper's logreg/2NN/CNN and the
LM-scale models alike. For pod-scale pjit runs see fl/sharded.py.
"""
from __future__ import annotations

from typing import Callable


def make_round_fn(loss_fn: Callable, fed, *, backend: str = None) -> Callable:
    """loss_fn(params, batch)->(loss, metrics); batch={'x','y'} (or tokens).

    Returns round_fn(state, data, priority_mask, weights, rng, round_idx)
    -> (new_state, stats), with ``state`` a ``fl.engine.FederationState``
    (build one with ``init_state``). ``data`` leaves have leading client
    axis [C, n, ...]. ``backend`` (default fed.backend) picks vmap_spatial
    or scan_temporal execution — identical rounds either way."""
    from repro.fl import engine
    return engine.make_round_fn(loss_fn, fed, backend=backend)


def init_state(params, fed, num_clients=None):
    """Fresh ``fl.engine.FederationState`` (re-exported for adapters)."""
    from repro.fl import engine
    return engine.init_state(params, fed, num_clients)


def _local_solver(loss_fn, fed):
    """Back-compat alias for engine.local_solver (used by the local-only
    baseline in fl/simulator.py)."""
    from repro.fl import engine
    return engine.local_solver(loss_fn, fed)
