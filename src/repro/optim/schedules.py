"""Learning-rate schedules, including the paper's decaying rate
eta_t = 2 / (mu (t + gamma)), gamma = max{8L/mu, E}  (Theorem 1)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr):
    return lambda t: jnp.float32(lr)


def paper_decay_schedule(mu: float, gamma: float):
    """eta_t = 2 / (mu (t + gamma)) — the Theorem-1 rate."""
    return lambda t: 2.0 / (mu * (jnp.asarray(t, jnp.float32) + gamma))


def cosine_schedule(peak, total_steps, warmup=0):
    def f(t):
        t = jnp.asarray(t, jnp.float32)
        warm = peak * t / jnp.maximum(warmup, 1)
        prog = jnp.clip((t - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = 0.5 * peak * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(t < warmup, warm, cos)
    return f


def make_schedule(fed_cfg):
    if fed_cfg.lr_schedule == "constant":
        return constant_schedule(fed_cfg.lr)
    if fed_cfg.lr_schedule == "paper_decay":
        return paper_decay_schedule(fed_cfg.mu_strong, fed_cfg.gamma_decay)
    raise ValueError(fed_cfg.lr_schedule)
