"""xlstm-125m [ssm] — alternating mLSTM / sLSTM blocks. [arXiv:2405.04517]

12L d_model=768 4H d_ff=0 vocab=50304. d_ff=0: xLSTM blocks are
self-contained (mLSTM pre-up x2, sLSTM post-up GLU x4/3).
Attention-free => runs long_500k natively (O(1) state per layer).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    pattern="xlstm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        vocab_size=512, param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64, ssm_chunk=16)
