"""fedlint core: the LintRule registry, context, report, and entry points.

A lint rule is a function ``rule(ctx) -> list[LintViolation]`` registered
with ``@lint_rule("name")`` on the generic ``utils.Registry`` (the same
machinery behind the strategy/aggregator/codec tables). Rules are STATIC:
they inspect the jaxpr and the optimized HLO of a federation program —
nothing is ever executed.

    from repro.analysis import lint_program
    report = lint_program(fn, args, fed=fed, donate_argnums=(0, 1),
                          args2=args_at_other_round, meta={"m_total": M})
    assert report.ok, report.summary()

``lint_program`` traces/compiles ``fn`` itself; ``lint_hlo_text`` runs
the HLO-only subset of rules over an already-dumped artifact
(``launch/dryrun.py --dump-hlo``). Rules declare what they need
(``needs_hlo`` / ``needs_second`` / ``needs_fed``) and are skipped — and
reported as skipped, never silently dropped — when the invocation cannot
provide it. ``suppress=("rule-name",)`` disables a rule for a documented
exception; suppressions are recorded on the report.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.analysis import hlo as hlo_mod
from repro.utils import Registry

LINT_RULES = Registry("lint rule")


def lint_rule(name: str, *, needs_jaxpr: bool = False, needs_hlo: bool = False,
              needs_second: bool = False, needs_fed: bool = False):
    """Decorator: register a lint rule under ``name``.

    ``needs_jaxpr`` — the rule walks the traced jaxpr; ``needs_hlo`` —
    the rule reads the compiled HLO (alias config, constants,
    collectives); ``needs_second`` — the rule compares two lowerings
    (recompile-stability); ``needs_fed`` — the rule is config-conditional
    and needs the FedConfig to decide what "clean" means. A rule whose
    inputs are unavailable is reported in ``LintReport.skipped`` instead
    of running on partial data; a rule declaring neither jaxpr nor HLO
    runs on whichever the invocation has."""
    return LINT_RULES.register(name, rule_name=name, needs_jaxpr=needs_jaxpr,
                               needs_hlo=needs_hlo, needs_second=needs_second,
                               needs_fed=needs_fed)


@dataclass
class LintViolation:
    rule: str
    message: str
    detail: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "message": self.message,
                "detail": {k: v for k, v in self.detail.items()}}


@dataclass
class LintReport:
    label: str
    violations: list
    checked: list                      # rule names that actually ran
    skipped: dict = field(default_factory=dict)   # name -> why not run

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        if self.ok:
            return (f"[fedlint] {self.label}: clean "
                    f"({len(self.checked)} rules)")
        lines = [f"[fedlint] {self.label}: "
                 f"{len(self.violations)} violation(s)"]
        for v in self.violations:
            lines.append(f"  {v.rule}: {v.message}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"label": self.label, "ok": self.ok,
                "checked": list(self.checked), "skipped": dict(self.skipped),
                "violations": [v.to_dict() for v in self.violations]}


@dataclass
class LintContext:
    """Everything a rule may look at. HLO derivatives (parsed computations,
    alias config) are computed lazily and memoized — most rules touch one
    of them, no invocation needs all."""
    fed: Any = None
    jaxpr: Any = None                  # ClosedJaxpr of the program
    jaxpr2: Any = None                 # second lowering (other round/state)
    hlo_text: Optional[str] = None     # optimized HLO of the compiled program
    donated: list = field(default_factory=list)   # donated entry params
    meta: dict = field(default_factory=dict)
    _parsed: Any = None
    _aliases: Any = None

    @property
    def comps(self):
        if self._parsed is None and self.hlo_text is not None:
            self._parsed = hlo_mod.parse_hlo(self.hlo_text)
        return self._parsed[0] if self._parsed else None

    @property
    def entry(self):
        if self._parsed is None and self.hlo_text is not None:
            self._parsed = hlo_mod.parse_hlo(self.hlo_text)
        return self._parsed[1] if self._parsed else None

    @property
    def alias_entries(self):
        if self._aliases is None and self.hlo_text is not None:
            self._aliases = hlo_mod.parse_input_output_alias(self.hlo_text)
        return self._aliases or []


def _flat_params(args, donate_argnums):
    """Entry-parameter table of a jitted call: jax flattens the positional
    args in order, one flat leaf per XLA parameter (``lint_program``
    compiles with ``keep_unused=True`` so numbering is exactly the flat
    index). Returns the donated subset: (flat index, path, nbytes)."""
    donated, flat_idx = [], 0
    donate = set(donate_argnums)
    for i, arg in enumerate(args):
        leaves_with_path = jax.tree_util.tree_flatten_with_path(arg)[0]
        for path, leaf in leaves_with_path:
            if i in donate:
                shape = getattr(leaf, "shape", ())
                dtype = getattr(leaf, "dtype", None)
                nbytes = (int(np.prod(shape, dtype=np.int64))
                          * np.dtype(dtype).itemsize) if dtype else 0
                donated.append(
                    {"param": flat_idx,
                     "path": f"args[{i}]" + jax.tree_util.keystr(path),
                     "nbytes": int(nbytes)})
            flat_idx += 1
    return donated


def _select_rules(rules, suppress):
    names = list(rules) if rules is not None else LINT_RULES.names()
    return [n for n in names if n not in set(suppress)], \
           [n for n in names if n in set(suppress)]


def _run_rules(ctx, names, *, have_hlo, have_second):
    violations, checked, skipped = [], [], {}
    for name in names:
        rule = LINT_RULES.lookup(name)
        if rule.needs_jaxpr and ctx.jaxpr is None:
            skipped[name] = "jaxpr-level rule, HLO-only invocation"
            continue
        if rule.needs_hlo and not have_hlo:
            skipped[name] = "no compiled HLO for this invocation"
            continue
        if rule.needs_second and not have_second:
            skipped[name] = "no second lowering (pass args2=)"
            continue
        if rule.needs_fed and ctx.fed is None:
            skipped[name] = "config-conditional rule needs fed="
            continue
        violations.extend(rule(ctx))
        checked.append(name)
    return violations, checked, skipped


def lint_program(fn, args, fed=None, *, args2=None, donate_argnums=(),
                 rules=None, suppress=(), meta=None, compile_hlo=True,
                 label="program") -> LintReport:
    """Run the registered lint rules over one federation program.

    ``fn(*args)`` is traced with ``jax.make_jaxpr`` (args may be real
    arrays or ShapeDtypeStructs — nothing executes) and, when
    ``compile_hlo``, compiled with ``jax.jit(fn, donate_argnums=...,
    keep_unused=True)`` to optimized HLO. ``args2`` triggers a second
    trace for the recompile-stability rule; it must differ from ``args``
    only in VALUES (round index, state contents), never shapes.
    ``meta`` carries program facts rules key on — ``m_total`` (wire
    width), ``pod`` (cross-device program), per-rule thresholds — and is
    merged into the violation details."""
    meta = dict(meta or {})
    closed = jax.make_jaxpr(fn)(*args)
    closed2 = jax.make_jaxpr(fn)(*args2) if args2 is not None else None
    hlo_text = None
    if compile_hlo:
        jitted = jax.jit(fn, donate_argnums=donate_argnums, keep_unused=True)
        hlo_text = jitted.lower(*args).compile().as_text()
    ctx = LintContext(fed=fed, jaxpr=closed, jaxpr2=closed2,
                      hlo_text=hlo_text,
                      donated=_flat_params(args, donate_argnums), meta=meta)
    names, suppressed = _select_rules(rules, suppress)
    violations, checked, skipped = _run_rules(
        ctx, names, have_hlo=hlo_text is not None,
        have_second=closed2 is not None)
    for name in suppressed:
        skipped[name] = "suppressed"
    return LintReport(label=label, violations=violations, checked=checked,
                      skipped=skipped)


def lint_hlo_text(text, fed=None, *, rules=None, suppress=(), meta=None,
                  label="hlo") -> LintReport:
    """HLO-only lint pass over an already-compiled module (a dryrun
    ``--dump-hlo`` artifact): runs the subset of rules that read the HLO
    alone, skipping jaxpr-level ones."""
    ctx = LintContext(fed=fed, hlo_text=text, meta=dict(meta or {}))
    names, suppressed = _select_rules(rules, suppress)
    violations, checked, skipped = _run_rules(ctx, names, have_hlo=True,
                                              have_second=False)
    for name in suppressed:
        skipped[name] = "suppressed"
    return LintReport(label=label, violations=violations, checked=checked,
                      skipped=skipped)
