"""scan_async overlapped-cohort backend: staleness semantics.

Pins (1) async_depth=0 parity — BIT-identical to vmap_spatial and equal to
scan_temporal within backend tolerance, for every registered strategy;
(2) the pipeline state machine — params frozen while the pipe warms up,
deltas applied exactly async_depth rounds late, staleness discount scaling;
(3) checkpoint/resume mid-flight with the in-flight cohort restored
bit-identically; (4) participation/straggler masks under staggered
cohorts; (5) the sharded pod rounds and the partition-spec layout of the
in-flight buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.fl.simulator import (load_federation_state, run_federation,
                                save_federation_state)
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=7, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))

STRATEGIES = sorted(engine.STRATEGIES)


def _base(**kw):
    d = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
             epsilon=0.5, warmup_frac=0.0, align_stat="loss", topk=2,
             welfare_floor=0.05)
    d.update(kw)
    return FedConfig(**d)


def _run(fed, backend, r=2, seed=1, state=None, rounds=1):
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    if state is None:
        state = engine.init_state(PARAMS, fed, C)
    for i in range(rounds):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(seed + i),
                          jnp.int32(r + i))
    return state, stats


# ================================================= depth-0 parity (sync)
@pytest.mark.parametrize("selection", STRATEGIES)
def test_depth0_bit_identical_to_vmap_spatial(selection):
    """The acceptance pin: scan_async at async_depth=0 IS the synchronous
    spatial round — bit-identical state and gates, every strategy."""
    fed = _base(selection=selection)
    (ss, ts) = _run(fed, "vmap_spatial")
    (sa, ta) = _run(fed, "scan_async")
    np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                  np.asarray(ta["gates"]))
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sa)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("selection", STRATEGIES)
def test_depth0_matches_scan_temporal(selection):
    """...and agrees with the other synchronous backend to the usual
    backend-equivalence tolerance."""
    fed = _base(selection=selection)
    (st_, tt) = _run(fed, "scan_temporal")
    (sa, ta) = _run(fed, "scan_async")
    np.testing.assert_array_equal(np.asarray(tt["gates"]),
                                  np.asarray(ta["gates"]))
    for a, b in zip(jax.tree.leaves(st_), jax.tree.leaves(sa)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-6)


def test_depth0_parity_with_cohort_gather():
    """max_cohort and async compose: the depth-0 gathered round still
    equals the synchronous gathered round bitwise."""
    (ss, ts) = _run(_base(max_cohort=5), "vmap_spatial")
    (sa, ta) = _run(_base(max_cohort=5), "scan_async")
    np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                  np.asarray(ta["gates"]))
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sa)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_depth_requires_async_backend():
    """Synchronous backends refuse a config asking for a pipeline they
    would silently ignore."""
    with pytest.raises(ValueError, match="scan_async"):
        engine.make_round_fn(LOSS, _base(async_depth=2), backend="vmap_spatial")
    with pytest.raises(ValueError, match="scan_async"):
        engine.make_round_fn(LOSS, _base(async_depth=1,
                                         backend="scan_temporal"))


# ================================================= in-flight buffer layout
def test_inflight_layout_follows_config():
    st0 = engine.init_state(PARAMS, _base(), C)
    assert st0.inflight == ()
    fed = _base(async_depth=3, agg_dtype="bfloat16", backend="scan_async")
    st = engine.init_state(PARAMS, fed, C)
    assert set(st.inflight) == {"delta", "valid"}
    assert st.inflight["valid"].shape == (3,)
    for p, d in zip(jax.tree.leaves(PARAMS),
                    jax.tree.leaves(st.inflight["delta"])):
        assert d.shape == (3,) + p.shape
        assert d.dtype == jnp.bfloat16          # the delta wire dtype
    # registered pytree: the buffer rides flatten/unflatten like any leaf
    leaves, treedef = jax.tree.flatten(st)
    assert isinstance(jax.tree.unflatten(treedef, leaves),
                      engine.FederationState)


# ================================================= pipeline semantics
def test_pipeline_applies_deltas_depth_rounds_late():
    """Rounds 0..D-1 leave params (and optimizer moments) untouched; the
    first cohort's delta lands exactly at round D."""
    D = 2
    fed = _base(backend="scan_async", async_depth=D, server_opt="adam",
                epsilon=1e9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    for r in range(D + 1):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(r),
                          jnp.int32(r))
        frozen = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(PARAMS)))
        assert frozen == (r < D), f"round {r}"
        assert float(stats["applied_valid"]) == (0.0 if r < D else 1.0)
        assert int(stats["staleness"]) == D
        assert float(stats["inflight_occupancy"]) == min(r + 1, D)
        # warm-up rounds must not tick the adam step counter either
        assert int(state.opt_state["t"]) == max(0, r - D + 1)


def test_staleness_discount_scales_applied_delta():
    """depth=1, decay=0.5, sgd server: the delta applied at round t+1 is
    exactly half the delta the synchronous round would have applied."""
    fed_sync = _base(epsilon=1e9)
    sync_params = _run(fed_sync, "vmap_spatial", r=0, seed=1)[0].params
    d0 = jax.tree.map(lambda a, b: np.asarray(a) - np.asarray(b),
                      sync_params, PARAMS)

    fed = _base(backend="scan_async", async_depth=1, staleness_decay=0.5,
                epsilon=1e9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    # round 0 buffers d0 (same PRNG key and round index as the sync round);
    # round 1 applies 0.5 * d0
    state, _ = fn(state, DATA, PM, W, jax.random.PRNGKey(1), jnp.int32(0))
    state, _ = fn(state, DATA, PM, W, jax.random.PRNGKey(99), jnp.int32(1))
    for p, p0, d in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(PARAMS), jax.tree.leaves(d0)):
        np.testing.assert_allclose(np.asarray(p), np.asarray(p0) + 0.5 * d,
                                   atol=1e-6)


def test_drain_inflight_flushes_stragglers():
    """depth=1, decay=1: one round + drain equals the synchronous round
    bit-identically (the drained delta takes the same apply path)."""
    fed = _base(epsilon=1e9)
    sync = run_federation(LOSS, PARAMS, fed.replace(rounds=1), FEDN,
                          eval_every=1)
    asy = run_federation(
        LOSS, PARAMS,
        fed.replace(rounds=1, backend="scan_async", async_depth=1), FEDN,
        eval_every=1, drain_inflight=True)
    for a, b in zip(jax.tree.leaves(sync.state.params),
                    jax.tree.leaves(asy.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the drained buffer is empty
    assert float(jnp.sum(asy.state.inflight["valid"])) == 0.0


def test_drain_is_noop_for_sync_state():
    st = engine.init_state(PARAMS, _base(), C)
    assert engine.drain_inflight(_base(), st) is st


# ================================================= masks under staggering
def test_depth0_parity_under_participation_and_stragglers():
    """Partial participation + straggler cadence: the depth-0 async round
    still reproduces the synchronous round bitwise, seed by seed."""
    fed = _base(epsilon=1e9, participation=0.6, straggler_period=3,
                max_cohort=5)
    for seed in range(3):
        (ss, ts) = _run(fed, "vmap_spatial", r=seed, seed=seed)
        (sa, ta) = _run(fed, "scan_async", r=seed, seed=seed)
        np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                      np.asarray(ta["gates"]))
        for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sa)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staggered_cohorts_respect_masks_and_backlog():
    """With a live pipeline (D=2), gates stay truthful: binary, priority
    honoured under participation sampling, cohort budget enforced, and the
    backlog ledger advances exactly as the gates dictate."""
    fed = _base(backend="scan_async", async_depth=2, epsilon=1e9,
                participation=0.6, straggler_period=3, max_cohort=4)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    pm = np.asarray(PM).astype(bool)
    for r in range(5):
        prev_backlog = np.asarray(state.backlog)
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(r),
                          jnp.int32(r))
        gates = np.asarray(stats["gates"])
        assert set(np.unique(gates)) <= {0.0, 1.0}
        assert gates.sum() <= fed.max_cohort
        assert gates[pm].sum() >= 1.0            # priority never starves out
        bl = np.asarray(state.backlog)
        assert np.all(bl[gates > 0] == 0)        # aggregated clients reset
        assert np.all(bl >= 0) and np.all(bl <= prev_backlog + 1)


# ================================================= checkpoint / resume
def test_async_checkpoint_resume_mid_flight(tmp_path):
    """Interrupt an async run with cohorts still in flight; the resumed run
    must be bit-identical to the uninterrupted one — in-flight deltas,
    their validity mask, params, moments, PRNG stream, stats."""
    path = str(tmp_path / "async.msgpack")
    fed = FedConfig(num_clients=C, num_priority=3, rounds=8, local_epochs=2,
                    epsilon=0.3, lr=0.1, warmup_frac=0.0, batch_size=32,
                    align_stat="loss", server_opt="yogi", server_lr=0.3,
                    max_cohort=5, backend="scan_async", async_depth=2,
                    staleness_decay=0.9)
    full = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=4)

    half = run_federation(LOSS, PARAMS, fed.replace(rounds=5), FEDN,
                          eval_every=4)
    # the interrupted state really is mid-flight: both slots occupied
    assert float(jnp.sum(half.state.inflight["valid"])) == 2.0
    save_federation_state(path, half.state, half.rng, 5)
    like = engine.init_state(PARAMS, fed, C)
    state, rng, step = load_federation_state(path, like)
    assert step == 5
    # the in-flight cohort buffer survived the round-trip bit-identically
    for a, b in zip(jax.tree.leaves(half.state.inflight),
                    jax.tree.leaves(state.inflight)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=4,
                             state=state, rng=rng, start_round=step)
    for a, b in zip(jax.tree.leaves(full.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.global_loss[5:]),
                                  np.asarray(resumed.global_loss))


def test_checkpoint_layout_mismatch_raises_helpfully(tmp_path):
    """Restoring an async checkpoint with the wrong async_depth (different
    in-flight layout) fails with an actionable error, not a bare assert."""
    path = str(tmp_path / "st.msgpack")
    fed = _base(backend="scan_async", async_depth=2)
    st = engine.init_state(PARAMS, fed, C)
    save_federation_state(path, st, jax.random.PRNGKey(0), 3)
    with pytest.raises(ValueError, match="async_depth"):
        load_federation_state(
            path, engine.init_state(PARAMS, _base(backend="scan_async",
                                                  async_depth=3), C))
    with pytest.raises(ValueError, match="async_depth"):
        load_federation_state(path, engine.init_state(PARAMS, _base(), C))


# ================================================= sharded pod rounds
def test_sharded_async_rounds_pipeline():
    """Both pod modes run the same staleness state machine: params frozen
    while the pipe warms up, moving once the first cohort lands, and the
    depth-0 spatial round stays bit-identical to the sync spatial round."""
    from repro.configs import get_smoke
    from repro.fl import sharded
    from repro.models import get_model
    from tests.test_sharded import _batch

    cfg = get_smoke("qwen1_5_0_5b").replace(remat=False)
    model = get_model(cfg)
    batch = _batch()
    p0 = model.init(jax.random.PRNGKey(0))
    sync_fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05)
    async_fed = sync_fed.replace(async_depth=1, staleness_decay=1.0,
                                 backend="scan_async")

    s_sync, _ = jax.jit(sharded.make_spatial_round(model, sync_fed, 4))(
        engine.init_state(p0, sync_fed, 4), batch)

    for mk in (sharded.make_spatial_round, sharded.make_temporal_round):
        step = jax.jit(mk(model, async_fed, 4))
        st = engine.init_state(p0, async_fed, 4)
        st, t0 = step(st, batch, 0)
        for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(p0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(t0["applied_valid"]) == 0.0
        st, t1 = step(st, batch, 1)
        assert float(t1["applied_valid"]) == 1.0
        changed = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(p0)))
        assert changed
        if mk is sharded.make_spatial_round:
            # round 0 buffered exactly the sync round's delta (decay 1, so
            # round 1 applied it unscaled): params == one sync round
            for a, b in zip(jax.tree.leaves(st.params),
                            jax.tree.leaves(s_sync.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)


def test_federation_state_specs_cover_inflight():
    """The pjit lowering seam: spec tree structure matches the async state
    structure, and every delta slot inherits its param's layout behind the
    leading ring-buffer axis."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding.specs import auto_param_specs, federation_state_specs

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    pspecs = auto_param_specs(jax.eval_shape(lambda: params), mesh)
    fed = FedConfig(server_opt="yogi", async_depth=2, backend="scan_async")
    shapes = jax.eval_shape(lambda: engine.init_state(params, fed, C))
    specs = federation_state_specs(fed, pspecs)
    is_p = lambda x: isinstance(x, P)
    assert (jax.tree.structure(shapes)
            == jax.tree.structure(specs, is_leaf=is_p))
    for psp, dsp in zip(jax.tree.leaves(pspecs, is_leaf=is_p),
                        jax.tree.leaves(specs.inflight["delta"],
                                        is_leaf=is_p)):
        assert tuple(dsp) == (None,) + tuple(psp)
    # sync configs keep the old layout
    assert federation_state_specs(FedConfig(), pspecs).inflight == ()
