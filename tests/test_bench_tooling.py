"""Benchmark entrypoint tooling: a raising (or silently empty) suite must
fail the run with a nonzero exit instead of being swallowed, and the
scripts/check_bench.py CI gate must catch rounds/sec regressions while
letting new rows through."""
import importlib.util
import json
import os
import sys
import types

import pytest


def _fake_suite(name, fn):
    mod = types.ModuleType(name)
    mod.run = fn
    sys.modules[name] = mod
    return mod


def test_bench_runner_exits_nonzero_on_suite_error(monkeypatch, tmp_path,
                                                   capsys):
    import benchmarks.run as br

    _fake_suite("benchmarks._boom", lambda fast=True: (_ for _ in ()).throw(
        RuntimeError("boom")))
    _fake_suite("benchmarks._fine", lambda fast=True: [{"ok": 1}])
    monkeypatch.setattr(br, "SUITES", [("boom", "benchmarks._boom"),
                                       ("fine", "benchmarks._fine")])
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    with pytest.raises(SystemExit) as exc:
        br.main()
    assert exc.value.code == 1
    out = capsys.readouterr().out
    # the failing suite is reported AND the later suite still ran
    assert "ERROR:RuntimeError:boom" in out
    assert "fine," in out


def test_bench_runner_exits_zero_when_clean(monkeypatch, tmp_path):
    import benchmarks.run as br

    _fake_suite("benchmarks._fine2", lambda fast=True: [{"ok": 1}])
    monkeypatch.setattr(br, "SUITES", [("fine2", "benchmarks._fine2")])
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    assert br.main() is None


def test_bench_runner_exits_nonzero_on_empty_output(monkeypatch, tmp_path,
                                                    capsys):
    """A suite that returns NO rows produces an empty output artifact —
    that must fail the run just like a raising suite does."""
    import benchmarks.run as br

    _fake_suite("benchmarks._empty", lambda fast=True: [])
    monkeypatch.setattr(br, "SUITES", [("empty", "benchmarks._empty")])
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    with pytest.raises(SystemExit) as exc:
        br.main()
    assert exc.value.code == 1
    assert "EmptyOutput" in capsys.readouterr().out


def test_bench_runner_resolves_module_attr_suites(monkeypatch, tmp_path,
                                                  capsys):
    """SUITES entries may name a non-default entry point as module:attr —
    how bench_round.py --quick is registered (round_pipeline_quick)."""
    import benchmarks.run as br

    mod = types.ModuleType("benchmarks._multi")
    mod.run = lambda fast=True: (_ for _ in ()).throw(AssertionError("wrong fn"))
    mod.run_quick = lambda fast=True: [{"ok": 1}]
    sys.modules["benchmarks._multi"] = mod
    monkeypatch.setattr(br, "SUITES",
                        [("multi_quick", "benchmarks._multi:run_quick")])
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    assert br.main() is None
    assert "multi_quick," in capsys.readouterr().out


def test_bench_runner_skips_opt_in_suites_unless_only(monkeypatch, tmp_path,
                                                      capsys):
    """Opt-in suites (local smoke entry points) run only under --only."""
    import benchmarks.run as br

    _fake_suite("benchmarks._optin", lambda fast=True: [{"ok": 1}])
    monkeypatch.setattr(br, "SUITES", [("smoke_only", "benchmarks._optin")])
    monkeypatch.setattr(br, "OPT_IN_SUITES", {"smoke_only"})
    monkeypatch.setattr(sys, "argv", ["run.py"])
    monkeypatch.chdir(tmp_path)
    assert br.main() is None
    assert "smoke_only," not in capsys.readouterr().out
    # a SUBSTRING --only must not drag the opt-in suite in...
    monkeypatch.setattr(sys, "argv", ["run.py", "--only", "smoke"])
    assert br.main() is None
    assert "smoke_only," not in capsys.readouterr().out
    # ...only its exact name does
    monkeypatch.setattr(sys, "argv", ["run.py", "--only", "smoke_only"])
    assert br.main() is None
    assert "smoke_only," in capsys.readouterr().out


# ===================================================== check_bench CI gate
def _check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(os.path.dirname(__file__), "..",
                                    "scripts", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


CB = _check_bench()


def _rows(*rps):
    return [{"path": f"p{i}", "clients": 64, "rounds_per_sec": r}
            for i, r in enumerate(rps)]


def _gate(tmp_path, baseline, fresh, *extra):
    b, f = tmp_path / "base.json", tmp_path / "fresh.json"
    b.write_text(json.dumps(baseline))
    f.write_text(json.dumps(fresh))
    return CB.main([str(b), str(f), *extra])


def test_check_bench_green_on_identical(tmp_path):
    assert _gate(tmp_path, _rows(4.0, 8.0), _rows(4.0, 8.0)) == 0


def test_check_bench_tolerates_small_regression(tmp_path):
    # 10% down is inside the default 15% tolerance
    assert _gate(tmp_path, _rows(4.0), _rows(3.6)) == 0


def test_check_bench_fails_on_large_regression(tmp_path):
    # 20% down on one row fails the gate even when the other row improved
    assert _gate(tmp_path, _rows(4.0, 8.0), _rows(3.2, 9.0)) == 1
    # custom tolerance rescues it
    assert _gate(tmp_path, _rows(4.0, 8.0), _rows(3.2, 9.0),
                 "--tolerance", "0.3") == 0


def test_check_bench_normalizes_common_mode_slowdown(tmp_path):
    """A uniformly slower box (different CI hardware than the machine that
    committed the baseline) must stay green: with >= 3 rows the gate judges
    each row against the median ratio."""
    assert _gate(tmp_path, _rows(4.0, 8.0, 2.0, 6.0),
                 _rows(2.0, 4.0, 1.0, 3.0)) == 0
    # ...but --absolute restores raw gating for same-machine use
    assert _gate(tmp_path, _rows(4.0, 8.0, 2.0, 6.0),
                 _rows(2.0, 4.0, 1.0, 3.0), "--absolute") == 1


def test_check_bench_catches_row_falling_behind_the_fleet(tmp_path):
    """One row 40% down while its peers hold: fails even though a uniform
    factor would have excused it."""
    assert _gate(tmp_path, _rows(4.0, 8.0, 2.0, 6.0),
                 _rows(4.0, 8.0, 2.0, 3.6)) == 1


def test_check_bench_uniform_speedup_not_penalized(tmp_path):
    """Normalization caps at 1.0: rows that merely stayed flat while others
    sped up are NOT failed."""
    assert _gate(tmp_path, _rows(4.0, 8.0, 2.0, 6.0),
                 _rows(6.0, 12.0, 3.0, 6.0)) == 0


def test_check_bench_allows_new_rows(tmp_path):
    fresh = _rows(4.0) + [{"path": "brand_new", "rounds_per_sec": 0.1}]
    assert _gate(tmp_path, _rows(4.0), fresh) == 0


def test_check_bench_fails_on_vanished_rows(tmp_path):
    assert _gate(tmp_path, _rows(4.0, 8.0), _rows(4.0)) == 1


def test_check_bench_ignores_metricless_rows(tmp_path):
    base = _rows(4.0) + [{"path": "convergence", "rounds_to_target": 7}]
    fresh = _rows(4.0) + [{"path": "convergence", "rounds_to_target": 12}]
    assert _gate(tmp_path, base, fresh) == 0


def test_check_bench_matches_rows_by_key_not_position(tmp_path):
    base = [{"path": "a", "max_cohort": 16, "rounds_per_sec": 4.0},
            {"path": "a", "max_cohort": 32, "rounds_per_sec": 2.0}]
    fresh = list(reversed(json.loads(json.dumps(base))))
    assert _gate(tmp_path, base, fresh) == 0


def test_check_bench_rejects_unreadable_input(tmp_path):
    b = tmp_path / "base.json"
    b.write_text("[]")
    f = tmp_path / "fresh.json"
    f.write_text(json.dumps(_rows(1.0)))
    with pytest.raises(SystemExit) as exc:
        CB.load_rows(str(b))
    assert exc.value.code == 2
    with pytest.raises(SystemExit):
        CB.main([str(tmp_path / "missing.json"), str(f)])
