"""Scan-aware HLO cost and shape analysis.

XLA's built-in ``compiled.cost_analysis()`` counts every ``while`` body
ONCE, so any program built from ``lax.scan`` (our layer stacks, local-epoch
loops, loss chunking) is undercounted by the trip counts. This module
re-derives roofline quantities directly from the optimized HLO text:

  * builds the computation call graph (entry -> fusions / calls / while
    bodies) and multiplies while bodies by ``known_trip_count``,
  * counts dot/convolution FLOPs exactly from operand shapes (two-pass
    name->shape symbol table per computation: CPU HLO references operands
    by name only),
  * estimates HBM traffic as 2x result bytes of non-aliasing top-level ops
    (each tensor written once, read ~once; fusion internals stay on-chip),
  * attributes collective bytes AND op counts at true multiplicity,
  * records every ``constant`` op's materialized size (the fedlint
    no-large-literal rule's input) and the module's ``input_output_alias``
    config (the donation-honored rule's input).

All quantities are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import gzip
import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM bytes of their own
_ALIAS_KINDS = {"tuple", "get-tuple-element", "parameter", "constant",
                "bitcast", "after-all", "iota", "broadcast", "reshape",
                "while", "conditional", "call"}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_OP = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_KIND = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
# one aliasing entry of the module-level input_output_alias config:
#   { <output index> }: (<param number>, { <param index> }[, <kind>])
_ALIAS_ENTRY = re.compile(
    r"\{\s*([\d,\s]*)\}\s*:\s*\(\s*(\d+)\s*,\s*\{\s*([\d,\s]*)\}"
    r"(?:\s*,\s*([\w\-]+))?\s*\)")


def _dims_of(blob: str):
    m = _SHAPE.search(blob)
    return [int(d) for d in m.group(2).split(",") if d] if m else None


def _split_operands(blob: str) -> list[str]:
    """Split an operand list at top-level commas only. Operand entries may
    carry inline shapes (``f32[32,48]{1,0} %arg``) whose dims/layout contain
    commas, so a naive ``split(",")`` truncates them."""
    parts, cur, depth = [], [], 0
    for ch in blob:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _operand_dims(operand: str, shapes: dict):
    """Dims of one operand: inline shape if present, else symbol table."""
    if "[" in operand:
        return _dims_of(operand)
    name = operand.split(" ")[-1].lstrip("%")
    return shapes[name][1] if name in shapes else None


def _result_bytes(blob: str) -> int:
    """Bytes of the result shape(s) — the text before the op kind."""
    total = 0
    for dt, dims in _SHAPE.findall(blob):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Comp:
    name: str
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: dict = field(default_factory=dict)        # kind -> bytes
    coll_n: dict = field(default_factory=dict)      # kind -> op count
    transcendental: float = 0.0
    calls: list = field(default_factory=list)       # (callee, multiplier)
    constants: list = field(default_factory=list)   # (op name, bytes, shape blob)
    coll_ops: list = field(default_factory=list)    # per-op collective records


def _split_result_op(rhs: str):
    """rhs = '<result shapes> kind(<operands>), attrs' -> (result_blob, kind, rest)."""
    m = _KIND.match(rhs)
    if not m:
        return rhs, "", ""
    kind = m.group(1)
    idx = rhs.find(kind + "(")
    return rhs[:idx], kind, rhs[idx:]


def parse_input_output_alias(text: str) -> list[dict]:
    """The module's ``input_output_alias`` config as a list of entries
    ``{"output_index": tuple, "param_number": int, "param_index": tuple,
    "kind": str}``. XLA emits it in the ``HloModule`` header when buffer
    donation survived compilation; a donated-but-dropped buffer simply has
    no entry — which is exactly what the donation-honored rule checks."""
    start = text.find("input_output_alias={")
    if start < 0:
        return []
    # the config nests braces ({ {0}: (0, {}) }): take the balanced span
    i = start + len("input_output_alias=")
    depth, j = 0, i
    for j in range(i, min(len(text), i + 100_000)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    blob = text[i + 1:j]
    entries = []
    for out_idx, param, param_idx, kind in _ALIAS_ENTRY.findall(blob):
        entries.append({
            "output_index": tuple(int(i) for i in out_idx.split(",") if i.strip()),
            "param_number": int(param),
            "param_index": tuple(int(i) for i in param_idx.split(",") if i.strip()),
            "kind": kind or "may-alias",
        })
    return entries


def _groups_blob(rest: str):
    """The raw ``replica_groups=...`` attribute of one collective op, or
    None if absent. Handles both the explicit brace form
    (``{{0,1},{2,3}}``, ``{}``) and the iota form
    (``[32,16]<=[16,16,2]T(2,0,1)``) — returned verbatim;
    ``replica_group_members`` decides which are decodable."""
    key = "replica_groups="
    i = rest.find(key)
    if i < 0:
        return None
    j = i + len(key)
    if j >= len(rest):
        return None
    if rest[j] == "{":
        depth, k = 0, j
        while k < len(rest):
            if rest[k] == "{":
                depth += 1
            elif rest[k] == "}":
                depth -= 1
                if depth == 0:
                    return rest[j:k + 1]
            k += 1
        return rest[j:]
    # iota form: runs to the first comma at bracket depth 0
    depth, k = 0, j
    while k < len(rest):
        ch = rest[k]
        if ch in "[(":
            depth += 1
        elif ch in "])":
            depth -= 1
        elif ch == "," and depth == 0:
            break
        k += 1
    return rest[j:k]


def replica_group_members(blob) -> "list[list[int]] | None":
    """Decode an explicit replica_groups blob into member lists.
    ``{}`` (all devices, one group) decodes to ``[]``; the iota form (and
    anything else undecodable) returns None — callers must treat those
    conservatively."""
    if blob is None:
        return None
    blob = blob.strip()
    if not blob.startswith("{"):
        return None
    inner = blob[1:-1].strip()
    if not inner:
        return []
    groups = re.findall(r"\{([\d,\s]*)\}", inner)
    if not groups:
        return None
    return [[int(d) for d in g.split(",") if d.strip()] for g in groups]


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Comp] = {}
    entry = None
    # --- split into computation blocks --------------------------------------
    blocks: list[tuple[str, bool, list[str]]] = []
    cur_name, cur_lines, cur_entry = None, [], False
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            if cur_name is not None:
                blocks.append((cur_name, cur_entry, cur_lines))
            cur_name, cur_lines = hdr.group(1), []
            cur_entry = line.startswith("ENTRY")
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        blocks.append((cur_name, cur_entry, cur_lines))

    for name, is_entry, lines in blocks:
        comp = Comp(name)
        comps[name] = comp
        if is_entry:
            entry = name
        shapes: dict[str, list] = {}
        parsed = []
        for line in lines:
            op = _OP.match(line)
            if not op:
                continue
            oname, rhs = op.group(1), op.group(2)
            result_blob, kind, rest = _split_result_op(rhs)
            dims = _dims_of(result_blob)
            if dims is not None:
                shapes[oname] = (result_blob, dims)
            parsed.append((oname, rhs, result_blob, kind, rest))

        for oname, rhs, result_blob, kind, rest in parsed:
            if kind == "dot":
                res_dims = _dims_of(result_blob) or []
                opm = _OPERANDS.search(rest)
                lhs_dims = None
                if opm:
                    operands = _split_operands(opm.group(1))
                    if operands:
                        lhs_dims = _operand_dims(operands[0], shapes)
                cm = _LHS_CONTRACT.search(rest)
                contract = [int(i) for i in cm.group(1).split(",") if i] if cm else []
                if lhs_dims is not None:
                    k = 1
                    for i in contract:
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
                    out = 1
                    for d in res_dims:
                        out *= d
                    comp.dot_flops += 2.0 * out * k
            elif kind == "convolution":
                res_dims = _dims_of(result_blob) or []
                opm = _OPERANDS.search(rest)
                kern_dims = None
                if opm:
                    parts = _split_operands(opm.group(1))
                    if len(parts) >= 2:
                        kern_dims = _operand_dims(parts[1], shapes)
                if kern_dims and res_dims:
                    out = 1
                    for d in res_dims:
                        out *= d
                    kf = 1
                    for d in kern_dims:
                        kf *= d
                    comp.dot_flops += 2.0 * out * max(kf // max(res_dims[-1], 1), 1)
            elif kind in ("exponential", "tanh", "log", "rsqrt", "power", "logistic"):
                dims = _dims_of(result_blob)
                if dims:
                    n = 1
                    for d in dims:
                        n *= d
                    comp.transcendental += n
            elif kind == "constant":
                comp.constants.append(
                    (oname, _result_bytes(result_blob), result_blob.strip()))

            if kind in COLLECTIVES:
                comp.coll[kind] = comp.coll.get(kind, 0) + _result_bytes(result_blob)
                comp.coll_n[kind] = comp.coll_n.get(kind, 0) + 1
                comp.coll_ops.append({"kind": kind,
                                      "bytes": _result_bytes(result_blob),
                                      "groups": _groups_blob(rest), "n": 1.0})

            if kind not in _ALIAS_KINDS:
                comp.bytes_accessed += 2.0 * _result_bytes(result_blob)

            called = _CALLED.search(rest)
            if called:
                mult = 1.0
                if kind == "while":
                    tm = _TRIP.search(rest)
                    mult = float(tm.group(1)) if tm else 1.0
                comp.calls.append((called.group(1), mult))
                condm = _COND.search(rest)
                if condm:
                    comp.calls.append((condm.group(1), 1.0))
    return comps, entry


def aggregate(comps: dict, entry: str) -> dict:
    memo: dict[str, dict] = {}

    def visit(name: str) -> dict:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_n": {},
                    "coll_ops": [], "transc": 0.0}
        on_chip = ("fused" in name) or name.startswith("region")
        total = {"flops": c.dot_flops,
                 "bytes": 0.0 if on_chip else c.bytes_accessed,
                 "coll": dict(c.coll), "coll_n": dict(c.coll_n),
                 "coll_ops": [dict(op) for op in c.coll_ops],
                 "transc": c.transcendental}
        memo[name] = total      # (cycles impossible in HLO)
        for callee, mult in c.calls:
            sub = visit(callee)
            total["flops"] += mult * sub["flops"]
            total["transc"] += mult * sub["transc"]
            total["bytes"] += mult * sub["bytes"]
            for k, v in sub["coll"].items():
                total["coll"][k] = total["coll"].get(k, 0) + mult * v
            for k, v in sub["coll_n"].items():
                total["coll_n"][k] = total["coll_n"].get(k, 0) + mult * v
            total["coll_ops"].extend(
                {**op, "n": mult * op["n"]} for op in sub["coll_ops"])
        return total

    return visit(entry)


def hlo_constants(comps: dict) -> list[tuple[str, str, int]]:
    """Every materialized ``constant`` op across the module:
    (computation name, op name, bytes). Constants are materialized once
    regardless of while-body trip counts, so no multiplicity applies."""
    out = []
    for cname, comp in comps.items():
        for oname, nbytes, _blob in comp.constants:
            out.append((cname, oname, nbytes))
    return out


def analyze_text(text: str) -> dict:
    comps, entry = parse_hlo(text)
    agg = aggregate(comps, entry)
    agg["coll_total"] = float(sum(agg["coll"].values()))
    return agg


def analyze_file(path: str) -> dict:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze_text(f.read())


def read_hlo_file(path: str) -> str:
    """Raw HLO text of a dryrun artifact (gzip-aware) — the lint entry
    point for ``lint_hlo_text`` over ``--dump-hlo`` output."""
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return f.read()
