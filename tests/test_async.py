"""scan_async overlapped-cohort backend: staleness semantics.

Pins (1) async_depth=0 parity — BIT-identical to vmap_spatial and equal to
scan_temporal within backend tolerance, for every registered strategy;
(2) the pipeline state machine — params frozen while the pipe warms up,
deltas applied exactly async_depth rounds late, staleness discount scaling;
(3) checkpoint/resume mid-flight with the in-flight cohort restored
bit-identically; (4) participation/straggler masks under staggered
cohorts; (5) the sharded pod rounds and the partition-spec layout of the
in-flight buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.fl.simulator import (load_federation_state, run_federation,
                                save_federation_state)
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=7, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))

STRATEGIES = sorted(engine.STRATEGIES)


def _base(**kw):
    d = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
             epsilon=0.5, warmup_frac=0.0, align_stat="loss", topk=2,
             welfare_floor=0.05)
    d.update(kw)
    return FedConfig(**d)


def _run(fed, backend, r=2, seed=1, state=None, rounds=1):
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    if state is None:
        state = engine.init_state(PARAMS, fed, C)
    for i in range(rounds):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(seed + i),
                          jnp.int32(r + i))
    return state, stats


# ================================================= depth-0 parity (sync)
@pytest.mark.parametrize("selection", STRATEGIES)
def test_depth0_bit_identical_to_vmap_spatial(selection):
    """The acceptance pin: scan_async at async_depth=0 IS the synchronous
    spatial round — bit-identical state and gates, every strategy."""
    fed = _base(selection=selection)
    (ss, ts) = _run(fed, "vmap_spatial")
    (sa, ta) = _run(fed, "scan_async")
    np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                  np.asarray(ta["gates"]))
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sa)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("selection", STRATEGIES)
def test_depth0_matches_scan_temporal(selection):
    """...and agrees with the other synchronous backend to the usual
    backend-equivalence tolerance."""
    fed = _base(selection=selection)
    (st_, tt) = _run(fed, "scan_temporal")
    (sa, ta) = _run(fed, "scan_async")
    np.testing.assert_array_equal(np.asarray(tt["gates"]),
                                  np.asarray(ta["gates"]))
    for a, b in zip(jax.tree.leaves(st_), jax.tree.leaves(sa)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-6)


def test_depth0_parity_with_cohort_gather():
    """max_cohort and async compose: the depth-0 gathered round still
    equals the synchronous gathered round bitwise."""
    (ss, ts) = _run(_base(max_cohort=5), "vmap_spatial")
    (sa, ta) = _run(_base(max_cohort=5), "scan_async")
    np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                  np.asarray(ta["gates"]))
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sa)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_depth_requires_async_backend():
    """Synchronous backends refuse a config asking for a pipeline they
    would silently ignore."""
    with pytest.raises(ValueError, match="scan_async"):
        engine.make_round_fn(LOSS, _base(async_depth=2), backend="vmap_spatial")
    with pytest.raises(ValueError, match="scan_async"):
        engine.make_round_fn(LOSS, _base(async_depth=1,
                                         backend="scan_temporal"))


# ================================================= in-flight buffer layout
def test_inflight_layout_follows_config():
    st0 = engine.init_state(PARAMS, _base(), C)
    assert st0.inflight == ()
    fed = _base(async_depth=3, agg_dtype="bfloat16", backend="scan_async")
    st = engine.init_state(PARAMS, fed, C)
    assert set(st.inflight) == {"delta", "valid", "age"}
    assert st.inflight["valid"].shape == (3,)
    assert st.inflight["age"].shape == (3,)
    assert st.inflight["age"].dtype == jnp.int32
    for p, d in zip(jax.tree.leaves(PARAMS),
                    jax.tree.leaves(st.inflight["delta"])):
        assert d.shape == (3,) + p.shape
        assert d.dtype == jnp.bfloat16          # the delta wire dtype
    # the drift-reference sketch leaf exists iff adaptive_staleness asks
    assert st.last_delta == ()
    ad = engine.init_state(
        PARAMS, fed.replace(adaptive_staleness=True, sketch_dim=128), C)
    assert ad.last_delta.shape == (128,)
    assert ad.last_delta.dtype == jnp.float32
    # registered pytree: the buffer rides flatten/unflatten like any leaf
    leaves, treedef = jax.tree.flatten(st)
    assert isinstance(jax.tree.unflatten(treedef, leaves),
                      engine.FederationState)


def test_async_config_validation():
    with pytest.raises(ValueError, match="async_mode"):
        engine.init_state(PARAMS, _base(backend="scan_async", async_depth=2,
                                        async_mode="lifo"), C)
    with pytest.raises(ValueError, match="min_lag"):
        engine.init_state(PARAMS, _base(backend="scan_async", async_depth=2,
                                        async_mode="ready", min_lag=3), C)
    # min_lag=0 would silently behave as 1 (push happens after the pop
    # phase) — rejected rather than documented away
    with pytest.raises(ValueError, match="min_lag"):
        engine.init_state(PARAMS, _base(backend="scan_async", async_depth=2,
                                        async_mode="ready", min_lag=0), C)
    # fifo ignores min_lag entirely — an out-of-range value must not trip it
    engine.init_state(PARAMS, _base(backend="scan_async", async_depth=2,
                                    async_mode="fifo", min_lag=9), C)


# ================================================= pipeline semantics
def test_pipeline_applies_deltas_depth_rounds_late():
    """Rounds 0..D-1 leave params (and optimizer moments) untouched; the
    first cohort's delta lands exactly at round D."""
    D = 2
    fed = _base(backend="scan_async", async_depth=D, server_opt="adam",
                epsilon=1e9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    for r in range(D + 1):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(r),
                          jnp.int32(r))
        frozen = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(PARAMS)))
        assert frozen == (r < D), f"round {r}"
        assert float(stats["applied_valid"]) == (0.0 if r < D else 1.0)
        # the staleness stat is the MEASURED age of the applied slot: 0 on
        # warm-up rounds where nothing landed (the PR 5 stats fix), D once
        # the pipe flows
        assert int(stats["staleness"]) == (0 if r < D else D)
        assert float(stats["inflight_occupancy"]) == min(r + 1, D)
        # warm-up rounds must not tick the adam step counter either
        assert int(state.opt_state["t"]) == max(0, r - D + 1)


def test_staleness_discount_scales_applied_delta():
    """depth=1, decay=0.5, sgd server: the delta applied at round t+1 is
    exactly half the delta the synchronous round would have applied."""
    fed_sync = _base(epsilon=1e9)
    sync_params = _run(fed_sync, "vmap_spatial", r=0, seed=1)[0].params
    d0 = jax.tree.map(lambda a, b: np.asarray(a) - np.asarray(b),
                      sync_params, PARAMS)

    fed = _base(backend="scan_async", async_depth=1, staleness_decay=0.5,
                epsilon=1e9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    # round 0 buffers d0 (same PRNG key and round index as the sync round);
    # round 1 applies 0.5 * d0
    state, _ = fn(state, DATA, PM, W, jax.random.PRNGKey(1), jnp.int32(0))
    state, _ = fn(state, DATA, PM, W, jax.random.PRNGKey(99), jnp.int32(1))
    for p, p0, d in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(PARAMS), jax.tree.leaves(d0)):
        np.testing.assert_allclose(np.asarray(p), np.asarray(p0) + 0.5 * d,
                                   atol=1e-6)


def test_drain_inflight_flushes_stragglers():
    """depth=1, decay=1: one round + drain equals the synchronous round
    bit-identically (the drained delta takes the same apply path)."""
    fed = _base(epsilon=1e9)
    sync = run_federation(LOSS, PARAMS, fed.replace(rounds=1), FEDN,
                          eval_every=1)
    asy = run_federation(
        LOSS, PARAMS,
        fed.replace(rounds=1, backend="scan_async", async_depth=1), FEDN,
        eval_every=1, drain_inflight=True)
    for a, b in zip(jax.tree.leaves(sync.state.params),
                    jax.tree.leaves(asy.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the drained buffer is empty
    assert float(jnp.sum(asy.state.inflight["valid"])) == 0.0


def test_drain_is_noop_for_sync_state():
    st = engine.init_state(PARAMS, _base(), C)
    assert engine.drain_inflight(_base(), st) is st


def _const_delta(v):
    return jax.tree.map(lambda p: jnp.full(p.shape, v, p.dtype), PARAMS)


# ================================================= fifo == PR 4 fixed lag
def test_fifo_matches_fixed_lag_replay():
    """The generalized readiness machine in fifo mode IS the fixed-depth
    pipe: replaying the pushed deltas through an independent python FIFO
    (pop after exactly D rounds, constant ``staleness_decay ** D``
    discount, same ServerOptimizer) reproduces the params round for
    round."""
    from repro.core.aggregation import apply_server_opt, server_optimizer

    D = 2
    fed = _base(backend="scan_async", async_depth=D, staleness_decay=0.5,
                server_opt="momentum", server_momentum=0.5, epsilon=1e9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    ref_params, ref_opt = PARAMS, server_optimizer(fed).init(PARAMS)
    disc = engine.staleness_discount(fed)
    pipe = []
    for r in range(6):
        if len(pipe) == D:
            ref_params, ref_opt = apply_server_opt(fed, ref_params, ref_opt,
                                                   pipe.pop(0), scale=disc)
        state, _ = fn(state, DATA, PM, W, jax.random.PRNGKey(r), jnp.int32(r))
        occ = int(np.asarray(state.inflight["valid"]).sum())
        pipe.append(jax.tree.map(lambda b, occ=occ: b[occ - 1],
                                 state.inflight["delta"]))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(ref_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-7, err_msg=f"round {r}")


def test_ready_with_lag_equal_depth_matches_fifo():
    """ready(min_lag=D) pops on exactly the fifo schedule — bit-identical
    at decay 1 (no discount arithmetic), tight-tolerance at decay 0.5
    (traced decay**age vs the constant-folded discount)."""
    for decay, exact in ((1.0, True), (0.5, False)):
        fed_f = _base(backend="scan_async", async_depth=2,
                      staleness_decay=decay)
        fed_r = fed_f.replace(async_mode="ready", min_lag=2)
        (sf, tf) = _run(fed_f, "scan_async", rounds=5)
        (sr, tr) = _run(fed_r, "scan_async", rounds=5)
        np.testing.assert_array_equal(np.asarray(tf["gates"]),
                                      np.asarray(tr["gates"]))
        for a, b in zip(jax.tree.leaves(sf), jax.tree.leaves(sr)):
            if exact:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            else:
                np.testing.assert_allclose(np.asarray(a, np.float64),
                                           np.asarray(b, np.float64),
                                           atol=1e-6)


# ================================================= variable-lag readiness
def test_ready_applies_at_min_lag_not_depth():
    """min_lag=2 in a depth-4 buffer: the first delta lands at round 2 (age
    2), not round 4, and steady-state occupancy is min_lag, not D."""
    D, L = 4, 2
    fed = _base(backend="scan_async", async_depth=D, async_mode="ready",
                min_lag=L, staleness_decay=1.0, epsilon=1e9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    for r in range(L + 2):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(r),
                          jnp.int32(r))
        frozen = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(PARAMS)))
        assert frozen == (r < L), f"round {r}"
        assert float(stats["applied_valid"]) == (0.0 if r < L else 1.0)
        assert int(stats["staleness"]) == (0 if r < L else L)
        assert float(stats["inflight_occupancy"]) == min(r + 1, L)


def test_ready_multi_pop_applies_all_ready_slots():
    """A backlogged buffer (heterogeneous ages, all past min_lag) drains in
    ONE round, oldest first, each delta with its own measured-age
    discount — the FedBuff-style catch-up the fifo pipe cannot do."""
    D = 4
    fed = _base(backend="scan_async", async_depth=D, async_mode="ready",
                min_lag=1, staleness_decay=0.5, epsilon=1e9)
    state = engine.init_state(PARAMS, fed, C)
    inflight = {
        "delta": jax.tree.map(lambda *xs: jnp.stack(xs), _const_delta(1.0),
                              _const_delta(2.0), _const_delta(3.0),
                              _const_delta(4.0)),
        "valid": jnp.ones((D,), jnp.float32),
        "age": jnp.asarray([3, 2, 1, 0], jnp.int32),
    }
    fresh = _const_delta(0.0)
    p, _, nf, _, info = engine.async_apply(fed, PARAMS, state.opt_state,
                                           inflight, fresh)
    assert float(info["applied_valid"]) == 4.0
    assert int(info["applied_age"]) == 4          # the oldest popped slot
    # sgd server at lr 1: params moved by sum_i decay**age_i * delta_i with
    # ages incremented to (4, 3, 2, 1) at pop time
    expect = sum(0.5 ** a * v for a, v in zip((4, 3, 2, 1), (1, 2, 3, 4)))
    for pl, p0 in zip(jax.tree.leaves(p), jax.tree.leaves(PARAMS)):
        np.testing.assert_allclose(np.asarray(pl), np.asarray(p0) + expect,
                                   rtol=1e-6)
    # only the fresh push survives, at age 0
    np.testing.assert_array_equal(np.asarray(nf["valid"]), [1, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(nf["age"]), [0, 0, 0, 0])


def test_full_buffer_force_pops_oldest():
    """No ready slot but the buffer is full: the oldest is force-popped
    (FedBuff overflow) so the fresh delta always has a slot — nothing is
    silently dropped or overwritten."""
    D = 2
    fed = _base(backend="scan_async", async_depth=D, async_mode="ready",
                min_lag=2, staleness_decay=1.0, epsilon=1e9)
    state = engine.init_state(PARAMS, fed, C)
    # hand-built pathological state: full buffer, ages too young to be
    # ready even after this round's increment... except the forced slot 0
    inflight = {
        "delta": jax.tree.map(lambda *xs: jnp.stack(xs), _const_delta(1.0),
                              _const_delta(2.0)),
        "valid": jnp.ones((D,), jnp.float32),
        "age": jnp.asarray([0, 0], jnp.int32),
    }
    p, _, nf, _, info = engine.async_apply(fed, PARAMS, state.opt_state,
                                           inflight, _const_delta(4.0))
    assert float(info["applied_valid"]) == 1.0
    assert int(info["applied_age"]) == 1
    for pl, p0 in zip(jax.tree.leaves(p), jax.tree.leaves(PARAMS)):
        np.testing.assert_allclose(np.asarray(pl), np.asarray(p0) + 1.0,
                                   rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nf["valid"]), [1, 1])
    np.testing.assert_array_equal(np.asarray(nf["age"]), [1, 0])
    # ...and the survivor really is the old slot-1 delta
    for dl in jax.tree.leaves(jax.tree.map(lambda b: b[0], nf["delta"])):
        np.testing.assert_allclose(np.asarray(dl), 2.0)


def test_ready_drain_discounts_by_measured_age():
    """Drain under the variable-lag buffer scales each straggler by its
    CURRENT age, not the pipe depth."""
    D = 3
    fed = _base(backend="scan_async", async_depth=D, async_mode="ready",
                min_lag=2, staleness_decay=0.5, epsilon=1e9)
    state = engine.init_state(PARAMS, fed, C)
    state = state.replace(inflight={
        "delta": jax.tree.map(lambda *xs: jnp.stack(xs), _const_delta(1.0),
                              _const_delta(2.0), _const_delta(9.0)),
        "valid": jnp.asarray([1.0, 1.0, 0.0]),
        "age": jnp.asarray([1, 0, 0], jnp.int32),
    })
    out = engine.drain_inflight(fed, state)
    expect = 0.5 ** 1 * 1.0 + 0.5 ** 0 * 2.0      # invalid slot 2 ignored
    for pl, p0 in zip(jax.tree.leaves(out.params), jax.tree.leaves(PARAMS)):
        np.testing.assert_allclose(np.asarray(pl), np.asarray(p0) + expect,
                                   rtol=1e-6)
    assert float(jnp.sum(out.inflight["valid"])) == 0.0
    assert float(jnp.sum(out.inflight["age"])) == 0.0


# ================================================= adaptive drift discount
def test_adaptive_discount_cos_clamp_and_fallback():
    """The drift factor max(0, cos) against the last applied delta: 1 when
    no reference exists yet (zero sketch), ~1 for an aligned delta, exactly
    0 for an opposed one (the clamp — stale misaligned deltas are dropped,
    never applied negatively)."""
    fed = _base(backend="scan_async", async_depth=1, adaptive_staleness=True,
                staleness_decay=1.0, sketch_dim=128, epsilon=1e9)
    state = engine.init_state(PARAMS, fed, C)
    d = _const_delta(0.25)
    sk = engine.delta_sketch(d, engine.drift_sketch_key(fed), fed.sketch_dim)
    inflight = {"delta": jax.tree.map(lambda x: x[None], d),
                "valid": jnp.ones((1,), jnp.float32),
                "age": jnp.zeros((1,), jnp.int32)}
    zero_ref = jnp.zeros((fed.sketch_dim,), jnp.float32)
    fresh = _const_delta(0.0)

    for ref, factor in ((zero_ref, 1.0), (sk, 1.0), (-sk, 0.0)):
        p, _, _, last, info = engine.async_apply(
            fed, PARAMS, state.opt_state, inflight, fresh, last_delta=ref)
        assert float(info["applied_valid"]) == 1.0    # popped either way
        for pl, p0 in zip(jax.tree.leaves(p), jax.tree.leaves(PARAMS)):
            np.testing.assert_allclose(np.asarray(pl),
                                       np.asarray(p0) + factor * 0.25,
                                       atol=1e-6)
        if factor > 0:
            # the reference advances to the delta that landed
            np.testing.assert_allclose(np.asarray(last), np.asarray(sk),
                                       rtol=1e-5)
        else:
            # a clamped delta must NOT become the reference — otherwise an
            # oscillating stream (+d, -d, +d, ...) flips the reference
            # every pop and zeroes every later update
            np.testing.assert_array_equal(np.asarray(last), np.asarray(ref))


@pytest.mark.parametrize("server_opt", ["none", "momentum", "adam"])
def test_adaptive_oscillating_stream_keeps_moving(server_opt):
    """Alternating +d/-d pops: the opposed ones are clamped but the
    aligned ones keep landing — the drift reference never latches onto a
    direction that was dropped, so training cannot silently freeze. A
    clamped pop is dropped OPTIMIZER INCLUDED: under momentum/adam it
    must not decay moments or tick adam's t (which would move params
    along the stale residual on a round that claims to drop the delta)."""
    fed = _base(backend="scan_async", async_depth=1, adaptive_staleness=True,
                staleness_decay=1.0, sketch_dim=128, epsilon=1e9,
                server_opt=server_opt)
    state = engine.init_state(PARAMS, fed, C)
    d = _const_delta(0.25)
    neg = jax.tree.map(lambda x: -x, d)
    params, opt, last = PARAMS, state.opt_state, state.last_delta
    moved = []
    for delta in (d, neg, d, neg, d):
        inflight = {"delta": jax.tree.map(lambda x: x[None], delta),
                    "valid": jnp.ones((1,), jnp.float32),
                    "age": jnp.zeros((1,), jnp.int32)}
        new_params, new_opt, _, last, _ = engine.async_apply(
            fed, params, opt, inflight, _const_delta(0.0), last_delta=last)
        stepped = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(new_params),
                            jax.tree.leaves(params)))
        if not stepped:
            # a dropped pop leaves the optimizer moments untouched too
            for a, b in zip(jax.tree.leaves(new_opt), jax.tree.leaves(opt)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        moved.append(stepped)
        params, opt = new_params, new_opt
    # first +d lands (no reference yet); every -d is clamped; every later
    # +d still lands because the reference stayed on the landed direction
    assert moved == [True, False, True, False, True]


def test_drift_sketch_deterministic_and_linear():
    """The drift projection is ONE fixed key per run (fold_in_name/crc32 —
    process-deterministic), shared by every sketch the cosine ever
    compares; CountSketch linearity makes cos(sketch(d), sketch(-d))
    exactly -1, which the factor clamps to 0."""
    fed = _base(backend="scan_async", async_depth=1, adaptive_staleness=True)
    np.testing.assert_array_equal(np.asarray(engine.drift_sketch_key(fed)),
                                  np.asarray(engine.drift_sketch_key(fed)))
    d = _const_delta(0.3)
    s1 = engine.delta_sketch(d, engine.drift_sketch_key(fed), 64)
    s2 = engine.delta_sketch(d, engine.drift_sketch_key(fed), 64)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    neg = engine.delta_sketch(jax.tree.map(lambda x: -x, d),
                              engine.drift_sketch_key(fed), 64)
    np.testing.assert_allclose(np.asarray(neg), -np.asarray(s1), rtol=1e-6)
    assert float(engine.drift_factor(s1, s1)) == pytest.approx(1.0)
    assert float(engine.drift_factor(s1, neg)) == 0.0
    # a fresh run (different seed) projects differently
    other = engine.drift_sketch_key(fed.replace(seed=123))
    assert not np.array_equal(np.asarray(engine.drift_sketch_key(fed)),
                              np.asarray(other))


def test_adaptive_fifo_runs_and_checkpoints(tmp_path):
    """adaptive_staleness composes with the fifo pipe: the run advances,
    the last_delta sketch leaf is populated after the first apply, and the
    full state (sketch included) round-trips bit-identically."""
    fed = _base(backend="scan_async", async_depth=2, staleness_decay=0.9,
                adaptive_staleness=True, sketch_dim=64, epsilon=1e9)
    state, _ = _run(fed, "scan_async", r=0, rounds=4)
    assert float(jnp.sum(jnp.abs(state.last_delta))) > 0.0
    path = str(tmp_path / "adaptive.msgpack")
    save_federation_state(path, state, jax.random.PRNGKey(7), 4)
    got, _, step = load_federation_state(
        path, engine.init_state(PARAMS, fed, C))
    assert step == 4
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ================================================= masks under staggering
def test_depth0_parity_under_participation_and_stragglers():
    """Partial participation + straggler cadence: the depth-0 async round
    still reproduces the synchronous round bitwise, seed by seed."""
    fed = _base(epsilon=1e9, participation=0.6, straggler_period=3,
                max_cohort=5)
    for seed in range(3):
        (ss, ts) = _run(fed, "vmap_spatial", r=seed, seed=seed)
        (sa, ta) = _run(fed, "scan_async", r=seed, seed=seed)
        np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                      np.asarray(ta["gates"]))
        for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sa)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staggered_cohorts_respect_masks_and_backlog():
    """With a live pipeline (D=2), gates stay truthful: binary, priority
    honoured under participation sampling, cohort budget enforced, and the
    backlog ledger advances exactly as the gates dictate."""
    fed = _base(backend="scan_async", async_depth=2, epsilon=1e9,
                participation=0.6, straggler_period=3, max_cohort=4)
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state = engine.init_state(PARAMS, fed, C)
    pm = np.asarray(PM).astype(bool)
    for r in range(5):
        prev_backlog = np.asarray(state.backlog)
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(r),
                          jnp.int32(r))
        gates = np.asarray(stats["gates"])
        assert set(np.unique(gates)) <= {0.0, 1.0}
        assert gates.sum() <= fed.max_cohort
        assert gates[pm].sum() >= 1.0            # priority never starves out
        bl = np.asarray(state.backlog)
        assert np.all(bl[gates > 0] == 0)        # aggregated clients reset
        assert np.all(bl >= 0) and np.all(bl <= prev_backlog + 1)


# ================================================= checkpoint / resume
def test_async_checkpoint_resume_mid_flight(tmp_path):
    """Interrupt an async run with cohorts still in flight; the resumed run
    must be bit-identical to the uninterrupted one — in-flight deltas,
    their validity mask, params, moments, PRNG stream, stats."""
    path = str(tmp_path / "async.msgpack")
    fed = FedConfig(num_clients=C, num_priority=3, rounds=8, local_epochs=2,
                    epsilon=0.3, lr=0.1, warmup_frac=0.0, batch_size=32,
                    align_stat="loss", server_opt="yogi", server_lr=0.3,
                    max_cohort=5, backend="scan_async", async_depth=2,
                    staleness_decay=0.9)
    full = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=4)

    half = run_federation(LOSS, PARAMS, fed.replace(rounds=5), FEDN,
                          eval_every=4)
    # the interrupted state really is mid-flight: both slots occupied
    assert float(jnp.sum(half.state.inflight["valid"])) == 2.0
    save_federation_state(path, half.state, half.rng, 5)
    like = engine.init_state(PARAMS, fed, C)
    state, rng, step = load_federation_state(path, like)
    assert step == 5
    # the in-flight cohort buffer survived the round-trip bit-identically
    for a, b in zip(jax.tree.leaves(half.state.inflight),
                    jax.tree.leaves(state.inflight)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=4,
                             state=state, rng=rng, start_round=step)
    for a, b in zip(jax.tree.leaves(full.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.global_loss[5:]),
                                  np.asarray(resumed.global_loss))


def test_ready_checkpoint_resume_heterogeneous_ages(tmp_path):
    """Mid-flight resume of a VARIABLE-lag adaptive pipeline: the
    interrupted buffer holds slots of different ages (and a live drift
    sketch), and the resumed run is bit-identical to the uninterrupted
    one."""
    path = str(tmp_path / "ready.msgpack")
    fed = FedConfig(num_clients=C, num_priority=3, rounds=8, local_epochs=2,
                    epsilon=0.3, lr=0.1, warmup_frac=0.0, batch_size=32,
                    align_stat="loss", server_opt="adam", server_lr=0.3,
                    max_cohort=5, backend="scan_async", async_depth=4,
                    async_mode="ready", min_lag=2, staleness_decay=0.9,
                    adaptive_staleness=True, sketch_dim=64)
    full = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=4)

    half = run_federation(LOSS, PARAMS, fed.replace(rounds=5), FEDN,
                          eval_every=4)
    # the interrupted buffer really is heterogeneous: two slots in flight
    # at DIFFERENT ages (steady-state occupancy is min_lag, not depth)
    np.testing.assert_array_equal(np.asarray(half.state.inflight["valid"]),
                                  [1, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(half.state.inflight["age"]),
                                  [1, 0, 0, 0])
    assert float(jnp.sum(jnp.abs(half.state.last_delta))) > 0.0
    save_federation_state(path, half.state, half.rng, 5)
    state, rng, step = load_federation_state(
        path, engine.init_state(PARAMS, fed, C))
    for a, b in zip(jax.tree.leaves(half.state), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=4,
                             state=state, rng=rng, start_round=step)
    for a, b in zip(jax.tree.leaves(full.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_after_drain_does_not_reapply(tmp_path):
    """The drain/checkpoint double-apply hazard (PR 5 bugfix): with
    ``drain_inflight=True`` and a checkpoint path, the final checkpoint
    must hold the DRAINED state — resuming it and draining again must be a
    no-op, not a second application of the same cohort deltas."""
    path = str(tmp_path / "drained.msgpack")
    fed = _base(backend="scan_async", async_depth=2, staleness_decay=0.9,
                rounds=4, epsilon=1e9)
    hist = run_federation(LOSS, PARAMS, fed, FEDN, eval_every=2,
                          checkpoint_path=path, drain_inflight=True)
    state, rng, step = load_federation_state(
        path, engine.init_state(PARAMS, fed, C))
    assert step == fed.rounds
    # pre-fix this holds the un-drained buffer (occupancy 2): resuming and
    # draining would re-apply both in-flight deltas
    assert float(jnp.sum(state.inflight["valid"])) == 0.0
    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=2,
                             state=state, rng=rng, start_round=step,
                             drain_inflight=True)
    for a, b in zip(jax.tree.leaves(hist.state.params),
                    jax.tree.leaves(resumed.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_layout_mismatch_raises_helpfully(tmp_path):
    """Restoring an async checkpoint with the wrong async_depth (different
    in-flight layout) fails with an actionable error, not a bare assert."""
    path = str(tmp_path / "st.msgpack")
    fed = _base(backend="scan_async", async_depth=2)
    st = engine.init_state(PARAMS, fed, C)
    save_federation_state(path, st, jax.random.PRNGKey(0), 3)
    with pytest.raises(ValueError, match="async_depth"):
        load_federation_state(
            path, engine.init_state(PARAMS, _base(backend="scan_async",
                                                  async_depth=3), C))
    with pytest.raises(ValueError, match="async_depth"):
        load_federation_state(path, engine.init_state(PARAMS, _base(), C))
    # an adaptive resume of a non-adaptive checkpoint (missing last_delta
    # sketch leaf) is a leaf-count mismatch, named as such
    with pytest.raises(ValueError, match="adaptive_staleness"):
        load_federation_state(
            path, engine.init_state(PARAMS, fed.replace(
                adaptive_staleness=True), C))


def test_resume_with_wrong_async_mode_raises(tmp_path):
    """async_mode/min_lag change NO leaf shape, so shape validation can't
    catch a fifo resume of a ready-mode buffer — the checkpoint carries
    the writer's buffer-policy fingerprint and the loader (given the
    resume config) refuses a mismatch instead of silently popping the
    restored slot ages on the wrong schedule."""
    path = str(tmp_path / "policy.msgpack")
    fed_w = _base(backend="scan_async", async_depth=2, async_mode="ready",
                  min_lag=1)
    st = engine.init_state(PARAMS, fed_w, C)
    save_federation_state(path, st, jax.random.PRNGKey(0), 3, fed=fed_w)
    like = engine.init_state(PARAMS, fed_w, C)
    # matching config: fine, fingerprint round-trips
    _, _, step = load_federation_state(path, like, fed=fed_w)
    assert step == 3
    for bad in (fed_w.replace(async_mode="fifo"),
                fed_w.replace(min_lag=2)):
        with pytest.raises(ValueError, match="async"):
            load_federation_state(path, like, fed=bad)
    # legacy behaviour: no fed passed -> shapes-only validation, accepted
    load_federation_state(path, like)
    # checkpoints written WITHOUT a fingerprint (fed=None writer) stay
    # loadable under any policy — there is nothing to validate against
    save_federation_state(path, st, jax.random.PRNGKey(0), 3)
    load_federation_state(path, like, fed=fed_w.replace(async_mode="fifo"))


# ================================================= sharded pod rounds
def test_sharded_async_rounds_pipeline():
    """Both pod modes run the same staleness state machine: params frozen
    while the pipe warms up, moving once the first cohort lands, and the
    depth-0 spatial round stays bit-identical to the sync spatial round."""
    from repro.configs import get_smoke
    from repro.fl import sharded
    from repro.models import get_model
    from tests.test_sharded import _batch

    cfg = get_smoke("qwen1_5_0_5b").replace(remat=False)
    model = get_model(cfg)
    batch = _batch()
    p0 = model.init(jax.random.PRNGKey(0))
    sync_fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05)
    async_fed = sync_fed.replace(async_depth=1, staleness_decay=1.0,
                                 backend="scan_async")

    s_sync, _ = jax.jit(sharded.make_spatial_round(model, sync_fed, 4))(
        engine.init_state(p0, sync_fed, 4), batch)

    for mk in (sharded.make_spatial_round, sharded.make_temporal_round):
        step = jax.jit(mk(model, async_fed, 4))
        st = engine.init_state(p0, async_fed, 4)
        st, t0 = step(st, batch, 0)
        for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(p0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(t0["applied_valid"]) == 0.0
        st, t1 = step(st, batch, 1)
        assert float(t1["applied_valid"]) == 1.0
        changed = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(p0)))
        assert changed
        if mk is sharded.make_spatial_round:
            # round 0 buffered exactly the sync round's delta (decay 1, so
            # round 1 applied it unscaled): params == one sync round
            for a, b in zip(jax.tree.leaves(st.params),
                            jax.tree.leaves(s_sync.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)


def _pod_batch(n=16):
    """Tiny pod-round batch over the synth federation (logreg model — pod
    rounds only need model.loss_fn, so the full smoke LM is unnecessary
    for a strategies x modes sweep)."""
    return {
        "clients": {"x": DATA["x"][:, :n], "y": DATA["y"][:, :n]},
        "server": {"x": DATA["x"][0, :n], "y": DATA["y"][0, :n]},
        "priority_mask": PM,
        "weights": W,
    }


class _TinyPodModel:
    init = staticmethod(INIT)
    loss_fn = staticmethod(LOSS)


@pytest.mark.parametrize("selection", STRATEGIES)
def test_pod_modes_fifo_and_depth0_parity(selection):
    """Re-pin across EVERY strategy x both pod modes: the depth-0 async
    config is bit-identical to the synchronous pod round, and the fifo
    depth-1 pipe buffers round 0 (params frozen, staleness stat masked)
    then lands the identical delta at round 1."""
    from repro.fl import sharded

    base = FedConfig(num_clients=C, num_priority=3, local_epochs=1,
                     epsilon=1e9, lr=0.1, warmup_frac=0.0, topk=2,
                     welfare_floor=0.05, selection=selection,
                     grad_sim_sketch=True, sketch_dim=64)
    batch = _pod_batch()
    for mk in (sharded.make_spatial_round, sharded.make_temporal_round):
        s0 = engine.init_state(PARAMS, base, C)
        s_sync, t_sync = jax.jit(mk(_TinyPodModel, base, C))(s0, batch, 0)

        fed0 = base.replace(backend="scan_async", async_depth=0)
        s_a, t_a = jax.jit(mk(_TinyPodModel, fed0, C))(
            engine.init_state(PARAMS, fed0, C), batch, 0)
        np.testing.assert_array_equal(np.asarray(t_sync["gates"]),
                                      np.asarray(t_a["gates"]))
        assert "staleness" not in t_a          # sync stats structure
        for a, b in zip(jax.tree.leaves(s_sync), jax.tree.leaves(s_a)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        fed1 = base.replace(backend="scan_async", async_depth=1,
                            staleness_decay=1.0)
        step1 = jax.jit(mk(_TinyPodModel, fed1, C))
        st = engine.init_state(PARAMS, fed1, C)
        st, t0 = step1(st, batch, 0)
        assert float(t0["applied_valid"]) == 0.0
        assert int(t0["staleness"]) == 0       # nothing landed: masked
        for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(PARAMS)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        st, t1 = step1(st, batch, 1)
        assert float(t1["applied_valid"]) == 1.0
        assert int(t1["staleness"]) == 1       # the measured slot age
        # decay 1, deterministic local steps: the buffered round-0 delta
        # lands unscaled — params equal one synchronous round
        for a, b in zip(jax.tree.leaves(st.params),
                        jax.tree.leaves(s_sync.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_federation_state_specs_cover_inflight():
    """The pjit lowering seam: spec tree structure matches the async state
    structure, and every delta slot inherits its param's layout behind the
    leading ring-buffer axis."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.sharding.specs import auto_param_specs, federation_state_specs

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    pspecs = auto_param_specs(jax.eval_shape(lambda: params), mesh)
    fed = FedConfig(server_opt="yogi", async_depth=2, backend="scan_async")
    shapes = jax.eval_shape(lambda: engine.init_state(params, fed, C))
    specs = federation_state_specs(fed, pspecs)
    is_p = lambda x: isinstance(x, P)
    assert (jax.tree.structure(shapes)
            == jax.tree.structure(specs, is_leaf=is_p))
    for psp, dsp in zip(jax.tree.leaves(pspecs, is_leaf=is_p),
                        jax.tree.leaves(specs.inflight["delta"],
                                        is_leaf=is_p)):
        assert tuple(dsp) == (None,) + tuple(psp)
    # the per-slot age vector replicates like the validity mask
    assert tuple(specs.inflight["age"]) == ()
    assert specs.last_delta == ()               # not adaptive: no sketch
    # adaptive runs add the replicated drift-reference sketch spec
    fed_a = fed.replace(adaptive_staleness=True)
    shapes_a = jax.eval_shape(lambda: engine.init_state(params, fed_a, C))
    specs_a = federation_state_specs(fed_a, pspecs)
    assert (jax.tree.structure(shapes_a)
            == jax.tree.structure(specs_a, is_leaf=is_p))
    assert tuple(specs_a.last_delta) == ()
    # sync configs keep the old layout
    assert federation_state_specs(FedConfig(), pspecs).inflight == ()
    assert federation_state_specs(FedConfig(), pspecs).last_delta == ()
