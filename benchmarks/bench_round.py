"""Round-pipeline benchmark: dense train-everyone vs gate-before-train
cohort execution (``FedConfig.max_cohort``).

Times full engine rounds at C=64 clients on a small MLP across inclusion
rates, reporting rounds/sec and the wasted-local-epoch fraction (clients
that paid E local epochs but were dropped at aggregation). Every timing
pair is also a correctness pair: the cohort round must reproduce the dense
round exactly before its timing row is emitted.

    PYTHONPATH=src python benchmarks/bench_round.py [--full] [--out PATH]

emits ``BENCH_round.json``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import init_mlp2, make_loss_fn, mlp2_apply

CLIENTS = 64
N_PRIORITY = 2


def _time_round(fn, params, data, pm, w, iters):
    key = jax.random.PRNGKey(0)
    out = fn(params, data, pm, w, key, jnp.int32(1))
    jax.block_until_ready(out)                       # compile + warm-up
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(params, data, pm, w, key, jnp.int32(1))
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def run(fast=True):
    samples = 64 if fast else 256
    iters = 3 if fast else 8
    fedn = make_synth_federation(seed=0, n_priority=N_PRIORITY,
                                 n_nonpriority=CLIENTS - N_PRIORITY,
                                 samples_per_client=samples)
    data = {"x": jnp.asarray(fedn.x), "y": jnp.asarray(fedn.y)}
    pm = jnp.asarray(fedn.priority_mask)
    w = jnp.asarray(fedn.weights)
    init_fn = lambda key: init_mlp2(key, in_dim=60, hidden=256, num_classes=10)
    loss_fn = make_loss_fn(mlp2_apply)
    params = init_fn(jax.random.PRNGKey(42))

    rows = []
    for rate in (0.25, 0.5, 1.0):
        k = round(CLIENTS * rate)
        # topk_align with a huge eps band pins inclusion to exactly k
        # (priority + the k - P best-matched non-priority clients)
        base = FedConfig(num_clients=CLIENTS, num_priority=N_PRIORITY,
                         rounds=100, local_epochs=5, epsilon=1e9,
                         warmup_frac=0.0, align_stat="loss",
                         selection="topk_align", topk=k - N_PRIORITY,
                         batch_size=32, seed=0)
        dense_fn = jax.jit(engine.make_round_fn(loss_fn, base))
        cohort_fn = jax.jit(engine.make_round_fn(loss_fn,
                                                 base.replace(max_cohort=k)))
        sec_d, (pd, sd) = _time_round(dense_fn, params, data, pm, w, iters)
        sec_c, (pc, sc) = _time_round(cohort_fn, params, data, pm, w, iters)

        # correctness before timing is reported: identical gates + params
        np.testing.assert_array_equal(np.asarray(sd["gates"]),
                                      np.asarray(sc["gates"]))
        for a, b in zip(jax.tree.leaves(pd), jax.tree.leaves(pc)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)

        included = float(np.asarray(sd["gates"]).sum())
        for path, sec, trained in (("dense", sec_d, CLIENTS),
                                   ("cohort", sec_c, k)):
            rows.append({
                "path": path,
                "clients": CLIENTS,
                "max_cohort": 0 if path == "dense" else k,
                "target_inclusion_rate": rate,
                "measured_inclusion_rate": round(included / CLIENTS, 4),
                "clients_trained": trained,
                "wasted_local_epoch_frac": round((trained - included)
                                                 / trained, 4),
                "sec_per_round": round(sec, 5),
                "rounds_per_sec": round(1.0 / sec, 2),
                "speedup_vs_dense": round(sec_d / sec, 2),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_round.json")
    args = ap.parse_args()
    rows = run(fast=not args.full)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    for r in rows:
        print(r)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
