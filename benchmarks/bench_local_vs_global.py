"""Paper Figure 3 (App C.1): FedALIGN global model vs locally-trained models
when clients have only 50 samples — the incentive argument for non-priority
participation."""
from __future__ import annotations

import jax

from repro.configs.base import FedConfig
from repro.data.shards import make_benchmark_federation
from repro.fl.simulator import run_federation, run_local_baseline
from repro.models.small import SMALL_MODELS, make_loss_fn


def run(fast=True, datasets=("fmnist",), seeds=(0,)):
    rows = []
    rounds = 20 if fast else 150
    for ds in datasets:
        model_name = {"fmnist": "logreg", "emnist": "mlp2", "cifar": "cnn"}[ds]
        init_fn, apply_fn = SMALL_MODELS[model_name]
        loss_fn = make_loss_fn(apply_fn)
        fedn = make_benchmark_federation(ds, seed=0, n_priority=2,
                                         samples_per_client=50)
        fed = FedConfig(num_clients=fedn.x.shape[0], num_priority=2,
                        rounds=rounds, local_epochs=5, epsilon=0.2, lr=0.1,
                        warmup_frac=0.1, batch_size=16)
        hist = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                              fedn, eval_every=5)
        # locally trained models at a few non-priority clients
        local_accs = run_local_baseline(loss_fn, init_fn, fed, fedn,
                                        client_ids=[5, 20, 40])
        rows.append({
            "dataset": ds,
            "fedalign_acc": round(hist.summary()["final_acc"], 4),
            "local_accs": {k: round(v, 4) for k, v in local_accs.items()},
            "fedalign_beats_local": hist.summary()["final_acc"]
                                    > max(local_accs.values()),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
