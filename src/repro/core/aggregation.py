"""FedALIGN renormalized gated aggregation (paper eq. (15)):

    w <- sum_k p_k I_k w_k / sum_k p_k I_k

over client-stacked parameter pytrees. The default ``fused`` path flattens
the WHOLE pytree into one [C, M_total] buffer and invokes the ``fedagg``
kernel (Pallas on TPU, its jnp lowering on CPU) ONCE per round instead of
once per leaf — one kernel launch, one contraction, and under pjit with the
client axis sharded over (pod, data) exactly one all-reduce: FedALIGN's
entire server-side communication. Accumulation is f32 regardless of leaf
dtype, so fused and per-leaf outputs agree to the cast.

This module also owns three registries:

- the **ServerOptimizer registry**: the fused aggregated delta is a
  pseudo-gradient, and ``aggregate_updates`` applies the configured
  server-side update rule (FedOpt, Reddi et al., arXiv:2003.00295) to it —
  ``sgd`` (FedAvg), ``momentum`` (FedAvgM), ``adam`` (FedAdam), ``yogi``
  (FedYogi) — reusing the update rules from ``optim/optimizers.py``.
  Optimizer moments live in ``fl.engine.FederationState.opt_state`` and
  thread through the round scan.
- the **Aggregator registry** (``FedConfig.aggregator``): how the gated
  client deltas are REDUCED before the server step. ``mean`` is the paper
  rule above; ``trimmed_mean`` / ``median`` are the coordinate-wise
  Byzantine-robust order statistics (Yin et al., arXiv:1803.01498),
  ``dp`` is DP-FedAvg clip+noise (McMahan et al., arXiv:1710.06963), and
  ``cosine_filter`` zeroes the gates of delta-sketch outliers before the
  plain mean. A registered aggregator is a PREPARE function producing
  gate/weight rewrites and in-kernel operands — the reduction itself stays
  one fused fedagg kernel launch per round for every variant.
- the **WireCodec registry** (``FedConfig.wire_codec``): lossy uplink
  compression of the fused [C, M_total] buffer — ``int8`` rows with
  per-client scales, ``topk`` sparsification, ``sketch`` CountSketch
  rows — decoded INSIDE the same fedagg launch (dequantize-in-register /
  sparse-scatter-accumulate / hash-gather per VMEM tile, never a
  materialized dense decode buffer), with per-client error-feedback
  accumulators (``FederationState.ef_accum``) re-injecting the
  compression residual next round so convergence doesn't stall.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import register_validator
from repro.kernels import ops as kops
from repro.optim import optimizers as _opt
from repro.utils import Registry, fold_in_name


def check_client_weights(weights, *, where="client weights"):
    """Validate CONCRETE client weights at the aggregation boundary.

    A negative p_k silently sign-flips that client's contribution (the
    renormalized mean subtracts it); a NaN/inf poisons the whole aggregate.
    Neither is ever a legitimate data fraction, so both fail loudly here.
    Traced values (inside jit) pass through unchecked — jitted callers
    validate at their host-side entry points (fl/simulator, launch/train)
    where the weights are still concrete.
    """
    if isinstance(weights, jax.core.Tracer):
        return weights
    import numpy as np
    w = np.asarray(weights)
    if not np.all(np.isfinite(w)):
        bad = np.flatnonzero(~np.isfinite(w))
        raise ValueError(
            f"{where} must be finite: clients {bad.tolist()} are NaN/inf. "
            "Check the shard spec / data-fraction computation that produced "
            "them — a NaN weight poisons every aggregated parameter.")
    if np.any(w < 0):
        bad = np.flatnonzero(w < 0)
        raise ValueError(
            f"{where} must be non-negative: clients {bad.tolist()} have "
            f"negative weight (min {w.min()}). A negative data fraction "
            "sign-flips that client's update in the renormalized mean; fix "
            "the shard spec instead of aggregating with it.")
    return weights


def flatten_stacked(client_params, dtype=jnp.float32):
    """Client-stacked pytree ([C, ...] leaves) -> one [C, M_total] buffer."""
    leaves = jax.tree.leaves(client_params)
    C = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.reshape(C, -1).astype(dtype) for leaf in leaves], axis=1)


def aggregate_clients(client_params, weights, gates, *, use_pallas=False,
                      fused=True, interpret=False, aggregator="mean",
                      fed=None, key=None, wire_codec="identity",
                      ef_accum=None):
    """client_params: pytree with leading client axis C on every leaf.

    fused=True (default): one fedagg call on the [C, M_total] flattening;
    fused=False: one fedagg call per leaf (the pre-fusion path, kept as the
    parity reference and for incremental/per-leaf sharded layouts).

    ``aggregator`` names a registered Aggregator (mean | trimmed_mean |
    median | dp | cosine_filter). Non-mean aggregators read their knobs off
    ``fed`` and interpret the client rows as DELTAS (clip norms, outlier
    cosines); ``dp`` additionally needs a PRNG ``key`` for its per-round
    noise draw. Whatever the variant, the reduction stays one fedagg call
    (fused) or one per leaf — the robust work happens inside the kernel,
    plus an O(C * sketch_dim) gate pre-pass for cosine_filter.

    ``wire_codec`` names a registered WireCodec compressing the fused
    buffer's uplink (identity | int8 | topk | sketch); non-identity codecs
    require ``fused=True`` and ``fed=``. With ``ef_accum`` (a pytree of
    f32 per-client error-feedback rows, params-shaped leaves with the same
    leading client axis as ``client_params``) the accumulator is added to
    the rows BEFORE encoding and the call returns ``(aggregate,
    new_ef_accum)`` where ``new_ef_accum`` carries the per-row compression
    residual x - decode(encode(x)) for every transmitting (gate > 0,
    finite-residual) row and the previous accumulator for the rest —
    EF-style memory, so compression bias is re-injected next round instead
    of lost. The identity codec ignores both knobs and keeps the exact
    legacy trace."""
    check_client_weights(weights)
    leaves, treedef = jax.tree.flatten(client_params)
    if not leaves:
        return client_params
    C = leaves[0].shape[0]
    # which rows TRANSMITTED this round — captured before any server-side
    # gate rewrite (cosine_filter): a filtered-out client still encoded and
    # sent its delta, so its EF accumulator must still advance
    tx_gates = gates

    name = resolve_aggregator(aggregator)
    if name != "mean":
        if fed is None:
            raise ValueError(
                f"aggregator={name!r} reads its knobs (trim_frac/dp_clip/"
                "dp_noise/outlier_cos/sketch_dim) off a FedConfig: pass fed=")
        weights, gates, kernel_kw, noise = get_aggregator(name)(
            fed, client_params, weights, gates, key)
    else:
        kernel_kw, noise = {}, None

    codec_name = resolve_wire_codec(wire_codec)
    if codec_name != "identity":
        if fed is None:
            raise ValueError(
                f"wire_codec={codec_name!r} reads its rate knobs "
                "(codec_topk_frac/codec_sketch_dim) off a FedConfig: "
                "pass fed=")
        if not fused:
            raise ValueError(
                f"wire_codec={codec_name!r} compresses the fused "
                "[C, M_total] buffer; call with fused=True")
        return _aggregate_coded(
            codec_name, leaves, treedef, client_params, weights, gates,
            tx_gates, kernel_kw, noise, fed=fed, use_pallas=use_pallas,
            interpret=interpret, ef_accum=ef_accum)
    if ef_accum is not None:
        raise ValueError(
            "ef_accum (error-feedback rows) only makes sense with a "
            "non-identity wire_codec: the identity wire is lossless, its "
            "residual is exactly zero")

    if not fused:
        # per-leaf path: the dp noise vector is ONE [M_total] draw sliced at
        # each leaf's offset, so per-leaf == fused bit-for-bit per coordinate
        sizes = [leaf.size // C for leaf in leaves]
        offs, off = [], 0
        for size in sizes:
            offs.append(off)
            off += size
        agg_leaves = []
        for leaf, size, off in zip(leaves, sizes, offs):
            kw = dict(kernel_kw)
            if noise is not None:
                kw["noise"] = noise[off:off + size]
            out = kops.fedagg(leaf.reshape(C, -1), weights, gates,
                              use_pallas=use_pallas, interpret=interpret, **kw)
            agg_leaves.append(out.reshape(leaf.shape[1:]))
        return jax.tree.unflatten(treedef, agg_leaves)

    # keep a uniform leaf dtype on the wire (bf16 deltas stay bf16 in the
    # [C, M_total] buffer and its collective); mixed-dtype trees go f32.
    # fedagg accumulates in f32 either way, so fused == per-leaf numerics.
    dtypes = {leaf.dtype for leaf in leaves}
    buf_dtype = dtypes.pop() if len(dtypes) == 1 else jnp.float32
    sizes = [leaf.size // C for leaf in leaves]
    buf = flatten_stacked(client_params, dtype=buf_dtype)
    out = kops.fedagg(buf, weights, gates, use_pallas=use_pallas,
                      interpret=interpret, noise=noise, **kernel_kw)
    agg_leaves, off = [], 0
    for leaf, size in zip(leaves, sizes):
        agg_leaves.append(
            out[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, agg_leaves)


def _aggregate_coded(codec_name, leaves, treedef, client_params, weights,
                     gates, tx_gates, kernel_kw, noise, *, fed, use_pallas,
                     interpret, ef_accum):
    """The compressed-uplink fused path: encode the f32 [C, M_total] buffer
    (error-feedback rows folded in first), decode-and-reduce inside the one
    fedagg kernel launch, and advance the EF accumulator.

    The dense decode is materialized ONLY for the EF residual (it is the
    definition of the residual); the kernel itself consumes the encoded
    operands and decodes per [C, block_m] tile in VMEM."""
    C = leaves[0].shape[0]
    sizes = [leaf.size // C for leaf in leaves]
    codec = get_wire_codec(codec_name)
    buf = flatten_stacked(client_params, dtype=jnp.float32)
    if ef_accum is not None:
        buf = buf + flatten_stacked(ef_accum, dtype=jnp.float32)
    M = buf.shape[1]
    updates, codec_kw = codec.encode(fed, buf)
    out = kops.fedagg(updates, weights, gates, use_pallas=use_pallas,
                      interpret=interpret, noise=noise, **codec_kw,
                      **kernel_kw)
    agg_leaves, off = [], 0
    for leaf, size in zip(leaves, sizes):
        agg_leaves.append(
            out[off:off + size].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += size
    agg = jax.tree.unflatten(treedef, agg_leaves)
    if ef_accum is None:
        return agg
    resid = buf - codec.decode(fed, updates, codec_kw, M)
    # rows advance only when they transmitted (gate > 0 BEFORE server-side
    # rewrites) AND the residual is finite — a corrupted (NaN) delta must
    # not poison the accumulator for every later round
    ok = (tx_gates > 0) & jnp.all(jnp.isfinite(resid), axis=1)
    ef_leaves, ef_treedef = jax.tree.flatten(ef_accum)
    new_ef, off = [], 0
    for old, size in zip(ef_leaves, sizes):
        r = resid[:, off:off + size].reshape(old.shape)
        okb = ok.reshape((C,) + (1,) * (old.ndim - 1))
        new_ef.append(jnp.where(okb, r, old.astype(jnp.float32)))
        off += size
    return agg, jax.tree.unflatten(ef_treedef, new_ef)


# ================================================================ aggregators
AGGREGATORS = Registry("aggregator", aliases={None: "mean", "none": "mean"})


def register_aggregator(name: str, *, needs_key=False, in_kernel=True):
    """Register a client-delta Aggregator under ``name``.

    The registered callable is a PREPARE step
    ``prepare(fed, client_deltas, weights, gates, key)
        -> (weights, gates, kernel_kw, noise)``
    run once per round before the fused fedagg call: it may rewrite the
    weight/gate vectors (cosine_filter), attach extra in-kernel operands
    (dp's per-client clip scales), and return a [M_total] noise vector that
    the fused/per-leaf dispatcher slices per leaf. ``kernel_kw`` is passed
    straight to ``kernels.ops.fedagg`` — the reduction itself runs inside
    the kernel (``in_kernel`` aggregators add zero extra HBM passes over
    the [C, M_total] buffer). ``needs_key=True`` marks stochastic
    aggregators: the round loop derives a per-round key
    (``aggregator_key``) only for those, so deterministic traces are
    untouched."""
    return AGGREGATORS.register(name, agg_name=name, needs_key=needs_key,
                                in_kernel=in_kernel)


def resolve_aggregator(name) -> str:
    """Canonical registry name ('none' / None is the plain gated mean)."""
    return AGGREGATORS.resolve(name)


def get_aggregator(name: str) -> Callable:
    return AGGREGATORS.lookup(name)


def aggregator_key(fed, round_idx):
    """Per-round PRNG key for stochastic aggregators (dp's noise draw).

    Derived from ``fed.seed`` via ``fold_in_name`` (crc32 — deterministic
    across processes) + the round index, and computed IDENTICALLY by the
    engine round and both sharded pod rounds, so every backend draws the
    same noise and stays bit-comparable."""
    base = fold_in_name(jax.random.PRNGKey(fed.seed), "aggregator_noise")
    return jax.random.fold_in(base, round_idx)


def inclusion_mass(fed, weights, gates):
    """The configured aggregator's denominator mass for a round — the
    aggregate can be nonzero iff this is > 0 (the zero-inclusion
    ServerOptimizer skip keys off it). mean/dp/cosine_filter renormalize
    by sum p_k I_k; trimmed_mean/median are unweighted order statistics
    over the included clients, so their mass is the included COUNT (a
    zero-weight included client still moves the median)."""
    name = resolve_aggregator(getattr(fed, "aggregator", "mean"))
    if name in ("trimmed_mean", "median"):
        return jnp.sum((gates > 0).astype(jnp.float32))
    return jnp.sum(weights.astype(jnp.float32) * gates.astype(jnp.float32))


@register_validator("aggregator")
def check_aggregator_config(fed):
    """Validate the aggregator knobs whose bad values would corrupt the
    aggregate silently (like check_async_config for the async knobs).
    Registered as the ``validate_config`` "aggregator" hook; direct calls
    are deprecated — call ``repro.configs.base.validate_config(fed)``."""
    name = resolve_aggregator(fed.aggregator)
    get_aggregator(name)
    if name == "trimmed_mean" and not 0.0 <= fed.trim_frac < 0.5:
        raise ValueError(
            f"FedConfig.trim_frac={fed.trim_frac} outside [0, 0.5): trimming "
            "half or more from each side leaves no survivors for any n")
    if name == "dp":
        if fed.dp_clip <= 0:
            raise ValueError(
                f"FedConfig.dp_clip={fed.dp_clip} must be > 0: the clip bound "
                "is the DP sensitivity; 0 would zero every client delta")
        if fed.dp_noise < 0:
            raise ValueError(
                f"FedConfig.dp_noise={fed.dp_noise} must be >= 0 "
                "(noise multiplier z; 0 = clip-only)")
    if name == "cosine_filter":
        if not -1.0 <= fed.outlier_cos <= 1.0:
            raise ValueError(
                f"FedConfig.outlier_cos={fed.outlier_cos} outside [-1, 1]: "
                "it is compared against cosine similarities")
        if fed.sketch_dim <= 0:
            raise ValueError(
                "cosine_filter scores clients on sketch_dim CountSketches; "
                f"FedConfig.sketch_dim={fed.sketch_dim} must be > 0")


def _delta_sq_norms(client_deltas):
    """Per-client squared L2 norm over the WHOLE delta pytree -> [C] f32."""
    leaves = jax.tree.leaves(client_deltas)
    C = leaves[0].shape[0]
    tot = jnp.zeros((C,), jnp.float32)
    for leaf in leaves:
        x = leaf.reshape(C, -1).astype(jnp.float32)
        tot = tot + jnp.sum(x * x, axis=1)
    return tot


@register_aggregator("mean")
def _agg_mean(fed, client_deltas, weights, gates, key):
    # the paper's renormalized gated weighted mean — the kernel default
    return weights, gates, {}, None


@register_aggregator("trimmed_mean")
def _agg_trimmed(fed, client_deltas, weights, gates, key):
    return weights, gates, dict(aggregator="trimmed_mean",
                                trim_frac=float(fed.trim_frac)), None


@register_aggregator("median")
def _agg_median(fed, client_deltas, weights, gates, key):
    return weights, gates, dict(aggregator="median"), None


@register_aggregator("dp", needs_key=True)
def _agg_dp(fed, client_deltas, weights, gates, key):
    """DP-FedAvg: clip each client delta to L2 <= dp_clip (a per-client
    multiplicative factor folded into the kernel's weighted contraction),
    add N(0, (dp_noise * dp_clip / inclusion_mass)^2) per coordinate.

    The noise is drawn OUTSIDE the kernel (one [M_total] jax.random draw
    per round) so the Pallas kernel and the jnp lowering see the very same
    vector — the in-kernel TPU PRNG would break CPU/TPU parity. dp_noise
    is the raw noise multiplier z; ``dp_epsilon`` below composes the
    per-round mechanisms over a run into an (epsilon, delta) report."""
    if key is None:
        raise ValueError(
            "aggregator='dp' draws per-round Gaussian noise and needs the "
            "round key: thread key=aggregator_key(fed, round_idx) through "
            "aggregate_clients/aggregate_delta")
    norms = jnp.sqrt(_delta_sq_norms(client_deltas))
    row_scale = jnp.minimum(1.0, fed.dp_clip / jnp.maximum(norms, 1e-12))
    M = sum(leaf.size for leaf in jax.tree.leaves(client_deltas))
    C = jax.tree.leaves(client_deltas)[0].shape[0]
    noise = jax.random.normal(key, (M // C,), jnp.float32)
    kw = dict(aggregator="dp", row_scale=row_scale,
              noise_scale=float(fed.dp_noise) * float(fed.dp_clip))
    return weights, gates, kw, noise


# ============================================================ DP accounting
# RDP orders to minimize over: dense where the optimum usually lands for
# z in [0.3, 10] over 1..1e5 rounds, sparse log-spaced tail for tiny z.
DP_RDP_ORDERS = tuple([1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0,
                       10.0, 12.0, 16.0, 20.0, 24.0, 32.0, 48.0, 64.0,
                       96.0, 128.0, 192.0, 256.0, 384.0, 512.0])


def dp_epsilon(noise_multiplier: float, steps: int, delta: float,
               orders=DP_RDP_ORDERS):
    """(epsilon, best_order) for ``steps`` compositions of the Gaussian
    mechanism with noise multiplier z (= FedConfig.dp_noise), at the given
    target ``delta`` — the budget the ``dp`` aggregator actually spends.

    Renyi DP of one Gaussian mechanism at order alpha is alpha / (2 z^2)
    (Mironov 2017, arXiv:1702.07476 Prop. 7); RDP composes additively over
    rounds, and converts to (eps, delta)-DP via
    eps = min_alpha [ steps * alpha / (2 z^2) + log(1/delta) / (alpha - 1) ]
    (ibid. Prop. 3). This is the standard moments-accountant bound for
    full-batch participation (no subsampling amplification — every gated
    client contributes each round, which is FedALIGN's regime); it is
    conservative when participation sampling thins cohorts.

    z <= 0 means no noise: epsilon is infinite. Sanity anchor: z=1, one
    step, delta=1e-5 -> eps ~ 5.3."""
    if steps <= 0:
        return 0.0, None
    if noise_multiplier <= 0:
        return float("inf"), None
    if not (0.0 < delta < 1.0):
        raise ValueError(f"dp_epsilon needs a target delta in (0, 1), "
                         f"got {delta}")
    z2 = float(noise_multiplier) ** 2
    log1d = math.log(1.0 / float(delta))
    best, best_order = float("inf"), None
    for a in orders:
        if a <= 1.0:
            continue
        eps = steps * a / (2.0 * z2) + log1d / (a - 1.0)
        if eps < best:
            best, best_order = eps, a
    return best, best_order


def dp_report(fed, rounds: int):
    """(epsilon, delta) actually spent by a run of ``rounds`` rounds under
    this config, or None when the run is not differentially private
    (aggregator != 'dp', or clip-only dp_noise=0)."""
    if resolve_aggregator(getattr(fed, "aggregator", "mean")) != "dp":
        return None
    if fed.dp_noise <= 0:
        return None
    eps, _ = dp_epsilon(float(fed.dp_noise), int(rounds), float(fed.dp_delta))
    return eps, float(fed.dp_delta)


@register_aggregator("cosine_filter", in_kernel=False)
def _agg_cosine(fed, client_deltas, weights, gates, key):
    """Zero the gate of clients whose delta DIRECTION disagrees with the
    cohort: cosines are estimated on sketch_dim CountSketches (one O(M)
    pass per client, reusing engine.delta_sketch), so the similarity pass
    is O(C * sketch_dim) — never [C, C] on full deltas. The reference is
    the gated weighted mean of the per-client NORMALIZED sketches (the
    mean direction): normalizing first means a norm-boosted Byzantine
    client cannot buy reference mass, which a raw-delta mean would grant
    it. Clients with cos < fed.outlier_cos are dropped for the round; the
    reduction then proceeds as the plain gated mean (same single kernel
    launch, this is purely a gate rewrite)."""
    from repro.fl.engine import delta_sketch
    skey = fold_in_name(jax.random.PRNGKey(fed.seed), "aggregator_cosine_sketch")
    sk = jax.vmap(lambda d: delta_sketch(d, skey, fed.sketch_dim))(client_deltas)
    norms = jnp.sqrt(jnp.sum(sk * sk, axis=1))
    dirs = sk / jnp.maximum(norms, 1e-12)[:, None]
    wg = (weights * gates).astype(jnp.float32)
    # mask excluded rows before the weighted mean: a non-finite delta
    # behind gate 0 sketches to NaN and 0 * NaN would poison the reference
    ref = (jnp.einsum("c,cd->d", wg, jnp.where((wg > 0)[:, None], dirs, 0.0))
           / jnp.maximum(jnp.sum(wg), 1e-30))
    ref = ref / jnp.maximum(jnp.sqrt(jnp.sum(ref * ref)), 1e-12)
    cos = dirs @ ref
    keep = (cos >= fed.outlier_cos).astype(gates.dtype)
    return weights, gates * keep, {}, None


# ============================================================== wire codecs
WIRE_CODECS = Registry(
    "wire codec", aliases={None: "identity", "": "identity",
                           "none": "identity"})


def register_wire_codec(name: str):
    """Register a WireCodec under ``name`` (decorator, like
    ``register_aggregator``).

    A WireCodec is lossy uplink compression of the fused [C, M_total]
    client-delta buffer — the client -> server stream that dominates
    federated communication at pod scale. The registered object provides
    three static methods:

    - ``encode(fed, buf) -> (updates, codec_kw)``: compress the f32
      [C, M] buffer into the wire operand ``updates`` (whatever the codec
      transmits — int8 rows, [C, k] top-k values, [C, dim] sketch rows)
      plus the extra operands/kwargs ``codec_kw`` that
      ``kernels.ops.fedagg`` needs to decode-and-reduce INSIDE the one
      fused kernel launch (per-client dequant scales, index planes,
      hash/sign streams, and the true output length ``out_m``).
    - ``decode(fed, updates, codec_kw, M) -> [C, M] f32``: the dense
      decode — used ONLY for the error-feedback residual and by tests.
      The aggregation itself never materializes it: the kernel decodes
      per [C, block_m] tile in VMEM (dequantize-in-register, sparse
      scatter-accumulate, sketch gather).
    - ``wire_bytes(fed, C, M) -> int``: analytic uplink bytes per round
      (the bench's ``bytes_per_round`` metric).
    """
    return WIRE_CODECS.register(name, codec_name=name)


def resolve_wire_codec(name) -> str:
    """Canonical registry name ('none' / None / '' mean identity)."""
    return WIRE_CODECS.resolve(name)


def get_wire_codec(name):
    return WIRE_CODECS.lookup(name)


@register_validator("codec")
def check_codec_config(fed):
    """Validate the wire-codec knobs whose bad values would corrupt the
    uplink silently (same contract as ``check_aggregator_config``:
    actionable errors at the engine boundary, no-op when disabled).
    Registered as the ``validate_config`` "codec" hook; direct calls are
    deprecated."""
    name = resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
    get_wire_codec(name)
    if name == "identity":
        return
    if not fed.fused_agg:
        raise ValueError(
            f"wire_codec={name!r} compresses the fused [C, M_total] buffer; "
            "fused_agg=False never builds that buffer (one kernel call per "
            "leaf) — enable fused_agg or set wire_codec='identity'")
    if name == "topk" and not 0.0 < float(fed.codec_topk_frac) <= 1.0:
        raise ValueError(
            f"FedConfig.codec_topk_frac={fed.codec_topk_frac} outside "
            "(0, 1]: it is the kept fraction of M_total per client row "
            "(k = max(1, floor(frac * M)))")
    if name == "sketch" and int(fed.codec_sketch_dim) < 1:
        raise ValueError(
            f"FedConfig.codec_sketch_dim={fed.codec_sketch_dim} must be "
            ">= 1 (the CountSketch row width on the wire)")


def wire_sketch_streams(fed, M: int):
    """The run-constant CountSketch hash/sign planes of the sketch codec:
    ``h`` [M] i32 buckets, ``sign`` [M] f32 Rademacher signs.

    One named stream off the config seed (``fold_in_name`` — crc32, so
    deterministic across processes), SHARED by every client and every
    round: encode buckets coordinates with ``h``/``sign``, decode gathers
    the same buckets back, and sketched rounds stay backend-identical."""
    dim = int(fed.codec_sketch_dim)
    key = fold_in_name(jax.random.PRNGKey(fed.seed), "wire_sketch")
    kh, ks = jax.random.split(key)
    h = jax.random.randint(kh, (M,), 0, dim, dtype=jnp.int32)
    sign = jax.random.rademacher(ks, (M,), dtype=jnp.float32)
    return h, sign


def wire_bytes_per_round(fed, num_rows: int, m_total: int) -> int:
    """Analytic uplink bytes for one round: ``num_rows`` client rows (C
    dense, K under a cohort gather) of ``m_total`` coordinates through the
    configured ``fed.wire_codec`` (identity pays ``agg_dtype`` bytes)."""
    codec = get_wire_codec(getattr(fed, "wire_codec", "identity"))
    return int(codec.wire_bytes(fed, int(num_rows), int(m_total)))


@register_wire_codec("identity")
class _IdentityCodec:
    """No codec: the [C, M] buffer travels as-is at ``fed.agg_dtype``."""

    @staticmethod
    def encode(fed, buf):
        return buf, {}

    @staticmethod
    def decode(fed, updates, codec_kw, M):
        return updates.astype(jnp.float32)

    @staticmethod
    def wire_bytes(fed, C, M):
        return C * M * jnp.dtype(fed.agg_dtype).itemsize


@register_wire_codec("int8")
class _Int8Codec:
    """Symmetric per-client-row int8: q = round(x / scale) clipped to
    [-127, 127], scale = rowmax|x| / 127 (1.0 on an all-zero row, so its
    decode is exact zero). The wire is [C, M] int8 plus one f32 scale per
    client — 4x under f32 agg_dtype — and the kernel dequantizes
    ``q * scale`` in-register right after the tile load, under every
    registered aggregator (inside the mean/dp contraction; before the
    order-statistics sort)."""

    @staticmethod
    def encode(fed, buf):
        amax = jnp.max(jnp.abs(buf), axis=1)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(buf / scale[:, None]), -127.0, 127.0)
        return q.astype(jnp.int8), dict(codec="int8", dequant_scale=scale)

    @staticmethod
    def decode(fed, updates, codec_kw, M):
        scale = codec_kw["dequant_scale"].astype(jnp.float32)
        return updates.astype(jnp.float32) * scale[:, None]

    @staticmethod
    def wire_bytes(fed, C, M):
        return C * M + C * 4                        # int8 rows + f32 scales


@register_wire_codec("topk")
class _TopkCodec:
    """Per-client magnitude top-k sparsification: keep the
    k = max(1, floor(codec_topk_frac * M)) largest-|x| coordinates per row
    (an f32 value + i32 index pair each on the wire). The kernel rebuilds
    every [C, block_m] tile with a fori_loop scatter-accumulate over the k
    entries — sparse in HBM, dense only in VMEM."""

    @staticmethod
    def _k(fed, M):
        return max(1, min(int(M), int(float(fed.codec_topk_frac) * M)))

    @staticmethod
    def encode(fed, buf):
        M = buf.shape[1]
        k = _TopkCodec._k(fed, M)
        _, idx = jax.lax.top_k(jnp.abs(buf), k)
        idx = idx.astype(jnp.int32)
        vals = jnp.take_along_axis(buf, idx, axis=1).astype(jnp.float32)
        return vals, dict(codec="topk", topk_idx=idx, out_m=M)

    @staticmethod
    def decode(fed, updates, codec_kw, M):
        C = updates.shape[0]
        rows = jnp.arange(C)[:, None]
        dense = jnp.zeros((C, M), jnp.float32)
        return dense.at[rows, codec_kw["topk_idx"]].add(
            updates.astype(jnp.float32))

    @staticmethod
    def wire_bytes(fed, C, M):
        return C * _TopkCodec._k(fed, M) * 8        # f32 value + i32 index


@register_wire_codec("sketch")
class _SketchCodec:
    """CountSketch uplink (the ``engine.delta_sketch`` projection with ONE
    shared hash/sign stream per run — ``wire_sketch_streams``): each client
    transmits [codec_sketch_dim] f32 bucket sums; decode gathers the
    unbiased estimate ``sign[m] * s[c, h[m]]`` per kernel tile."""

    @staticmethod
    def encode(fed, buf):
        M = buf.shape[1]
        dim = int(fed.codec_sketch_dim)
        h, sign = wire_sketch_streams(fed, M)
        s = jax.vmap(
            lambda row: jax.ops.segment_sum(sign * row, h, num_segments=dim)
        )(buf.astype(jnp.float32))
        return s, dict(codec="sketch", sketch_h=h, sketch_sign=sign, out_m=M)

    @staticmethod
    def decode(fed, updates, codec_kw, M):
        h = codec_kw["sketch_h"]
        sign = codec_kw["sketch_sign"].astype(jnp.float32)
        return updates.astype(jnp.float32)[:, h] * sign[None, :]

    @staticmethod
    def wire_bytes(fed, C, M):
        return C * int(fed.codec_sketch_dim) * 4    # f32 bucket rows


# ========================================================= server optimizers
SERVER_OPTIMIZERS = Registry("server optimizer",
                             aliases={None: "sgd", "none": "sgd"})


def register_server_optimizer(name: str):
    """Register ``factory(fed) -> optim.optimizers.Optimizer`` under ``name``.

    The factory reads its hyper-parameters off the FedConfig (duck-typed:
    anything with the ``server_*`` attributes works); the resulting
    Optimizer's ``init(params)`` builds the moment pytree carried in
    ``FederationState.opt_state`` and ``update`` consumes the aggregated
    delta as a pseudo-gradient."""
    return SERVER_OPTIMIZERS.register(name, opt_name=name)


def resolve_server_opt(name) -> str:
    """Canonical registry name ('none', the legacy no-op, is plain sgd)."""
    return SERVER_OPTIMIZERS.resolve(name)


def get_server_optimizer(name: str) -> Callable:
    return SERVER_OPTIMIZERS.lookup(name)


def server_optimizer(fed):
    """The configured ServerOptimizer instance for ``fed.server_opt``."""
    return get_server_optimizer(fed.server_opt)(fed)


@register_server_optimizer("sgd")
def _server_sgd(fed):
    # w <- w + server_lr * agg_delta: FedAvg at server_lr=1 (the paper rule)
    return _opt.sgd(0.0)


@register_server_optimizer("momentum")
def _server_momentum(fed):
    # FedAvgM: momentum over aggregated deltas
    return _opt.sgd(momentum=fed.server_momentum)


@register_server_optimizer("adam")
def _server_adam(fed):
    return _opt.adam(fed.server_b1, fed.server_b2, fed.server_eps)


@register_server_optimizer("yogi")
def _server_yogi(fed):
    return _opt.yogi(fed.server_b1, fed.server_b2, fed.server_eps)


def apply_server_opt(fed, global_params, opt_state, agg_delta, *, scale=1.0):
    """One server-optimizer step on an already-aggregated global delta.

    Returns (new_params, new_opt_state). The delta enters the optimizer as
    the pseudo-gradient g = -agg_delta, so ``sgd`` at server_lr recovers
    w + server_lr * delta exactly and ``momentum`` reproduces the legacy
    FedAvgM recursion m <- beta m + delta, w <- w + server_lr m.

    ``scale`` pre-multiplies the delta (in f32, after the wire-dtype cast):
    the staleness discount of the ``scan_async`` backend enters the
    optimizer here — one call PER POPPED in-flight slot, each with that
    slot's own scale (the constant ``staleness_decay ** async_depth``
    under the fifo pipe; ``staleness_decay ** age``, optionally times the
    measured-drift cosine, under the variable-lag ``ready`` buffer) — so a
    stale delta's momentum/second-moment contribution is discounted too,
    not just its parameter step. ``scale`` may be a traced scalar (the
    measured-age discounts are); only the python-literal 1.0 skips the
    multiply entirely — the synchronous path is untouched."""
    opt = server_optimizer(fed)
    if isinstance(scale, (int, float)) and float(scale) == 1.0:
        grads = jax.tree.map(lambda d: -d.astype(jnp.float32), agg_delta)
    else:
        grads = jax.tree.map(lambda d: -d.astype(jnp.float32) * scale,
                             agg_delta)
    return opt.update(grads, opt_state, global_params, fed.server_lr)


def aggregate_delta(global_params, client_params, weights, gates, *,
                    fed, interpret=False, key=None, ef_accum=None):
    """Delta-form gated aggregation WITHOUT the server step:

        d <- agg(cast(w_k - w, fed.agg_dtype))      (ONE fused fedagg call)

    Returns the aggregated global delta (leaves in ``fed.agg_dtype``),
    reduced by the configured ``fed.aggregator`` (``key`` feeds stochastic
    aggregators — pass ``aggregator_key(fed, round_idx)`` when
    ``get_aggregator(fed.aggregator).needs_key``). This is the seam the
    ``scan_async`` backend buffers: an in-flight cohort is exactly one of
    these deltas awaiting its (staleness-discounted) ``apply_server_opt``
    some rounds later — the robust/private reduction happens at PUSH time,
    so every aggregator commutes with the async buffer. ``client_params``
    may live in cohort space [K, ...] (zero gates drop padding slots).

    A non-identity ``fed.wire_codec`` compresses the fused buffer's uplink
    before the kernel decodes-and-reduces it; with ``ef_accum`` (the
    per-client error-feedback rows, matching ``client_params``'s leading
    axis) the call returns ``(delta, new_ef_accum)`` — under scan_async
    this runs at PUSH time, so the accumulator advances when the delta is
    encoded, not when it lands. ``wire_codec='identity'`` keeps the exact
    legacy trace (python-level branch, codec code untouched)."""
    ad = jnp.dtype(fed.agg_dtype)
    deltas = jax.tree.map(lambda ck, g: (ck - g[None]).astype(ad),
                          client_params, global_params)
    codec_name = resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
    if codec_name != "identity":
        return aggregate_clients(deltas, weights, gates,
                                 use_pallas=fed.use_pallas,
                                 fused=fed.fused_agg, interpret=interpret,
                                 aggregator=getattr(fed, "aggregator", "mean"),
                                 fed=fed, key=key, wire_codec=codec_name,
                                 ef_accum=ef_accum)
    if ef_accum is not None:
        raise ValueError(
            "ef_accum given but fed.wire_codec='identity': the lossless "
            "wire has no compression residual to accumulate")
    return aggregate_clients(deltas, weights, gates,
                             use_pallas=fed.use_pallas,
                             fused=fed.fused_agg, interpret=interpret,
                             aggregator=getattr(fed, "aggregator", "mean"),
                             fed=fed, key=key)


def aggregate_updates(global_params, client_params, weights, gates, *,
                      fed, opt_state=(), interpret=False, key=None):
    """Delta-form gated aggregation + the configured server optimizer:

        d  <- aggregate_delta(...)                  (ONE fused fedagg call)
        w, moments <- ServerOptimizer(fed.server_opt)(w, moments, d)

    Returns (new_params, new_opt_state). ``fed.agg_dtype`` selects the
    reduced-precision delta wire format; accumulation is f32 either way."""
    agg = aggregate_delta(global_params, client_params, weights, gates,
                          fed=fed, interpret=interpret, key=key)
    return apply_server_opt(fed, global_params, opt_state, agg)
