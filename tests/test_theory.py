"""Theorem-1 validation on the exactly-solvable quadratic PFL testbed."""
import numpy as np
import pytest

from repro.core.theory import (empirical_theta_rho,
                               make_quadratic_pfl, run_fedalign_gd,
                               theorem1_bound, theorem1_constants)

_run_fedalign_gd = run_fedalign_gd


def test_quadratic_closed_forms():
    q = make_quadratic_pfl(seed=0)
    ws = q.w_star()
    # gradient of the priority objective vanishes at w*
    grad = sum(q.weights[k] * q.A[k] @ (ws - q.c[k])
               for k in range(len(q.d)) if q.priority_mask[k])
    assert np.linalg.norm(grad) < 1e-8
    assert q.gamma() >= -1e-10
    L, mu = q.smoothness()
    assert L >= mu > 0


def test_aligned_nonpriority_have_small_gamma_k():
    q = make_quadratic_pfl(seed=1, n_nonpriority=6,
                           nonpriority_align=np.linspace(1, 0, 6))
    gks = [q.gamma_k(k) for k in range(4, 10)]
    assert gks[0] < gks[-1]          # aligned client -> small Gamma_k
    assert gks[0] < 0.5


def test_theorem1_bound_holds_on_quadratic():
    """E[F(w_T)] - F* <= (C1 + C2 theta_T Gamma)/(T+gamma) + rho_T with
    the paper's constants, on a strongly-convex instance (deterministic
    gradients => sigma = 0)."""
    q = make_quadratic_pfl(seed=3, n_priority=4, n_nonpriority=6, dim=8)
    L, mu = q.smoothness()
    E = 5
    gamma = max(8 * L / mu, E)
    lr_fn = lambda t: 2.0 / (mu * (t + gamma))
    T_rounds = 60
    w_T, theta_hist, rho_hist = _run_fedalign_gd(q, T_rounds, E, eps=0.5,
                                                 lr_fn=lr_fn)
    err = q.F(w_T) - q.F(q.w_star())
    # G bound: gradients along the trajectory are bounded; use a generous cap
    G2 = max(np.linalg.norm(q.A[k] @ (np.zeros(8) - q.c[k])) ** 2
             for k in range(len(q.d))) * 4 + 1.0
    C1, C2, _ = theorem1_constants(L, mu, sigma=0.0, G=np.sqrt(G2), E=E,
                                   w0_dist_sq=np.linalg.norm(q.w_star()) ** 2)
    T = T_rounds * E
    theta_T, rho_un = empirical_theta_rho(theta_hist, rho_hist, gamma, E)
    rho_T = 2 * L / mu * rho_un
    bound = theorem1_bound(T, C1=C1, C2=C2, gamma=gamma, Gamma=q.gamma(),
                           theta_T=theta_T, rho_T=rho_T)
    assert err <= bound, (err, bound)
    assert 0 < theta_T <= 1.0


def test_theta_rho_tradeoff_direction():
    """Larger eps => smaller theta_T (more inclusion) and larger rho_T —
    the paper's central trade-off (§3.2)."""
    q = make_quadratic_pfl(seed=4, n_priority=3, n_nonpriority=8, dim=6)
    L, mu = q.smoothness()
    E, gamma = 5, max(8 * L / mu, 5)
    lr_fn = lambda t: 2.0 / (mu * (t + gamma))
    res = {}
    for eps in (0.0, 0.3, 3.0, 1e9):
        _, th, rh = _run_fedalign_gd(q, 30, E, eps, lr_fn)
        theta_T, rho_un = empirical_theta_rho(th, rh, gamma, E)
        res[eps] = (theta_T, rho_un)
    assert res[0.0][0] == pytest.approx(1.0 * 30 * 5 / (30 * 5 + gamma - 2), rel=1e-6)
    assert res[1e9][0] < res[0.3][0] <= res[0.0][0] + 1e-9
    assert res[1e9][1] >= res[0.0][1]
    assert res[0.0][1] == 0.0


def test_eps_zero_recovers_fedavg_priority_rate():
    """With eps=0 FedALIGN == FedAvg-on-priority: same iterates exactly."""
    q = make_quadratic_pfl(seed=5)
    L, mu = q.smoothness()
    lr_fn = lambda t: 2.0 / (mu * (t + max(8 * L / mu, 5)))
    w_a, _, _ = _run_fedalign_gd(q, 20, 5, eps=0.0, lr_fn=lr_fn)
    # manual FedAvg over priority clients only
    C = len(q.d)
    w = np.zeros(q.c.shape[1])
    t = 0
    for r in range(20):
        locals_ = []
        for k in range(C):
            wk = w.copy()
            for e in range(5):
                wk = wk - lr_fn(t + e) * (q.A[k] @ (wk - q.c[k]))
            locals_.append(wk)
        t += 5
        wg = q.weights * q.priority_mask
        w = np.einsum("k,ki->i", wg, np.stack(locals_)) / wg.sum()
    np.testing.assert_allclose(w_a, w, atol=1e-10)
