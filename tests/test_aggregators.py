"""Aggregator registry coverage: per-variant kernel parity (jnp lowering vs
Pallas interpret vs per-leaf split vs the naive refs), the zero-inclusion /
zero-mass edges, client-weight validation, dp determinism, cosine_filter
gate rewrites, checkpoint fingerprints, and cross-backend round parity for
every robust/private variant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import aggregation as agg
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=7, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])

ROBUST = ["trimmed_mean", "median", "dp", "cosine_filter"]


def _fed(aggregator="mean", **kw):
    base = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                aggregator=aggregator, trim_frac=0.25, dp_clip=0.5,
                dp_noise=0.25, outlier_cos=-0.5)
    base.update(kw)
    return FedConfig(**base)


def _tree(C=6, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "w1": jax.random.normal(ks[0], (C, 7, 13)).astype(dtype),
        "b1": jax.random.normal(ks[1], (C, 13)).astype(dtype),
        "w2": jax.random.normal(ks[2], (C, 13, 3)).astype(dtype),
        "scale": jax.random.normal(ks[3], (C,)).astype(dtype),
    }


def _wg(C=6, seed=1):
    k = jax.random.PRNGKey(seed)
    w = jax.random.uniform(k, (C,)) + 0.1
    g = (jax.random.uniform(jax.random.fold_in(k, 1), (C,)) > 0.4).astype(jnp.float32)
    g = g.at[0].set(1.0)
    return w, g


# ===================================================== registry contract
def test_registry_contract():
    for name in ["mean"] + ROBUST:
        prep = agg.get_aggregator(name)
        assert prep.agg_name == name
    assert agg.resolve_aggregator(None) == "mean"
    assert agg.resolve_aggregator("none") == "mean"
    assert agg.get_aggregator("dp").needs_key
    assert not agg.get_aggregator("median").needs_key
    assert not agg.get_aggregator("cosine_filter").in_kernel
    with pytest.raises(ValueError, match="registered"):
        agg.get_aggregator("krum")


def test_aggregator_config_validation():
    with pytest.raises(ValueError, match="trim_frac"):
        agg.check_aggregator_config(_fed("trimmed_mean", trim_frac=0.5))
    with pytest.raises(ValueError, match="dp_clip"):
        agg.check_aggregator_config(_fed("dp", dp_clip=0.0))
    with pytest.raises(ValueError, match="dp_noise"):
        agg.check_aggregator_config(_fed("dp", dp_noise=-1.0))
    with pytest.raises(ValueError, match="outlier_cos"):
        agg.check_aggregator_config(_fed("cosine_filter", outlier_cos=1.5))
    # and the round factory runs the same check up front
    with pytest.raises(ValueError, match="aggregator"):
        engine.make_round_fn(LOSS, _fed("krum"))


def test_dp_requires_round_key():
    tree = _tree()
    w, g = _wg()
    with pytest.raises(ValueError, match="aggregator_key"):
        agg.aggregate_clients(tree, w, g, aggregator="dp", fed=_fed("dp"))
    with pytest.raises(ValueError, match="fed="):
        agg.aggregate_clients(tree, w, g, aggregator="median")


# ===================================================== multi-path parity
@pytest.mark.parametrize("name", ["mean"] + ROBUST)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_per_leaf_pallas_agree(name, dtype):
    """Every registered aggregator: fused [C, M_total] == per-leaf ==
    Pallas interpret, on a mixed-size pytree."""
    tree = _tree(dtype=dtype)
    w, g = _wg()
    fed = _fed(name)
    key = agg.aggregator_key(fed, 2) if agg.get_aggregator(name).needs_key else None
    kw = dict(aggregator=name, fed=fed, key=key)
    fused = agg.aggregate_clients(tree, w, g, fused=True, **kw)
    per_leaf = agg.aggregate_clients(tree, w, g, fused=False, **kw)
    pallas = agg.aggregate_clients(tree, w, g, fused=True, use_pallas=True,
                                   interpret=True, **kw)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for a, b, c in zip(jax.tree.leaves(fused), jax.tree.leaves(per_leaf),
                       jax.tree.leaves(pallas)):
        assert a.dtype == b.dtype == c.dtype == dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=atol)


# ===================================================== zero-inclusion edges
@pytest.mark.parametrize("name", ["mean"] + ROBUST)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_all_zero_gates_exact_zero(name, dtype):
    """Zero inclusion mass -> EXACT zero delta on every path, even when an
    excluded client's payload is NaN (the old num/1e-30 guard amplified
    instead)."""
    tree = _tree(dtype=dtype)
    tree = jax.tree.map(lambda l: l.at[2].set(jnp.nan), tree)   # poison
    w, _ = _wg()
    g = jnp.zeros((6,))
    fed = _fed(name)
    key = agg.aggregator_key(fed, 0) if agg.get_aggregator(name).needs_key else None
    kw = dict(aggregator=name, fed=fed, key=key)
    for path in (dict(fused=True), dict(fused=False),
                 dict(fused=True, use_pallas=True, interpret=True)):
        out = agg.aggregate_clients(tree, w, g, **kw, **path)
        for leaf, src in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            assert leaf.dtype == src.dtype
            assert np.all(np.asarray(leaf, np.float32) == 0.0), (name, path)


def test_excluded_nan_client_does_not_leak():
    """A NaN delta behind gate 0 must not perturb the included clients'
    aggregate (0 * NaN masking), for every aggregator."""
    tree = _tree()
    w, _ = _wg()
    g = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0, 1.0])
    poisoned = jax.tree.map(lambda l: l.at[2].set(jnp.nan).at[4].set(jnp.inf),
                            tree)
    for name in ["mean"] + ROBUST:
        fed = _fed(name)
        key = agg.aggregator_key(fed, 1) if agg.get_aggregator(name).needs_key else None
        kw = dict(aggregator=name, fed=fed, key=key)
        clean = agg.aggregate_clients(tree, w, g, **kw)
        dirty = agg.aggregate_clients(poisoned, w, g, **kw)
        for a, b in zip(jax.tree.leaves(clean), jax.tree.leaves(dirty)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


# ===================================================== weight validation
def test_client_weight_validation_errors():
    with pytest.raises(ValueError, match=r"clients \[1\] are NaN/inf"):
        agg.check_client_weights(np.asarray([0.5, np.nan, 0.5]))
    with pytest.raises(ValueError, match=r"clients \[0, 2\] have"):
        agg.check_client_weights(np.asarray([-0.1, 0.5, -2.0]))
    # zero weights are legitimate (a client can own no data)
    agg.check_client_weights(np.asarray([0.0, 1.0]))
    # traced values pass through: validation happens at concrete boundaries
    jax.jit(lambda w: agg.check_client_weights(w))(jnp.ones((3,)))
    # and the aggregation entry point enforces it on concrete weights
    tree = _tree()
    with pytest.raises(ValueError, match="non-negative"):
        agg.aggregate_clients(tree, jnp.asarray([1.0, -1.0, 1, 1, 1, 1]),
                              jnp.ones((6,)))


def test_run_federation_validates_weights():
    from repro.fl.simulator import run_federation
    bad = dataclasses.replace(
        FEDN, weights=np.asarray(FEDN.weights).copy() * np.nan)
    fed = _fed(rounds=1)
    with pytest.raises(ValueError, match="Federation.weights"):
        run_federation(LOSS, INIT(jax.random.PRNGKey(0)), fed, bad)


# ===================================================== zero-inclusion rounds
@pytest.mark.parametrize("server_opt", ["sgd", "momentum", "adam", "yogi"])
@pytest.mark.parametrize("backend", engine.BACKENDS)
def test_zero_inclusion_round_skips_server_opt(server_opt, backend):
    """A sync round where EVERY gate is zero (warm-up with an empty priority
    set) must be a true no-op: params, momentum, and adam/yogi's step count
    bit-identical — running the optimizer on the zero delta would decay
    momentum and tick ``t``."""
    fed = _fed(server_opt=server_opt, warmup_frac=0.5, selection="fedalign",
               server_lr=0.7, server_momentum=0.9)
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    state0 = engine.init_state(INIT(jax.random.PRNGKey(0)), fed, C)
    pm0 = jnp.zeros_like(PM)                 # no priority clients at all
    state1, stats = fn(state0, DATA, pm0, W, jax.random.PRNGKey(0),
                       jnp.int32(0))         # round 0 is warm-up
    assert float(jnp.sum(stats["gates"])) == 0.0
    for a, b in zip(jax.tree.leaves(state0.params),
                    jax.tree.leaves(state1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state0.opt_state),
                    jax.tree.leaves(state1.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sanity: a post-warm-up round with real gates DOES step
    state2, stats2 = fn(state0, DATA, PM, W, jax.random.PRNGKey(0),
                        jnp.int32(9))
    assert float(jnp.sum(stats2["gates"])) > 0
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(state0.params),
                                jax.tree.leaves(state2.params)))
    assert moved


@pytest.mark.parametrize("aggregator", ["median", "dp"])
def test_zero_inclusion_skip_under_robust_aggregators(aggregator):
    """The skip keys off the configured aggregator's own inclusion mass
    (count for the order statistics, sum p_k I_k otherwise)."""
    fed = _fed(aggregator, server_opt="adam", warmup_frac=0.5,
               selection="fedalign")
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    state0 = engine.init_state(INIT(jax.random.PRNGKey(0)), fed, C)
    state1, _ = fn(state0, DATA, jnp.zeros_like(PM), W,
                   jax.random.PRNGKey(0), jnp.int32(0))
    assert int(state1.opt_state["t"]) == 0
    for a, b in zip(jax.tree.leaves((state0.params, state0.opt_state)),
                    jax.tree.leaves((state1.params, state1.opt_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_inclusion_mass_conventions():
    w = jnp.asarray([0.0, 0.2, 0.8])
    g = jnp.asarray([1.0, 1.0, 0.0])
    # weighted mass for the renormalized means
    assert float(agg.inclusion_mass(_fed("mean"), w, g)) == pytest.approx(0.2)
    # included COUNT for the unweighted order statistics: a zero-weight
    # included client still moves the median
    assert float(agg.inclusion_mass(_fed("median"), w, g)) == 2.0
    assert float(agg.inclusion_mass(_fed("trimmed_mean"), w, g)) == 2.0


# ===================================================== dp semantics
def test_dp_noise_deterministic_per_round_key():
    tree = _tree()
    w, g = _wg()
    fed = _fed("dp", dp_noise=0.8)
    k3 = agg.aggregator_key(fed, 3)
    a = agg.aggregate_clients(tree, w, g, aggregator="dp", fed=fed, key=k3)
    b = agg.aggregate_clients(tree, w, g, aggregator="dp", fed=fed, key=k3)
    c = agg.aggregate_clients(tree, w, g, aggregator="dp", fed=fed,
                              key=agg.aggregator_key(fed, 4))
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert any(not np.array_equal(np.asarray(la), np.asarray(lc))
               for la, lc in zip(jax.tree.leaves(a), jax.tree.leaves(c)))


def test_dp_clip_bounds_aggregate_norm():
    """Clip-only dp (dp_noise=0): the aggregate is a convex combination of
    deltas clipped to L2 <= dp_clip, so its own norm obeys the bound."""
    tree = jax.tree.map(lambda l: l * 50.0, _tree())     # huge deltas
    w, g = _wg()
    fed = _fed("dp", dp_clip=0.3, dp_noise=0.0)
    out = agg.aggregate_clients(tree, w, g, aggregator="dp", fed=fed,
                                key=agg.aggregator_key(fed, 0))
    norm = float(jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                              for l in jax.tree.leaves(out))))
    assert norm <= 0.3 + 1e-5, norm


# ===================================================== cosine_filter
def _aligned_deltas(C=6, bad=4, factor=-25.0):
    k = jax.random.PRNGKey(5)
    base = {"a": jax.random.normal(k, (40,)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (25,))}
    tree = jax.tree.map(
        lambda x: jnp.stack([x * (1.0 + 0.02 * i) for i in range(C)]), base)
    # one sign-flipped, norm-boosted client (model-replacement style)
    return jax.tree.map(lambda l: l.at[bad].set(factor * l[0]), tree)


def test_cosine_filter_zeroes_outlier_gates():
    fed = _fed("cosine_filter", outlier_cos=0.0, sketch_dim=512)
    deltas = _aligned_deltas(bad=4)
    w = jnp.ones((6,)) / 6
    g = jnp.ones((6,))
    w2, g2, kernel_kw, noise = agg.get_aggregator("cosine_filter")(
        fed, deltas, w, g, None)
    assert kernel_kw == {} and noise is None
    g2 = np.asarray(g2)
    assert g2[4] == 0.0, g2                  # opposed client dropped
    np.testing.assert_array_equal(g2[[0, 1, 2, 3, 5]], 1.0)
    # end to end it is exactly the plain gated mean under the rewritten gates
    out = agg.aggregate_clients(deltas, w, g, aggregator="cosine_filter",
                                fed=fed)
    want = agg.aggregate_clients(deltas, w, jnp.asarray(g2))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cosine_filter_norm_boost_cannot_buy_reference_mass():
    """The reference direction is the mean of NORMALIZED sketches: a x1e4
    attacker moves it no further than a x25 one, so it still gets dropped."""
    fed = _fed("cosine_filter", outlier_cos=0.0, sketch_dim=512)
    w = jnp.ones((6,)) / 6
    g = jnp.ones((6,))
    for factor in (-25.0, -1e4):
        _, g2, _, _ = agg.get_aggregator("cosine_filter")(
            fed, _aligned_deltas(bad=4, factor=factor), w, g, None)
        assert np.asarray(g2)[4] == 0.0, factor


# ===================================================== robust semantics
def test_trimmed_and_median_resist_scaled_outlier():
    """An included Byzantine client scaling its delta x100 drags the mean
    but not the order statistics (and they are UNWEIGHTED: the attacker's
    weight does not matter)."""
    tree = _aligned_deltas(bad=4, factor=-100.0)
    w = jnp.asarray([0.1, 0.1, 0.1, 0.1, 0.55, 0.05])    # attacker is heavy
    g = jnp.ones((6,))
    honest = jax.tree.map(lambda l: l[:4], tree)
    honest_mean = jax.tree.map(lambda l: jnp.mean(l, axis=0), honest)

    def dist(x, y):
        return float(sum(jnp.sum((a - b) ** 2) ** 0.5 for a, b in
                         zip(jax.tree.leaves(x), jax.tree.leaves(y))))

    mean_out = agg.aggregate_clients(tree, w, g)
    med_out = agg.aggregate_clients(tree, w, g, aggregator="median",
                                    fed=_fed("median"))
    trim_out = agg.aggregate_clients(tree, w, g, aggregator="trimmed_mean",
                                     fed=_fed("trimmed_mean", trim_frac=0.25))
    assert dist(med_out, honest_mean) < 0.2 * dist(mean_out, honest_mean)
    assert dist(trim_out, honest_mean) < 0.2 * dist(mean_out, honest_mean)


# ===================================================== round-level parity
@pytest.mark.parametrize("aggregator", ROBUST)
def test_round_backends_agree_per_aggregator(aggregator):
    """vmap_spatial / scan_temporal / scan_async(depth 0) produce identical
    carried state under every robust/private aggregator (same per-round
    noise key, same gather semantics)."""
    fed = _fed(aggregator, local_epochs=2)
    state = engine.init_state(INIT(jax.random.PRNGKey(0)), fed, C)
    outs = []
    for backend in engine.BACKENDS:
        fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
        outs.append(fn(state, DATA, PM, W, jax.random.PRNGKey(0),
                       jnp.int32(1)))
    (pv, sv), *others = outs
    for pt, st in others:
        np.testing.assert_array_equal(np.asarray(sv["gates"]),
                                      np.asarray(st["gates"]))
        for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(pt)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


@pytest.mark.parametrize("aggregator", ROBUST)
def test_sharded_robust_spatial_equals_temporal(aggregator):
    """The temporal (FSDP) round cannot stream robust aggregators through
    its linear weighted-sum carry: it must gather the client axis and
    route through engine.server_delta — and still match the spatial round
    bit-for-bit in semantics."""
    from repro.fl import sharded
    from tests.test_sharded import MODEL, _batch
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05,
                    aggregator=aggregator, trim_frac=0.25, dp_clip=0.5,
                    dp_noise=0.1, outlier_cos=-0.5)
    batch = _batch()
    state = engine.init_state(MODEL.init(jax.random.PRNGKey(0)), fed, 4)
    ss, ts = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))(state, batch)
    st, tt = jax.jit(sharded.make_temporal_round(MODEL, fed, 4))(state, batch)
    np.testing.assert_array_equal(np.asarray(ts["gates"]),
                                  np.asarray(tt["gates"]))
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(st)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-5)


def test_sharded_robust_cohort_matches_dense():
    """Gather-train (max_cohort) spatial round under an order-statistic
    aggregator: padding slots carry gate 0, so the cohort-space reduction
    matches the dense one."""
    from repro.fl import sharded
    from tests.test_sharded import MODEL, _batch
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05, aggregator="median")
    batch = _batch()
    state = engine.init_state(MODEL.init(jax.random.PRNGKey(0)), fed, 4)
    sd, _ = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))(state, batch)
    sc, _ = jax.jit(sharded.make_spatial_round(
        MODEL, fed.replace(max_cohort=4), 4))(state, batch)
    for a, b in zip(jax.tree.leaves(sd), jax.tree.leaves(sc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-5)


# ===================================================== checkpoint fingerprint
def test_checkpoint_aggregator_fingerprint(tmp_path):
    from repro.fl.simulator import load_federation_state, save_federation_state
    fed_m = _fed("median")
    state = engine.init_state(INIT(jax.random.PRNGKey(0)), fed_m, C)
    path = str(tmp_path / "ck.msgpack")
    save_federation_state(path, state, jax.random.PRNGKey(1), 3, fed=fed_m)
    _, _, step = load_federation_state(path, state, fed=fed_m)
    assert step == 3
    with pytest.raises(ValueError, match="aggregator"):
        load_federation_state(path, state, fed=_fed("mean"))
    # no fed -> unvalidated load (old callers keep working)
    load_federation_state(path, state)
