"""Msgpack pytree checkpointing.

Arrays are gathered to host (works for sharded arrays via
``jax.device_get``), serialized with shape/dtype headers, and restored to
the exact pytree structure. Sufficient for single-controller runs; a real
multi-host deployment would write per-shard files keyed by device — the
layout here keeps that extension local to this module.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        arr = np.asarray(obj)
        return {b"__nd__": True, b"dtype": arr.dtype.str, b"shape": list(arr.shape),
                b"data": arr.tobytes()}
    raise TypeError(type(obj))


def _decode(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"])
                             ).reshape(obj[b"shape"]).copy()
    return obj


def save_pytree(path: str, tree: Any, step: int | None = None,
                meta: dict | None = None) -> None:
    """``meta`` is an optional plain-msgpack dict of writer-side config
    facts the reader may validate (e.g. the async-buffer knobs whose
    mismatch would NOT change any leaf shape — see
    ``fl.simulator.save_federation_state``)."""
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    payload = {"treedef": str(treedef), "step": step,
               "leaves": host_leaves, "meta": meta}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, default=_encode))
    os.replace(tmp, path)           # atomic


def load_pytree(path: str, like: Any):
    """Restore into the structure of ``like`` (shape/dtype-checked).
    Returns ``(tree, step, meta)`` — ``meta`` is whatever dict the writer
    passed to ``save_pytree`` (None for older checkpoints).

    Mismatches raise ``ValueError`` with the offending layout spelled out:
    the usual cause is restoring with a config whose state layout differs
    from the one that wrote the checkpoint (different ``server_opt`` moment
    tree, ``num_clients``, ``async_depth`` — which sizes the in-flight
    cohort buffer's leading [D] axis and its per-slot age/valid/timer
    vectors — ``adaptive_staleness``, which allocates the drift-reference
    ``last_delta`` sketch leaf, ``latency_mode``, which allocates the
    event-clock [C] latency leaves and the per-slot countdown timers,
    ``divergence_guard``, which allocates the skip counter, or
    ``wire_codec``/``error_feedback``, which allocate the per-client
    error-feedback accumulator leaves ``ef_accum`` — C x params rows).
    Knobs whose mismatch changes NO leaf shape (``async_mode``/``min_lag``
    — a fifo resume of a ready-mode buffer would reinterpret the slot ages
    — the ``latency_*``/``round_deadline``/failure-model knobs, whose
    mismatch replays a different fault/timer schedule against the restored
    buffer, ``aggregator``, whose mismatch silently feeds the restored
    optimizer moments a differently reduced delta stream, the codec
    identity/rate knobs — restored EF accumulators re-injected under a
    different codec describe a wire that no longer exists — or
    ``candidate_pool``/``pool_weighting``, whose mismatch samples
    different candidate pools from the resume round on, advancing the
    restored backlog/EMA rows for different clients than the writer's run)
    can't be caught here; the writer records them in the payload ``meta``
    and ``fl.simulator.load_federation_state(fed=...)`` validates them."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode, strict_map_key=False)
    leaves, treedef = jax.tree.flatten(like)
    new_leaves = payload["leaves"]
    if len(new_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint {path!r} holds {len(new_leaves)} leaves but the "
            f"requested structure has {len(leaves)} — was it written with a "
            "different config (server_opt moment layout, async_depth "
            "in-flight buffer, adaptive_staleness last_delta sketch, "
            "wire_codec/error_feedback ef_accum accumulator leaves, "
            "num_clients)?")
    out = []
    for i, (old, new) in enumerate(zip(leaves, new_leaves)):
        if tuple(new.shape) != tuple(old.shape):
            raise ValueError(
                f"checkpoint {path!r} leaf {i} has shape "
                f"{tuple(new.shape)} but the requested structure expects "
                f"{tuple(old.shape)} — config/state layout mismatch "
                "(e.g. a resume with a different async_depth, "
                "adaptive_staleness/sketch_dim, wire_codec/error_feedback "
                "ef_accum layout, or client count than the run that wrote "
                "the checkpoint)")
        out.append(jnp.asarray(new, dtype=old.dtype))
    return (jax.tree.unflatten(treedef, out), payload.get("step"),
            payload.get("meta"))
