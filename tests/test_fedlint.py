"""fedlint: every rule fires on its deliberately-broken fixture, stays
silent on the real engine's programs, and the parser extensions
(alias-config, constant sizes) read real compiled modules correctly.

Each fixture is the MINIMAL program exhibiting one bug class the rule
exists for — a closure-captured tensor, a dropped donation, an f32 upcast
on the bf16 wire, a surprise all-gather, a weak-type recompile — so a
rule that rots (stops firing) fails here before it silently green-lights
the sweep.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import lint_hlo_text, lint_program
from repro.analysis.hlo import parse_input_output_alias
from repro.analysis.lint import LINT_RULES
from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine, simulator
from repro.models.small import SMALL_MODELS, make_loss_fn

CLIENTS, N_PRIORITY = 12, 4


@pytest.fixture(scope="module")
def logreg():
    init_fn, apply_fn = SMALL_MODELS["synth_logreg"]
    loss_fn = make_loss_fn(apply_fn)
    fedn = make_synth_federation(seed=0, n_priority=N_PRIORITY,
                                 n_nonpriority=CLIENTS - N_PRIORITY,
                                 samples_per_client=16)
    return loss_fn, init_fn(jax.random.PRNGKey(0)), fedn


def _violations(report, rule):
    return [v for v in report.violations if v.rule == rule]


# ---------------------------------------------------------------- fixtures
# one deliberately-broken program per rule: the rule MUST fire


def test_no_large_literal_fires_on_captured_tensor():
    big = jnp.ones((600, 600), jnp.float32)         # 1.44 MB > 1 MiB
    rep = lint_program(lambda x: x + big.sum(), (jnp.ones((4,)),),
                       rules=["no-large-literal"], label="captured")
    vs = _violations(rep, "no-large-literal")
    assert vs, rep.summary()
    # both the jaxpr const and the constant-folded HLO literal are seen
    wheres = {v.detail["where"] for v in vs}
    assert any(w == "jaxpr const" for w in wheres)


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donation_honored_fires_on_dropped_alias():
    # returning the donated carry downcast to bf16 makes the output
    # buffer half the size: XLA silently drops the alias
    def step(state):
        return {"w": (state["w"] * 2).astype(jnp.bfloat16)}
    st = {"w": jnp.ones((512, 8), jnp.float32)}
    rep = lint_program(step, (st,), donate_argnums=(0,),
                       rules=["donation-honored"], label="dropped")
    vs = _violations(rep, "donation-honored")
    assert vs, rep.summary()
    assert vs[0].detail["path"] == "args[0]['w']"


def test_dtype_discipline_fires_on_f32_wire_upcast():
    fed = FedConfig(agg_dtype="bfloat16")
    m_total = 610

    def flatten(a, b):                       # flatten_stacked's shape, f32
        return jnp.concatenate([a.reshape(CLIENTS, -1),
                                b.reshape(CLIENTS, -1)], axis=1)
    a = jnp.ones((CLIENTS, 600), jnp.float32)
    b = jnp.ones((CLIENTS, 10), jnp.float32)
    rep = lint_program(flatten, (a, b), fed, meta={"m_total": m_total},
                       rules=["dtype-discipline"], label="upcast")
    assert _violations(rep, "dtype-discipline"), rep.summary()


def test_dtype_discipline_exempts_axis0_kernel_padding():
    # axis-0 M-wide concatenates are the sort kernel's row padding, not
    # the wire buffer — documented exemption
    fed = FedConfig(agg_dtype="bfloat16")

    def pad(a, b):
        return jnp.concatenate([a, b], axis=0)
    a = jnp.ones((CLIENTS, 610), jnp.float32)
    b = jnp.ones((4, 610), jnp.float32)
    rep = lint_program(pad, (a, b), fed, meta={"m_total": 610},
                       rules=["dtype-discipline"], label="padding")
    assert rep.ok, rep.summary()


_POD_HLO_WITH_GATHER = """HloModule round

ENTRY main (p0: f32[64,610]) -> f32[64,610] {
  p0 = f32[64,610]{1,0} parameter(0)
  ag = f32[256,610]{1,0} all-gather(p0), replica_groups={{0,1,2,3}}, dimensions={0}
  ar = f32[64,610]{1,0} all-reduce(ag), replica_groups={}, to_apply=add
  ROOT out = f32[64,610]{1,0} add(ar, p0)
}
"""


def test_collective_budget_fires_on_pod_all_gather():
    rep = lint_hlo_text(_POD_HLO_WITH_GATHER,
                        meta={"pod": True, "rounds": 1}, label="gather")
    vs = _violations(rep, "collective-budget")
    assert vs, rep.summary()
    assert "all-gather" in vs[0].message


_CROSS_POD_HLO = """HloModule round

ENTRY main (p0: f32[64,610]) -> f32[64,610] {
  p0 = f32[64,610]{1,0} parameter(0)
  tp = f32[64,610]{1,0} all-reduce(p0), replica_groups={{0,1},{2,3},{4,5},{6,7}}, to_apply=add
  ar = f32[64,610]{1,0} all-reduce(tp), replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=add
  xg = f32[512,610]{1,0} all-gather(ar), replica_groups={{0,4},{1,5},{2,6},{3,7}}, dimensions={0}
  sl = f32[64,610]{1,0} slice(xg), slice={[0:64], [0:610]}
  ROOT out = f32[64,610]{1,0} add(sl, p0)
}
"""


def test_collective_budget_classifies_cross_pod_by_replica_groups():
    # devices 0-3 are pod 0, 4-7 pod 1: the {0,4}-style groups straddle
    # the boundary (one cross-pod all-reduce = in budget; the cross-pod
    # all-gather fires); the {0,1}-style TP all-reduce is intra-pod and
    # never counts
    meta = {"pod": True, "rounds": 1, "devices": 8, "devices_per_pod": 4}
    rep = lint_hlo_text(_CROSS_POD_HLO, meta=meta, label="cross-pod")
    vs = _violations(rep, "collective-budget")
    assert len(vs) == 1, rep.summary()
    assert "all-gather" in vs[0].message
    assert vs[0].detail["cross_pod_n"]["all-reduce"] == 1

    # same module viewed as ONE pod of 8: nothing is cross-pod
    meta = {"pod": True, "rounds": 1, "devices": 8, "devices_per_pod": 8}
    assert lint_hlo_text(_CROSS_POD_HLO, meta=meta, label="one-pod").ok


def test_collective_budget_allows_gather_for_order_statistics():
    fed = FedConfig(aggregator="trimmed_mean")
    rep = lint_hlo_text(_POD_HLO_WITH_GATHER, fed,
                        meta={"pod": True, "rounds": 1}, label="trimmed")
    assert rep.ok, rep.summary()


def test_recompile_stability_fires_on_weak_type_leak():
    # python-scalar round_idx traces weak i32, device scalar traces
    # strong i32: jit's cache keys on weak_type, so these recompile
    # against each other every call
    rep = lint_program(lambda x, r: x * r, (jnp.ones((4,)), 3),
                       args2=(jnp.ones((4,)), jnp.int32(7)),
                       rules=["recompile-stability"], label="weak")
    assert _violations(rep, "recompile-stability"), rep.summary()


def test_recompile_stability_clean_on_value_only_change():
    rep = lint_program(lambda x, r: x * r,
                       (jnp.ones((4,)), jnp.int32(3)),
                       args2=(jnp.ones((4,)), jnp.int32(7)),
                       rules=["recompile-stability"], label="values")
    assert rep.ok, rep.summary()


# ------------------------------------------------------------ real programs


def test_chunk_program_clean_all_rules(logreg):
    loss_fn, params, fedn = logreg
    fed = FedConfig(num_clients=CLIENTS, num_priority=N_PRIORITY, rounds=4,
                    local_epochs=1, warmup_frac=0.0,
                    agg_dtype="bfloat16", aggregator="trimmed_mean")
    fn, args, donate, meta = simulator.capture_chunk_program(
        loss_fn, params, fed, fedn, n=2)
    args2 = (args[0], jax.random.PRNGKey(99), jnp.int32(7))
    rep = lint_program(fn, args, fed, args2=args2, donate_argnums=donate,
                       meta=meta, label="chunk")
    assert rep.ok, rep.summary()
    assert set(rep.checked) == set(LINT_RULES.names())
    assert not rep.skipped


def test_pooled_round_at_1e4_clients_no_large_literal(logreg):
    # PR 9 regression: the candidate-pool round at C=1e4 must compile
    # with NO federation-sized tensor baked into the program — the data
    # enters as (shape-only) arguments, so the trace and the optimized
    # HLO stay O(model), not O(population)
    loss_fn, params, fedn = logreg
    C, P = 10_000, 500
    fed = FedConfig(num_clients=C, num_priority=P, rounds=1, local_epochs=1,
                    warmup_frac=0.0, candidate_pool=2000)
    round_fn = engine.make_round_fn(loss_fn, fed)
    state = engine.init_state(params, fed, C)
    sds = jax.ShapeDtypeStruct
    data = {"x": sds((C,) + fedn.x.shape[1:], fedn.x.dtype),
            "y": sds((C,) + fedn.y.shape[1:], fedn.y.dtype)}
    rep = lint_program(
        round_fn,
        (state, data, sds((C,), jnp.bool_), sds((C,), jnp.float32),
         sds((2,), jnp.uint32), jnp.int32(0)),
        fed, rules=["no-large-literal"], label="pooled C=1e4")
    assert rep.ok, rep.summary()


def test_suppress_records_rule_as_skipped():
    big = jnp.ones((600, 600), jnp.float32)
    rep = lint_program(lambda x: x + big.sum(), (jnp.ones((4,)),),
                       rules=["no-large-literal"],
                       suppress=("no-large-literal",), label="suppressed")
    assert rep.ok
    assert rep.skipped["no-large-literal"] == "suppressed"


# ------------------------------------------------------------ parser pieces


def test_alias_parser_on_real_compiled_module():
    def step(state, x):
        return {"w": state["w"] + x.sum()}, x * 2
    st = {"w": jnp.ones((256, 4), jnp.float32)}
    x = jnp.ones((256, 4), jnp.float32)
    text = (jax.jit(step, donate_argnums=(0,), keep_unused=True)
            .lower(st, x).compile().as_text())
    entries = parse_input_output_alias(text)
    assert entries, "compiled donation produced no alias config"
    assert any(e["param_number"] == 0 for e in entries)
    for e in entries:
        assert isinstance(e["output_index"], tuple)
        assert e["kind"] in ("may-alias", "must-alias")


def test_alias_parser_handles_nested_braces():
    text = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
            "{1, 2}: (3, {1}, must-alias) }, entry_computation_layout=...")
    entries = parse_input_output_alias(text)
    assert len(entries) == 2
    assert entries[0] == {"output_index": (0,), "param_number": 0,
                          "param_index": (), "kind": "may-alias"}
    assert entries[1] == {"output_index": (1, 2), "param_number": 3,
                          "param_index": (1,), "kind": "must-alias"}


def test_alias_parser_empty_on_module_without_donation():
    text = jax.jit(lambda x: x * 2).lower(
        jnp.ones((8,))).compile().as_text()
    assert parse_input_output_alias(text) == []
