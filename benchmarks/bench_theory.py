"""Theorem-1 benchmark: theta_T/rho_T trade-off and bound tightness on the
exactly-solvable quadratic PFL testbed (core/theory.py)."""
from __future__ import annotations

import numpy as np

from repro.core.theory import (empirical_theta_rho, make_quadratic_pfl,
                               run_fedalign_gd as _run_fedalign_gd,
                               theorem1_bound, theorem1_constants)


def run(fast=True):
    q = make_quadratic_pfl(seed=3, n_priority=4, n_nonpriority=6, dim=8)
    L, mu = q.smoothness()
    E = 5
    gamma = max(8 * L / mu, E)
    lr_fn = lambda t: 2.0 / (mu * (t + gamma))
    T_rounds = 40 if fast else 200
    rows = []
    for eps in (0.0, 0.2, 0.5, 2.0, 1e9):
        w_T, th, rh = _run_fedalign_gd(q, T_rounds, E, eps, lr_fn)
        err = q.F(w_T) - q.F(q.w_star())
        theta_T, rho_un = empirical_theta_rho(th, rh, gamma, E)
        G = np.sqrt(max(np.linalg.norm(q.A[k] @ (np.zeros(8) - q.c[k])) ** 2
                        for k in range(len(q.d))) * 4 + 1.0)
        C1, C2, _ = theorem1_constants(L, mu, 0.0, G, E,
                                       np.linalg.norm(q.w_star()) ** 2)
        bound = theorem1_bound(T_rounds * E, C1=C1, C2=C2, gamma=gamma,
                               Gamma=q.gamma(), theta_T=theta_T,
                               rho_T=2 * L / mu * rho_un)
        rows.append({"eps": eps, "error": float(err), "bound": float(bound),
                     "theta_T": round(theta_T, 4),
                     "rho_unscaled": round(rho_un, 6),
                     "bound_holds": bool(err <= bound)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
