"""FedALIGN renormalized gated aggregation (paper eq. (15)):

    w <- sum_k p_k I_k w_k / sum_k p_k I_k

applied leaf-wise over client-stacked parameter pytrees. The inner reduce
is the ``fedagg`` Pallas kernel on TPU (kernels/fedagg.py); the jnp path
compiles to one fused contraction per leaf, which under pjit with the
client axis sharded over (pod, data) lowers to exactly one all-reduce —
FedALIGN's entire server-side communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def aggregate_clients(client_params, weights, gates, *, use_pallas=False):
    """client_params: pytree with leading client axis C on every leaf."""
    def agg_leaf(leaf):
        C = leaf.shape[0]
        flat = leaf.reshape(C, -1)
        out = kops.fedagg(flat, weights, gates, use_pallas=use_pallas)
        return out.reshape(leaf.shape[1:])
    return jax.tree.map(agg_leaf, client_params)


def aggregate_updates(global_params, client_params, weights, gates, *,
                      use_pallas=False, server_lr=1.0):
    """Delta-form aggregation: w <- w + server_lr * agg(w_k - w).

    Equivalent to aggregate_clients at server_lr=1 but numerically nicer at
    scale and the natural hook for server-side optimizers (beyond-paper)."""
    deltas = jax.tree.map(lambda ck, g: ck - g[None], client_params, global_params)
    agg = aggregate_clients(deltas, weights, gates, use_pallas=use_pallas)
    return jax.tree.map(lambda g, d: (g + server_lr * d.astype(g.dtype)),
                        global_params, agg)
