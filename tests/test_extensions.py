"""Supplementary-material extensions: stragglers (App. A.4) and the
beyond-paper server-momentum optimizer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.round import init_state, make_round_fn
from repro.data.synth import make_synth_federation
from repro.fl.simulator import run_federation
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=3, n_priority=4, n_nonpriority=4,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)


def test_straggler_cadence():
    """Straggler k participates only when round % (2 + k%period) == 0;
    priority clients always do."""
    fed = FedConfig(rounds=20, warmup_frac=0.0, epsilon=1e9, local_epochs=1,
                    straggler_period=3, align_stat="loss")
    fn = jax.jit(make_round_fn(LOSS, fed))
    state = init_state(INIT(jax.random.PRNGKey(0)), fed, int(PM.shape[0]))
    seen = []
    for r in range(6):
        _, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(r), jnp.int32(r))
        seen.append(np.asarray(stats["gates"]))
    seen = np.stack(seen)
    assert np.all(seen[:, :4] == 1.0)                  # priority every round
    # non-priority client 4 (cadence 2 + 4%3 = 3): rounds 0,3 only
    assert seen[0, 4] == 1.0 and seen[3, 4] == 1.0
    assert seen[1, 4] == 0.0 and seen[2, 4] == 0.0
    # client 6 (cadence 2): even rounds
    assert seen[0, 6] == 1.0 and seen[2, 6] == 1.0 and seen[1, 6] == 0.0


def test_straggler_rounds_still_train():
    fed = FedConfig(num_clients=8, num_priority=4, rounds=15, local_epochs=3,
                    epsilon=0.2, lr=0.1, warmup_frac=0.1, straggler_period=4)
    h = run_federation(LOSS, INIT(jax.random.PRNGKey(0)), fed, FEDN,
                       eval_every=5)
    assert h.test_acc[-1] > 0.4


def test_server_momentum_changes_trajectory_and_trains():
    base = dict(num_clients=8, num_priority=4, rounds=12, local_epochs=3,
                epsilon=0.2, lr=0.1, warmup_frac=0.0)
    h0 = run_federation(LOSS, INIT(jax.random.PRNGKey(0)),
                        FedConfig(**base), FEDN, eval_every=3)
    h1 = run_federation(LOSS, INIT(jax.random.PRNGKey(0)),
                        FedConfig(**base, server_opt="momentum",
                                  server_momentum=0.5), FEDN, eval_every=3)
    assert h1.test_acc[-1] > 0.4
    # trajectories must differ (momentum is actually applied)
    assert any(abs(a - b) > 1e-6 for a, b in zip(h0.test_loss, h1.test_loss))


def test_bf16_delta_aggregation_close_to_f32():
    """agg_dtype=bfloat16 quantizes client deltas on the wire; the result
    must stay close to exact f32 aggregation after one round."""
    from repro.fl import engine, sharded
    from tests.test_sharded import _batch, MODEL

    fed32 = FedConfig(local_epochs=2, epsilon=1e9, lr=0.05)
    fed16 = fed32.replace(agg_dtype="bfloat16")
    params = MODEL.init(jax.random.PRNGKey(0))
    batch = _batch()
    s32, _ = jax.jit(sharded.make_spatial_round(MODEL, fed32, 4))(
        engine.init_state(params, fed32, 4), batch)
    s16, _ = jax.jit(sharded.make_spatial_round(MODEL, fed16, 4))(
        engine.init_state(params, fed16, 4), batch)
    num = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(s32.params), jax.tree.leaves(s16.params)))
    den = sum(float(jnp.sum(jnp.abs(a - g))) for a, g in
              zip(jax.tree.leaves(s32.params), jax.tree.leaves(params)))
    # quantization error well below the actual update magnitude
    assert num < 0.05 * max(den, 1e-9), (num, den)
