"""Data-pipeline invariants: SYNTH generator, uniclass shards, token streams."""
import numpy as np

from repro.data.shards import make_benchmark_federation
from repro.data.synth import _noise_level, make_synth_federation
from repro.data.tokens import make_token_federation


def test_synth_shapes_and_masks():
    f = make_synth_federation(seed=0, n_priority=3, n_nonpriority=5,
                              samples_per_client=50)
    assert f.x.shape == (8, 50, 60)
    assert f.y.shape == (8, 50)
    assert f.priority_mask.sum() == 3
    assert np.isclose(f.weights[f.priority_mask].sum(), 1.0)
    assert f.test_x.shape[0] > 0
    assert set(np.unique(f.y)).issubset(set(range(10)))


def test_synth_priority_data_is_learnable_structure():
    """Priority labels must be the argmax of their own linear model —
    re-deriving them from a fitted model should beat chance easily."""
    f = make_synth_federation(seed=1, n_priority=2, n_nonpriority=2,
                              samples_per_client=400)
    x, y = f.x[0], f.y[0]
    # closed-form least squares onto one-hot labels
    Y = np.eye(10)[y]
    Wls, *_ = np.linalg.lstsq(x, Y, rcond=None)
    acc = (np.argmax(x @ Wls, 1) == y).mean()
    assert acc > 0.5


def test_noise_levels_monotone_in_rank_and_skew():
    for skew in (0.5, 1.5, 5.0):
        levels = [_noise_level(r, 1.0, skew) for r in np.linspace(0, 1, 11)]
        assert all(b >= a - 1e-12 for a, b in zip(levels, levels[1:]))
    # higher skew -> more clients near max noise (paper's reading)
    mid = 0.5
    assert _noise_level(mid, 1.0, 5.0) > _noise_level(mid, 1.0, 0.5)


def test_nonpriority_noise_increases_with_rank():
    f = make_synth_federation(seed=2, n_priority=2, n_nonpriority=6,
                              samples_per_client=300,
                              label_noise_factor=1.0, random_data_factor=0.0)
    # later non-priority clients have more flipped labels => their local
    # linear fit should be worse
    accs = []
    for c in range(2, 8):
        x, y = f.x[c], f.y[c]
        Y = np.eye(10)[y]
        Wls, *_ = np.linalg.lstsq(x, Y, rcond=None)
        accs.append((np.argmax(x @ Wls, 1) == y).mean())
    assert accs[0] > accs[-1]


def test_uniclass_shards():
    f = make_benchmark_federation("fmnist", seed=0, n_priority=2)
    assert f.x.shape[0] == 60
    # each client has at most 2 shards => at most 2 distinct classes
    for c in range(60):
        assert len(np.unique(f.y[c])) <= 2
    assert f.x.shape[1] == 1000      # 2 shards x 500


def test_emnist_spec():
    f = make_benchmark_federation("emnist", seed=0, n_priority=2)
    assert f.x.shape[2:] == (784,)
    for c in range(f.x.shape[0]):
        assert len(np.unique(f.y[c])) <= 24


def test_cifar_spec():
    f = make_benchmark_federation("cifar", seed=0, n_priority=2)
    assert f.x.shape[2:] == (32, 32, 3)


def test_token_federation_alignment_levels():
    d = make_token_federation(seed=0, vocab=128, n_clients=6, n_priority=2,
                              seq_len=32)
    assert d["tokens"].shape[0] == 6
    assert d["misalignment"][0] == 0.0
    assert d["misalignment"][-1] >= d["misalignment"][2]
    assert d["tokens"].max() < 128
