"""Hand-built functional optimizers (no optax in this container).

``Optimizer`` mirrors the optax GradientTransformation triple but folds the
parameter update in: ``update(grads, state, params, lr)`` returns
(new_params, new_state). lr is passed per-call so schedules stay outside.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple]      # (grads, state, params, lr) -> (params, state)
    name: str = "opt"


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    """Plain SGD (paper's local solver) with optional momentum."""
    if momentum == 0.0:
        def init(params):
            return ()

        def update(grads, state, params, lr):
            new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
            return new, state
    else:
        def init(params):
            return {"m": jax.tree.map(jnp.zeros_like, params)}

        def update(grads, state, params, lr):
            m = jax.tree.map(lambda mi, g: momentum * mi + g.astype(mi.dtype),
                             state["m"], grads)
            if nesterov:
                step = jax.tree.map(lambda g, mi: g.astype(mi.dtype) + momentum * mi,
                                    grads, m)
            else:
                step = m
            new = jax.tree.map(lambda p, s: p - lr * s.astype(p.dtype), params, step)
            return new, {"m": m}
    return Optimizer(init, update, "sgd")


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, mi, vi: (p - lr * (mi / bc1) /
                               (jnp.sqrt(vi / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}
    return Optimizer(init, update, "adam")


def yogi(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-3) -> Optimizer:
    """Yogi (Zaheer et al., NeurIPS 2018): Adam with an *additive* second
    moment, v <- v - (1-b2) sign(v - g^2) g^2, so v can shrink when recent
    gradients are small. Applied to the aggregated federation delta this is
    the FedYogi server optimizer of Reddi et al. (arXiv:2003.00295); eps
    defaults to that paper's 1e-3."""
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda vi, g: vi - (1 - b2) * jnp.sign(vi - jnp.square(g.astype(jnp.float32)))
            * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, mi, vi: (p - lr * (mi / bc1) /
                               (jnp.sqrt(vi / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}
    return Optimizer(init, update, "yogi")


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    base = adam(b1, b2, eps)

    def update(grads, state, params, lr):
        decayed = jax.tree.map(lambda p: p * (1 - lr * weight_decay), params)
        return base.update(grads, state, decayed, lr)
    return Optimizer(base.init, update, "adamw")
