"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam, adamw, sgd
from repro.optim.schedules import (cosine_schedule,
                                   paper_decay_schedule)


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("opt", [sgd(), sgd(momentum=0.9),
                                 sgd(momentum=0.9, nesterov=True),
                                 adam(), adamw(weight_decay=0.0)])
def test_optimizers_converge_on_quadratic(opt):
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((3,))}
    state = opt.init(params)
    lr = 0.1 if opt.name != "adam" else 0.3
    for _ in range(200):
        g = jax.grad(quad_loss)(params)
        params, state = opt.update(g, state, params, lr)
    assert float(quad_loss(params)) < 1e-3, opt.name


def test_paper_decay_schedule():
    """eta_t = 2/(mu (t+gamma)) — decays as Theorem 1 requires, and
    eta_t <= 2 eta_{t+E} (the condition used in Lemma A.4)."""
    mu, gamma, E = 0.5, 16.0, 5
    sched = paper_decay_schedule(mu, gamma)
    for t in range(0, 100, 7):
        assert float(sched(t)) > float(sched(t + 1))
        assert float(sched(t)) <= 2 * float(sched(t + E)) + 1e-9
    assert np.isclose(float(sched(0)), 2 / (mu * gamma))


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 100, warmup=10)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-5)


def test_momentum_matches_manual():
    opt = sgd(momentum=0.5)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    g1 = {"w": jnp.array([2.0])}
    params, state = opt.update(g1, state, params, 0.1)
    assert np.isclose(float(params["w"][0]), 1.0 - 0.1 * 2.0)
    g2 = {"w": jnp.array([1.0])}
    params, state = opt.update(g2, state, params, 0.1)
    # m2 = 0.5*2 + 1 = 2 -> w -= 0.1*2
    assert np.isclose(float(params["w"][0]), 0.8 - 0.2)
