"""Theorem-1 machinery: constants, bound evaluation, and an exactly-solvable
quadratic PFL testbed used to validate the convergence analysis.

Quadratic testbed: F_k(w) = 0.5 (w - c_k)^T A_k (w - c_k) + d_k with
mu I <= A_k <= L I. Then
    F(w)   = sum_{k in P} p_k F_k(w)          (priority objective)
    w*     = (sum p_k A_k)^{-1} sum p_k A_k c_k
    F_k^*  = d_k,   Gamma  = F(w*) - sum p_k d_k,   Gamma_k = F_k(w*) - d_k
— every quantity in the theorem is computable in closed form.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class QuadraticPFL:
    A: np.ndarray            # [C, m, m]
    c: np.ndarray            # [C, m]
    d: np.ndarray            # [C]
    priority_mask: np.ndarray
    weights: np.ndarray      # p_k (priority mass sums to 1)

    # ---- closed-form quantities -------------------------------------------
    def w_star(self):
        P = self.priority_mask
        Aw = np.einsum("k,kij->ij", self.weights * P, self.A)
        bw = np.einsum("k,kij,kj->i", self.weights * P, self.A, self.c)
        return np.linalg.solve(Aw, bw)

    def F_k(self, w, k):
        r = w - self.c[k]
        return 0.5 * r @ self.A[k] @ r + self.d[k]

    def F(self, w):
        P = self.priority_mask
        return sum(self.weights[k] * self.F_k(w, k) for k in range(len(self.d)) if P[k])

    def gamma(self):
        ws = self.w_star()
        P = self.priority_mask
        return self.F(ws) - sum(self.weights[k] * self.d[k]
                                for k in range(len(self.d)) if P[k])

    def gamma_k(self, k):
        return self.F_k(self.w_star(), k) - self.d[k]

    def smoothness(self):
        L = max(np.linalg.eigvalsh(a).max() for a in self.A)
        mu = min(np.linalg.eigvalsh(a).min() for a in self.A)
        return float(L), float(mu)


def make_quadratic_pfl(seed=0, n_priority=4, n_nonpriority=8, dim=10,
                       mu=0.5, L=4.0, priority_spread=1.0,
                       nonpriority_align=None):
    """nonpriority_align: [n_nonpriority] in [0,1]; 1 = centered at w*
    (perfectly aligned), 0 = far away (misaligned)."""
    rng = np.random.default_rng(seed)
    C = n_priority + n_nonpriority
    if nonpriority_align is None:
        nonpriority_align = np.linspace(1.0, 0.0, n_nonpriority)

    def rand_spd():
        q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
        eig = rng.uniform(mu, L, dim)
        return q @ np.diag(eig) @ q.T

    A = np.stack([rand_spd() for _ in range(C)])
    c = np.zeros((C, dim))
    c[:n_priority] = rng.normal(0, priority_spread, (n_priority, dim))

    pm = np.zeros(C, bool)
    pm[:n_priority] = True
    w = np.full(C, 1.0 / n_priority)
    quad = QuadraticPFL(A, c, rng.uniform(0, 0.1, C), pm, w)
    ws = quad.w_star()
    for i, a in enumerate(nonpriority_align):
        k = n_priority + i
        offset = rng.normal(0, 1, dim)
        offset /= np.linalg.norm(offset)
        c[k] = ws + (1.0 - a) * 4.0 * offset       # aligned => minimum near w*
    return quad


def run_fedalign_gd(q: QuadraticPFL, T_rounds, E, eps, lr_fn):
    """Full-batch deterministic FedALIGN on the quadratic testbed.
    Returns (w_T, theta_round_history, rho_core_history)."""
    C, m = q.c.shape
    w = np.zeros(m)
    theta_hist, rho_hist = [], []
    t = 0
    for r in range(T_rounds):
        losses = np.array([q.F_k(w, k) for k in range(C)])
        gl = q.F(w)
        gates = np.where(q.priority_mask, 1.0,
                         (np.abs(losses - gl) < eps).astype(float))
        locals_ = []
        for k in range(C):
            wk = w.copy()
            for e in range(E):
                wk = wk - lr_fn(t + e) * (q.A[k] @ (wk - q.c[k]))
            locals_.append(wk)
        t += E
        wg = q.weights * gates
        w = np.einsum("k,ki->i", wg, np.stack(locals_)) / wg.sum()
        inc = np.sum(q.weights * gates * (~q.priority_mask))
        theta_hist.append(1.0 / (1.0 + inc))
        rho_hist.append(np.sum([q.weights[k] * gates[k] * q.gamma_k(k)
                                for k in range(C) if not q.priority_mask[k]])
                        / (1.0 + inc))
    return w, theta_hist, rho_hist


# ------------------------------------------------------------- Theorem 1 bound
def theorem1_constants(L, mu, sigma, G, E, w0_dist_sq):
    C1 = 2 * L / mu**2 * (sigma**2 + 8 * (E - 1) ** 2 * G**2) + 4 * L**2 / mu * w0_dist_sq
    C2 = 12 * L**2 / mu**2
    gamma = max(8 * L / mu, E)
    return C1, C2, gamma


def theorem1_bound(T, *, C1, C2, gamma, Gamma, theta_T, rho_T):
    """E[F(w_T)] - F* <= (C1 + C2 theta_T Gamma)/(T + gamma) + rho_T."""
    return (C1 + C2 * theta_T * Gamma) / (T + gamma) + rho_T


def empirical_theta_rho(theta_rounds, included_stats, gamma, E):
    """Aggregate per-round stats into theta_T (eq. 7) and the rho_T numerator
    structure (eq. 8). theta_rounds: list of per-round 1/(1+sum p_k I_k).
    included_stats: list of per-round sum(p_k I_k Gamma_k)/(1+sum p_k I_k)."""
    theta_rounds = np.asarray(theta_rounds, np.float64)
    T = len(theta_rounds) * E
    # each communication round covers E local iterations with the same gate
    theta_T = float(np.sum(np.repeat(theta_rounds, E)) / (T + gamma - 2))
    rho_core = np.asarray(included_stats, np.float64)
    rho_T_unscaled = float(np.sum(np.repeat(rho_core, E)) / (T + gamma - 2))
    return theta_T, rho_T_unscaled   # multiply by 2L/mu for the bound's rho_T
