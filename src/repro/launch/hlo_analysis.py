"""Scan-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts every ``while`` body
ONCE, so any program built from ``lax.scan`` (our layer stacks, local-epoch
loops, loss chunking) is undercounted by the trip counts. This module
re-derives roofline quantities directly from the optimized HLO text:

  * builds the computation call graph (entry -> fusions / calls / while
    bodies) and multiplies while bodies by ``known_trip_count``,
  * counts dot/convolution FLOPs exactly from operand shapes (two-pass
    name->shape symbol table per computation: CPU HLO references operands
    by name only),
  * estimates HBM traffic as 2x result bytes of non-aliasing top-level ops
    (each tensor written once, read ~once; fusion internals stay on-chip),
  * attributes collective bytes at true multiplicity.

All quantities are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import gzip
import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM bytes of their own
_ALIAS_KINDS = {"tuple", "get-tuple-element", "parameter", "constant",
                "bitcast", "after-all", "iota", "broadcast", "reshape",
                "while", "conditional", "call"}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_OP = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_KIND = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")


def _dims_of(blob: str):
    m = _SHAPE.search(blob)
    return [int(d) for d in m.group(2).split(",") if d] if m else None


def _split_operands(blob: str) -> list[str]:
    """Split an operand list at top-level commas only. Operand entries may
    carry inline shapes (``f32[32,48]{1,0} %arg``) whose dims/layout contain
    commas, so a naive ``split(",")`` truncates them."""
    parts, cur, depth = [], [], 0
    for ch in blob:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _operand_dims(operand: str, shapes: dict):
    """Dims of one operand: inline shape if present, else symbol table."""
    if "[" in operand:
        return _dims_of(operand)
    name = operand.split(" ")[-1].lstrip("%")
    return shapes[name][1] if name in shapes else None


def _result_bytes(blob: str) -> int:
    """Bytes of the result shape(s) — the text before the op kind."""
    total = 0
    for dt, dims in _SHAPE.findall(blob):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Comp:
    name: str
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: dict = field(default_factory=dict)
    transcendental: float = 0.0
    calls: list = field(default_factory=list)     # (callee, multiplier)


def _split_result_op(rhs: str):
    """rhs = '<result shapes> kind(<operands>), attrs' -> (result_blob, kind, rest)."""
    m = _KIND.match(rhs)
    if not m:
        return rhs, "", ""
    kind = m.group(1)
    idx = rhs.find(kind + "(")
    return rhs[:idx], kind, rhs[idx:]


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Comp] = {}
    entry = None
    # --- split into computation blocks --------------------------------------
    blocks: list[tuple[str, bool, list[str]]] = []
    cur_name, cur_lines, cur_entry = None, [], False
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            if cur_name is not None:
                blocks.append((cur_name, cur_entry, cur_lines))
            cur_name, cur_lines = hdr.group(1), []
            cur_entry = line.startswith("ENTRY")
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        blocks.append((cur_name, cur_entry, cur_lines))

    for name, is_entry, lines in blocks:
        comp = Comp(name)
        comps[name] = comp
        if is_entry:
            entry = name
        shapes: dict[str, list] = {}
        parsed = []
        for line in lines:
            op = _OP.match(line)
            if not op:
                continue
            oname, rhs = op.group(1), op.group(2)
            result_blob, kind, rest = _split_result_op(rhs)
            dims = _dims_of(result_blob)
            if dims is not None:
                shapes[oname] = (result_blob, dims)
            parsed.append((oname, rhs, result_blob, kind, rest))

        for oname, rhs, result_blob, kind, rest in parsed:
            if kind == "dot":
                res_dims = _dims_of(result_blob) or []
                opm = _OPERANDS.search(rest)
                lhs_dims = None
                if opm:
                    operands = _split_operands(opm.group(1))
                    if operands:
                        lhs_dims = _operand_dims(operands[0], shapes)
                cm = _LHS_CONTRACT.search(rest)
                contract = [int(i) for i in cm.group(1).split(",") if i] if cm else []
                if lhs_dims is not None:
                    k = 1
                    for i in contract:
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
                    out = 1
                    for d in res_dims:
                        out *= d
                    comp.dot_flops += 2.0 * out * k
            elif kind == "convolution":
                res_dims = _dims_of(result_blob) or []
                opm = _OPERANDS.search(rest)
                kern_dims = None
                if opm:
                    parts = _split_operands(opm.group(1))
                    if len(parts) >= 2:
                        kern_dims = _operand_dims(parts[1], shapes)
                if kern_dims and res_dims:
                    out = 1
                    for d in res_dims:
                        out *= d
                    kf = 1
                    for d in kern_dims:
                        kf *= d
                    comp.dot_flops += 2.0 * out * max(kf // max(res_dims[-1], 1), 1)
            elif kind in ("exponential", "tanh", "log", "rsqrt", "power", "logistic"):
                dims = _dims_of(result_blob)
                if dims:
                    n = 1
                    for d in dims:
                        n *= d
                    comp.transcendental += n

            if kind in COLLECTIVES:
                comp.coll[kind] = comp.coll.get(kind, 0) + _result_bytes(result_blob)

            if kind not in _ALIAS_KINDS:
                comp.bytes_accessed += 2.0 * _result_bytes(result_blob)

            called = _CALLED.search(rest)
            if called:
                mult = 1.0
                if kind == "while":
                    tm = _TRIP.search(rest)
                    mult = float(tm.group(1)) if tm else 1.0
                comp.calls.append((called.group(1), mult))
                condm = _COND.search(rest)
                if condm:
                    comp.calls.append((condm.group(1), 1.0))
    return comps, entry


def aggregate(comps: dict, entry: str) -> dict:
    memo: dict[str, dict] = {}

    def visit(name: str) -> dict:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "transc": 0.0}
        on_chip = ("fused" in name) or name.startswith("region")
        total = {"flops": c.dot_flops,
                 "bytes": 0.0 if on_chip else c.bytes_accessed,
                 "coll": dict(c.coll), "transc": c.transcendental}
        memo[name] = total      # (cycles impossible in HLO)
        for callee, mult in c.calls:
            sub = visit(callee)
            total["flops"] += mult * sub["flops"]
            total["transc"] += mult * sub["transc"]
            total["bytes"] += mult * sub["bytes"]
            for k, v in sub["coll"].items():
                total["coll"][k] = total["coll"].get(k, 0) + mult * v
        return total

    return visit(entry)


def analyze_text(text: str) -> dict:
    comps, entry = parse_hlo(text)
    agg = aggregate(comps, entry)
    agg["coll_total"] = float(sum(agg["coll"].values()))
    return agg


def analyze_file(path: str) -> dict:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze_text(f.read())
