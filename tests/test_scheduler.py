"""Batched-serving scheduler: outputs must equal per-request generate()."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.launch.serve import generate
from repro.models import get_model
from repro.serving import BatchScheduler, Request

CFG = get_smoke("qwen1_5_0_5b").replace(remat=False)
MODEL = get_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))


def _ref_generate(prompt, max_new):
    out = generate(MODEL, PARAMS, jnp.asarray(prompt)[None], max_new)
    return np.asarray(out[0, len(prompt):])


def test_scheduler_matches_sequential_generate():
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 9, 7)]
    sched = BatchScheduler(MODEL, PARAMS, batch_slots=2, max_len=32)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = sched.run()
    assert len(done) == len(prompts)
    by_rid = {r.rid: r for r in done}
    for i, p in enumerate(prompts):
        want = _ref_generate(p, 6)
        got = np.asarray(by_rid[i].out_tokens)
        np.testing.assert_array_equal(got, want[:len(got)])
        assert len(got) == 6


def test_scheduler_eos_stops_early():
    rng = np.random.default_rng(1)
    p = rng.integers(0, CFG.vocab_size, size=6).astype(np.int32)
    ref = _ref_generate(p, 12)
    eos = int(ref[2])                  # force stop at the 3rd generated token
    sched = BatchScheduler(MODEL, PARAMS, batch_slots=1, max_len=32, eos_id=eos)
    sched.submit(Request(rid=0, prompt=p, max_new_tokens=12))
    done = sched.run()
    assert done[0].out_tokens[-1] == eos
    assert len(done[0].out_tokens) <= 3


def test_scheduler_multiple_waves():
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, CFG.vocab_size, size=4)
                    .astype(np.int32), max_new_tokens=3) for i in range(5)]
    sched = BatchScheduler(MODEL, PARAMS, batch_slots=2, max_len=16)
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out_tokens) == 3 for r in done)
