"""qwen1.5-0.5b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B]

24L d_model=1024 16H d_ff=2816 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=512, vocab_size=512, param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
