"""Batched serving example: prefill a batch of prompts, decode with KV
caches (ring-buffer sliding window optional).

    PYTHONPATH=src python examples/serve_llm.py --arch minicpm3-4b --gen 24
"""
import argparse
import time

import jax

from repro.configs import get_config, get_smoke
from repro.launch.serve import generate
from repro.models import get_model
from repro.utils import param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window size (0 = full attention)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name} ({param_count(params):,} params, "
          f"window={args.window or 'full'})")

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    toks = generate(model, params, prompt, args.gen)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({tps:.1f} tok/s incl. compile)")
    print("sample continuation:", toks[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
