"""Scan-aware HLO analyzer: trip-count multiplication must hold on real
compiled programs (the roofline's correctness depends on it)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_text


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def _scan_matmul(n):
    def fn(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y
    return fn


def test_scan_flops_scale_with_trip_count():
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    f4 = analyze_text(_compiled_text(_scan_matmul(4), x, w))
    f8 = analyze_text(_compiled_text(_scan_matmul(8), x, w))
    assert f4["flops"] > 0
    ratio = f8["flops"] / f4["flops"]
    assert 1.7 < ratio < 2.3, ratio        # ~2x, not ~1x (XLA's undercount)


def test_dot_flops_exact_single_matmul():
    a = jnp.ones((32, 48))
    b = jnp.ones((48, 16))
    agg = analyze_text(_compiled_text(lambda a, b: a @ b, a, b))
    want = 2 * 32 * 48 * 16
    assert agg["flops"] == pytest.approx(want, rel=0.01)


def test_nested_scan_multiplies():
    def fn(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jnp.ones((16, 16))
    w = jnp.ones((16, 16))
    agg = analyze_text(_compiled_text(fn, x, w))
    want = 2 * 16 * 16 * 16 * 15          # 5 x 3 matmuls
    assert agg["flops"] == pytest.approx(want, rel=0.05)


def test_bytes_and_transcendental_nonzero():
    x = jnp.ones((128, 128))
    agg = analyze_text(_compiled_text(lambda x: jnp.tanh(x) @ x, x))
    assert agg["bytes"] > 0
    assert agg["transc"] >= 128 * 128
