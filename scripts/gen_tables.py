"""Generate EXPERIMENTS.md markdown tables from dry-run + roofline artifacts."""
import glob
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import analyze_record, fmt_s  # noqa: E402


def dryrun_table(multi_pod):
    rows = []
    for path in sorted(glob.glob("results/dryrun/*.json")):
        if "__opt" in path or "__rebase" in path:
            continue
        r = json.load(open(path))
        if r.get("multi_pod") != multi_pod:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — |")
            continue
        mem = (r.get("memory") or {})
        peak = mem.get("peak_memory_in_bytes", 0) / 1e9
        coll = sum(r.get("collective_bytes_per_device", {}).values()) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok ({r['compile_s']}s) "
            f"| {peak:.2f} | {coll:.2f} | {r['meta'].get('mode')} |")
    hdr = ("| arch | shape | lower+compile | peak GB/dev | HLO coll GB/dev (uncorrected) | mode |\n"
           "|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table():
    rows = []
    for path in sorted(glob.glob("results/dryrun/*.json")):
        if "__opt" in path or "__rebase" in path:
            continue
        rec = json.load(open(path))
        if rec.get("multi_pod"):
            continue
        r = analyze_record(path)
        if r is None:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {ur} | {str(r['fits_hbm'])} |")
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful (6ND/HLO) | fits 16GB |\n|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Single pod (16x16 = 256 chips)\n")
        print(dryrun_table(False))
        print("\n### Multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(True))
    if which in ("all", "roofline"):
        print("\n### Roofline (single pod)\n")
        print(roofline_table())
