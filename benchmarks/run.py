"""Benchmark entrypoint — one suite per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV rows per the repo
contract; full row dumps land in results/bench/.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

SUITES = [
    ("fig1_benchmark_suite", "benchmarks.bench_benchmark_suite"),
    ("fig2_synth_noise", "benchmarks.bench_synth_noise"),
    ("fig3_local_vs_global", "benchmarks.bench_local_vs_global"),
    ("fig4_fedprox", "benchmarks.bench_fedprox"),
    ("fig5_partial_participation", "benchmarks.bench_partial"),
    ("fig6_sweeps", "benchmarks.bench_sweeps"),
    ("thm1_theory", "benchmarks.bench_theory"),
    ("ablations", "benchmarks.bench_ablations"),
    ("kernels", "benchmarks.bench_kernels"),
    ("round_pipeline", "benchmarks.bench_round"),
    # bench_round.py --quick: seconds-long smoke (one cohort rate + the
    # depth-0 async parity row) — opt-in, for local use via
    # `python -m benchmarks.run --only round_pipeline_quick`
    ("round_pipeline_quick", "benchmarks.bench_round:run_quick"),
    ("roofline_single_pod", "benchmarks.roofline"),
]

# suites that only run when --only names them (local smoke entry points;
# a full pass would just duplicate their parent suite's coverage)
OPT_IN_SUITES = {"round_pipeline_quick"}


def derived_summary(name: str, rows) -> str:
    """One derived scalar per suite for the CSV line."""
    try:
        if name.startswith(("fig1", "fig2", "fig4", "fig5", "fig6")):
            fa = [r["final_acc"] for r in rows if r["selection"] == "fedalign"]
            base = [r["final_acc"] for r in rows if r["selection"] != "fedalign"]
            return (
                f"fedalign_mean_acc={sum(fa) / len(fa):.4f};"
                f"baseline_mean_acc={sum(base) / len(base):.4f}"
            )
        if name.startswith("fig3"):
            wins = sum(r["fedalign_beats_local"] for r in rows)
            return f"fedalign_beats_local={wins}/{len(rows)}"
        if name.startswith("thm1"):
            holds = sum(r["bound_holds"] for r in rows)
            return f"bound_holds={holds}/{len(rows)}"
        if name == "ablations":
            accs = {f"{r['ablation']}/{r['setting']}": r["final_acc"] for r in rows}
            return ";".join(f"{k}={v}" for k, v in accs.items())
        if name == "kernels":
            worst = max(r["max_err_vs_oracle"] for r in rows)
            return f"max_oracle_err={worst:.2e}"
        if name.startswith("round_pipeline"):
            by_path = {r["path"]: r for r in rows}
            best = max(r["speedup_vs_dense"] for r in rows if r["path"] == "cohort")
            ov = by_path.get("state_threading_overhead", {}).get("overhead_frac")
            adam = by_path.get("server_opt:adam", {}).get("slowdown_vs_sgd")
            asy = None
            for r in rows:
                if r["path"].startswith(("async:fifo:", "async:ready:")) and r.get("async_depth"):
                    asy = r["async_speedup_vs_sync"]
                    break
            return (
                f"best_cohort_speedup={best:.2f}x;"
                f"state_overhead={ov};adam_slowdown={adam};"
                f"async_depth_speedup={asy}"
            )
        if name.startswith("roofline"):
            ok = [r for r in rows if r.get("status") == "ok"]
            if not ok:
                return "no_dryrun_artifacts(run repro.launch.dryrun first)"
            dom: dict = {}
            for r in ok:
                dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
            fits = sum(r["fits_hbm"] for r in ok)
            return f"combos={len(ok)};fits_hbm={fits};dominant={dom}"
    except Exception as e:  # noqa: BLE001
        return f"derived_error={type(e).__name__}"
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    os.makedirs("results/bench", exist_ok=True)
    print("name,us_per_call,derived")
    failures = []
    for name, modspec in SUITES:
        if args.only and args.only not in name:
            continue
        # opt-in suites run only when --only names them EXACTLY — the
        # substring filter alone would drag round_pipeline_quick into
        # every `--only round_pipeline` run
        if name in OPT_IN_SUITES and args.only != name:
            continue
        print(f"# running {name} ...", file=sys.stderr, flush=True)
        modname, _, attr = modspec.partition(":")
        mod = importlib.import_module(modname)
        run_fn = getattr(mod, attr or "run")
        t0 = time.perf_counter()
        try:
            rows = run_fn(fast=not args.full)
            status = ""
            if not rows:
                # a suite that silently produces NOTHING is as broken as a
                # raising one — its output file would be an empty artifact
                status = "ERROR:EmptyOutput:suite returned no rows"
                failures.append(name)
        except Exception as e:  # noqa: BLE001
            # a raising suite FAILS the run (nonzero exit below) — the
            # remaining suites still execute so one CI pass reports every
            # breakage, but nothing silently "continues past" an error
            rows, status = [], f"ERROR:{type(e).__name__}:{e}"
            failures.append(name)
            traceback.print_exc(file=sys.stderr)
        us = (time.perf_counter() - t0) * 1e6
        derived = status or derived_summary(name, rows)
        print(f"{name},{us:.0f},{derived}", flush=True)
        out_path = f"results/bench/{name}.json"
        try:
            with open(out_path, "w") as f:
                json.dump(rows, f, indent=1, default=str)
        except OSError as e:
            # a suite whose output file cannot be written is a failure,
            # not a quiet gap in the artifact directory
            print(f"# {name}: could not write {out_path}: {e}", file=sys.stderr)
            if name not in failures:
                failures.append(name)
    if failures:
        print(f"# FAILED suites: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
