"""Persistent FederationState: pytree registration, server-optimizer
registry semantics, welfare selection, sketched grad_sim scoring, and the
checkpoint/resume round-trip (bit-identical params + stats + PRNG stream).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import aggregation as agg
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.fl.simulator import (load_federation_state, run_federation,
                                save_federation_state)
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=5, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))


# ===================================================== FederationState pytree
def test_init_state_shapes_and_pytree():
    fed = FedConfig(num_clients=C, server_opt="adam")
    st = engine.init_state(PARAMS, fed, C)
    assert st.backlog.shape == (C,) and st.backlog.dtype == jnp.int32
    assert st.util_ema.shape == (C,) and st.incl_ema.shape == (C,)
    assert set(st.opt_state) == {"m", "v", "t"}
    # registered pytree: flatten/unflatten round-trips, jit can carry it
    leaves, treedef = jax.tree.flatten(st)
    st2 = jax.tree.unflatten(treedef, leaves)
    assert isinstance(st2, engine.FederationState)
    doubled = jax.jit(lambda s: jax.tree.map(lambda x: x * 2, s))(st)
    assert isinstance(doubled, engine.FederationState)


def test_init_state_optimizer_layout_follows_config():
    assert engine.init_state(PARAMS, FedConfig(server_opt="none"), C).opt_state == ()
    assert engine.init_state(PARAMS, FedConfig(server_opt="sgd"), C).opt_state == ()
    m = engine.init_state(PARAMS, FedConfig(server_opt="momentum"), C).opt_state
    assert set(m) == {"m"}
    y = engine.init_state(PARAMS, FedConfig(server_opt="yogi"), C).opt_state
    assert set(y) == {"m", "v", "t"}


def test_unknown_server_optimizer_raises():
    with pytest.raises(ValueError, match="server optimizer"):
        engine.init_state(PARAMS, FedConfig(server_opt="nope"), C)


# ===================================================== server-optimizer rules
def _one_round(fed, state=None, r=1, seed=0):
    fn = jax.jit(engine.make_round_fn(LOSS, fed))
    if state is None:
        state = engine.init_state(PARAMS, fed, C)
    return fn(state, DATA, PM, W, jax.random.PRNGKey(seed), jnp.int32(r))


def test_none_is_sgd_alias():
    base = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
                epsilon=1e9, warmup_frac=0.0, align_stat="loss")
    sa, _ = _one_round(FedConfig(**base, server_opt="none"))
    sb, _ = _one_round(FedConfig(**base, server_opt="sgd"))
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_momentum_matches_hand_recursion():
    """apply_server_opt with momentum reproduces m <- beta m + d,
    w <- w + lr m on a toy tree."""
    fed = FedConfig(server_opt="momentum", server_momentum=0.5, server_lr=0.1)
    p = {"w": jnp.asarray([1.0, 2.0])}
    opt_state = agg.server_optimizer(fed).init(p)
    d1 = {"w": jnp.asarray([1.0, -1.0])}
    d2 = {"w": jnp.asarray([0.5, 0.5])}
    p1, st1 = agg.apply_server_opt(fed, p, opt_state, d1)
    p2, _ = agg.apply_server_opt(fed, p1, st1, d2)
    m1 = 0.5 * 0 + np.asarray([1.0, -1.0])
    w1 = np.asarray([1.0, 2.0]) + 0.1 * m1
    m2 = 0.5 * m1 + np.asarray([0.5, 0.5])
    w2 = w1 + 0.1 * m2
    np.testing.assert_allclose(np.asarray(p1["w"]), w1, atol=1e-6)
    np.testing.assert_allclose(np.asarray(p2["w"]), w2, atol=1e-6)


def test_adam_and_yogi_rules_on_constant_delta():
    """With a constant delta, bias-corrected adam and yogi both step by
    ~server_lr * d/(|d| + eps) in the right DIRECTION, and their second
    moments differ (multiplicative vs additive v update)."""
    p = {"w": jnp.asarray([0.0])}
    d = {"w": jnp.asarray([0.01])}
    outs = {}
    for name in ("adam", "yogi"):
        fed = FedConfig(server_opt=name, server_lr=0.1, server_eps=1e-3)
        st = agg.server_optimizer(fed).init(p)
        w = p
        for _ in range(3):
            w, st = agg.apply_server_opt(fed, w, st, d)
        outs[name] = (float(w["w"][0]), st)
        assert int(st["t"]) == 3
        assert outs[name][0] > 0.0                       # moves toward delta
    # bias-corrected adam on a constant delta steps EXACTLY
    # server_lr * d / (|d| + eps) every round
    np.testing.assert_allclose(
        outs["adam"][0], 3 * 0.1 * 0.01 / (0.01 + 1e-3), rtol=1e-4)
    # yogi's additive second moment grows faster than adam's EMA
    v_adam = float(outs["adam"][1]["v"]["w"][0])
    v_yogi = float(outs["yogi"][1]["v"]["w"][0])
    assert v_yogi > v_adam > 0.0


@pytest.mark.parametrize("server_opt", ["momentum", "adam", "yogi"])
def test_server_optimizers_train_in_simulator(server_opt):
    fed = FedConfig(num_clients=C, num_priority=3, rounds=12, local_epochs=3,
                    epsilon=0.2, lr=0.1, warmup_frac=0.0, batch_size=32,
                    server_opt=server_opt,
                    server_lr=1.0 if server_opt == "momentum" else 0.3)
    hist = run_federation(LOSS, INIT(jax.random.PRNGKey(0)), fed, FEDN,
                          eval_every=4)
    assert hist.test_acc[-1] > 0.4
    # the optimizer state really threads: moments are non-zero at the end
    m_norm = sum(float(jnp.sum(jnp.abs(l)))
                 for l in jax.tree.leaves(hist.state.opt_state["m"]))
    assert m_norm > 0.0


# ===================================================== welfare strategy
def _ctx(**kw):
    d = dict(align_vals=jnp.zeros((4,)), global_align=jnp.float32(0.0),
             eps=jnp.float32(0.5), priority_mask=jnp.asarray([1, 0, 0, 0], bool))
    d.update(kw)
    return engine.SelectionContext(**d)


def test_welfare_gates_on_smoothed_gap_and_floor():
    ctx = _ctx(util_ema=jnp.asarray([0.0, 0.1, 0.9, 0.9]),
               incl_ema=jnp.asarray([1.0, 1.0, 0.02, 0.5]),
               welfare_floor=0.05)
    gates = engine.compute_gates(ctx, "welfare")
    # 1: smoothed gap 0.1 < eps; 2: gap 0.9 out of band BUT starved below
    # the floor -> fairness admission; 3: out of band, not starved -> out
    np.testing.assert_array_equal(np.asarray(gates), [1, 1, 1, 0])


def test_welfare_without_state_raises():
    with pytest.raises(ValueError, match="util_ema"):
        engine.compute_gates(_ctx(), "welfare")


def test_welfare_beta_zero_floor_zero_equals_fedalign():
    """utility_ema=0 makes the EMA the instantaneous gap; floor 0 disables
    the fairness admission -> welfare == fedalign gates for any round."""
    base = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                epsilon=0.3, warmup_frac=0.0, align_stat="loss",
                utility_ema=0.0, welfare_floor=0.0)
    _, sa = _one_round(FedConfig(**base, selection="welfare"))
    _, sb = _one_round(FedConfig(**base, selection="fedalign"))
    np.testing.assert_array_equal(np.asarray(sa["gates"]),
                                  np.asarray(sb["gates"]))


def test_utility_estimate_debiases_cold_start():
    """Round 0 with beta=0.9: the raw EMA is 0.1*gap (would sneak a gap of
    3.0 under eps=0.5); the bias-corrected estimate recovers the gap
    exactly, so welfare rejects the misaligned client immediately."""
    fed = FedConfig(utility_ema=0.9)
    gap = jnp.asarray([3.0, 0.1])
    raw = engine.utility_update(fed, jnp.zeros((2,)), gap, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(raw), [0.3, 0.01], atol=1e-6)
    hat = engine.utility_estimate(fed, raw, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(hat), [3.0, 0.1], atol=1e-5)
    # constant gap stays exactly recovered at every round
    for r in range(1, 5):
        raw = engine.utility_update(fed, raw, gap, jnp.float32(0.0))
        hat = engine.utility_estimate(fed, raw, jnp.int32(r))
        np.testing.assert_allclose(np.asarray(hat), [3.0, 0.1], atol=1e-5)
    # and the end-to-end welfare round at r=0 rejects what fedalign rejects
    base = dict(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                epsilon=0.3, warmup_frac=0.0, align_stat="loss",
                utility_ema=0.9, welfare_floor=0.0)
    _, sw = _one_round(FedConfig(**base, selection="welfare"), r=0)
    _, sf = _one_round(FedConfig(**base, selection="fedalign"), r=0)
    np.testing.assert_array_equal(np.asarray(sw["gates"]),
                                  np.asarray(sf["gates"]))


def test_welfare_ema_smooths_across_rounds():
    """A high decay keeps yesterday's utility alive: after rounds of small
    gaps, the smoothed gap stays in-band even if eps would cut the
    instantaneous one — pinned via the carried util_ema."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    selection="welfare", utility_ema=0.9)
    st, _ = _one_round(fed)
    st2, _ = _one_round(fed, state=st, r=2, seed=2)
    assert np.all(np.asarray(st2.util_ema) >= 0)
    assert np.any(np.asarray(st2.util_ema) != np.asarray(st.util_ema))


# ===================================================== sketched grad_sim
def test_delta_sketch_preserves_cosines():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    tree_a = {"x": a[:1000].reshape(10, 100), "y": a[1000:]}
    tree_b = {"x": 2.0 * a[:1000].reshape(10, 100), "y": 2.0 * a[1000:]}  # cos 1
    c = jax.random.normal(jax.random.PRNGKey(2), (4096,))
    tree_c = {"x": c[:1000].reshape(10, 100), "y": c[1000:]}              # cos ~0
    dim = 2048
    sa = engine.delta_sketch(tree_a, key, dim)
    sb = engine.delta_sketch(tree_b, key, dim)
    sc = engine.delta_sketch(tree_c, key, dim)

    def cos(u, v):
        return float(jnp.dot(u, v) / (jnp.linalg.norm(u) * jnp.linalg.norm(v)))

    assert cos(sa, sb) > 0.95                        # parallel stays parallel
    assert abs(cos(sa, sc)) < 0.2                    # orthogonal stays small
    # norms are preserved in expectation too (unbiased JL)
    assert abs(float(jnp.linalg.norm(sa)) / float(jnp.linalg.norm(a)) - 1) < 0.2


def test_engine_grad_sim_sketch_backends_identical():
    """Sketched scoring uses a round-derived key shared by both backends:
    vmap_spatial and scan_temporal still produce the identical round."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    selection="grad_sim", sim_threshold=0.0,
                    grad_sim_sketch=True, sketch_dim=256)
    state = engine.init_state(PARAMS, fed, C)
    outs = []
    for backend in engine.BACKENDS:
        fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
        outs.append(fn(state, DATA, PM, W, jax.random.PRNGKey(0), jnp.int32(1)))
    (sv, tv), *others = outs
    for st_, tt in others:
        np.testing.assert_array_equal(np.asarray(tv["gates"]),
                                      np.asarray(tt["gates"]))
        for a, b in zip(jax.tree.leaves(sv), jax.tree.leaves(st_)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_sketched_cosines_close_to_exact():
    """On the small model the sketched grad_sim statistic approximates the
    exact one: same gates at a 0 threshold with well-separated cosines."""
    from repro.core.aggregation import flatten_stacked
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    selection="grad_sim", sim_threshold=0.0, sketch_dim=2048)
    solver = engine.local_solver(LOSS, fed)
    lkeys = jax.random.split(jax.random.PRNGKey(3), C)
    client_params = jax.vmap(
        lambda d, k: solver(PARAMS, d, k, jnp.float32(fed.lr)))(DATA, lkeys)
    deltas = jax.tree.map(lambda ck, g: ck - g[None], client_params, PARAMS)
    exact = engine.cosine_to_priority(flatten_stacked(deltas), W, PM)
    skey = engine.sketch_key(fed, 1)
    sketches = jax.vmap(
        lambda d: engine.delta_sketch(d, skey, fed.sketch_dim))(deltas)
    approx = engine.cosine_to_priority(sketches, W, PM)
    exact, approx = np.asarray(exact), np.asarray(approx)
    np.testing.assert_allclose(approx, exact, atol=0.25)
    # clearly-separated clients (|cos| > 0.1) must gate identically at
    # threshold 0 — the sketch only risks flips inside the noise band
    clear = np.abs(exact) > 0.1
    assert clear.any()
    assert np.array_equal((exact > 0)[clear], (approx > 0)[clear])


# ===================================================== checkpoint / resume
def test_checkpoint_resume_bit_identical(tmp_path):
    """Save the FULL FederationState (+ PRNG key) mid-run, resume, and pin
    bit-identical params and stats against the uninterrupted run.

    warmup_frac=0 and constant schedules keep the round semantics
    independent of ``fed.rounds``, so the 'interrupted' run is literally
    the first 5 rounds of the same trajectory."""
    path = str(tmp_path / "fed.msgpack")
    fed = FedConfig(num_clients=C, num_priority=3, rounds=8, local_epochs=2,
                    epsilon=0.3, lr=0.1, warmup_frac=0.0, batch_size=32,
                    server_opt="yogi", server_lr=0.3, max_cohort=5,
                    align_stat="loss")
    params0 = INIT(jax.random.PRNGKey(0))
    full = run_federation(LOSS, params0, fed, FEDN, eval_every=4,
                          checkpoint_path=path)
    like = engine.init_state(params0, fed, C)
    _, _, step = load_federation_state(path, like)
    assert step == fed.rounds                  # last boundary checkpoint

    # interrupted run: rounds 0..4 (same chunking as the full run's first
    # two chunks), checkpointed, reloaded, resumed for rounds 5..7
    half = run_federation(LOSS, params0, fed.replace(rounds=5), FEDN,
                          eval_every=4)
    save_federation_state(path, half.state, half.rng, 5)
    state, rng, step = load_federation_state(path, like)
    assert step == 5
    resumed = run_federation(LOSS, None, fed, FEDN, eval_every=4,
                             state=state, rng=rng, start_round=step)

    # bit-identical final params + optimizer moments + client state
    for a, b in zip(jax.tree.leaves(full.state), jax.tree.leaves(resumed.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # stats of the overlapping rounds pin the PRNG stream too
    np.testing.assert_array_equal(np.asarray(full.global_loss[5:]),
                                  np.asarray(resumed.global_loss))
    np.testing.assert_array_equal(np.stack(full.gates[5:]),
                                  np.stack(resumed.gates))
    assert full.test_acc[-1] == resumed.test_acc[-1]


def test_checkpoint_roundtrip_state_pytree(tmp_path):
    """save/load of a FederationState preserves every leaf (incl. int32
    backlog and the adam step counter) exactly."""
    fed = FedConfig(num_clients=C, server_opt="adam")
    st = engine.init_state(PARAMS, fed, C)
    st = st.replace(backlog=st.backlog.at[1].set(3),
                    util_ema=st.util_ema + 0.25)
    path = str(tmp_path / "st.msgpack")
    save_federation_state(path, st, jax.random.PRNGKey(7), 11)
    like = engine.init_state(PARAMS, fed, C)
    st2, rng2, step = load_federation_state(path, like)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(rng2),
                                  np.asarray(jax.random.PRNGKey(7)))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
