"""mLSTM chunkwise-parallel form vs the sequential recurrence (the xLSTM
compute core adapted for TPU — DESIGN.md hardware-adaptation note)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xlstm import mlstm_chunked, mlstm_step

KEY = jax.random.PRNGKey(0)


def _inputs(B=2, S=32, H=2, hd=8, k=0):
    r = lambda i, shape: jax.random.normal(jax.random.fold_in(KEY, k * 10 + i), shape)
    q = r(0, (B, S, H, hd))
    kk = r(1, (B, S, H, hd)) * hd ** -0.5
    v = r(2, (B, S, H, hd))
    li = r(3, (B, S, H))
    lf = jax.nn.log_sigmoid(r(4, (B, S, H)) + 1.0)
    return q, kk, v, li, lf


def _sequential(q, k, v, li, lf):
    B, S, H, hd = q.shape
    C = jnp.zeros((B, H, hd, hd))
    n = jnp.zeros((B, H, hd))
    m = jnp.full((B, H), -1e30)
    hs = []
    for t in range(S):
        h, (C, n, m) = mlstm_step(q[:, t], k[:, t], v[:, t], li[:, t], lf[:, t],
                                  (C, n, m))
        hs.append(h)
    return jnp.stack(hs, axis=1), (C, n, m)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mlstm_chunked_matches_sequential(chunk):
    q, k, v, li, lf = _inputs()
    want, (Cw, nw, mw) = _sequential(q, k, v, li, lf)
    got, (Cg, ng, mg) = mlstm_chunked(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
    # stabilized states match up to the (C~, m) gauge: compare C*exp(m)
    np.testing.assert_allclose(np.asarray(Cg * jnp.exp(mg)[..., None, None]),
                               np.asarray(Cw * jnp.exp(mw)[..., None, None]),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_chunked_invariant_to_chunk_size():
    q, k, v, li, lf = _inputs(S=48, k=1)
    h1, _ = mlstm_chunked(q, k, v, li, lf, chunk=6)
    h2, _ = mlstm_chunked(q, k, v, li, lf, chunk=48)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-3)


def test_mlstm_extreme_gates_stable():
    """Exponential gating with the log-max stabilizer must not overflow."""
    q, k, v, li, lf = _inputs(k=2)
    li = li + 40.0                    # huge input gates
    h, _ = mlstm_chunked(q, k, v, li, lf, chunk=8)
    assert not bool(jnp.any(jnp.isnan(h)))
    assert not bool(jnp.any(jnp.isinf(h)))
