from repro.core.alignment import (epsilon_at, global_loss_from_locals,  # noqa: F401
                                  inclusion_gates)
from repro.core.aggregation import aggregate_clients, aggregate_updates  # noqa: F401
from repro.core.round import make_round_fn  # noqa: F401
