"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed top-6.
[arXiv:2401.06066]

28L d_model=2048 16H d_ff=1408(per expert) vocab=102400. Layer 0 is a dense
FFN (d_ff=10944) per the paper; layers 1..27 are MoE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,                       # the single dense layer's hidden dim
    vocab_size=102400,
    head_dim=128,
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense=1,
    tie_embeddings=False,
    source="arXiv:2401.06066",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=3, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=512, moe_d_ff=128, num_experts=4, num_shared_experts=1, top_k=2,
        vocab_size=512, param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, attn_block_kv=64)
