"""CI benchmark-regression gate.

Compares a freshly generated ``BENCH_round.json`` against the committed
baseline and FAILS (exit 1) when any row's ``rounds_per_sec`` regressed by
more than the tolerance (default 15%). Rows are matched by their identity
fields (path + configuration knobs), NOT by list position, so reordering
or interleaving new rows never miscompares:

* new rows (present only in the fresh run) are ALLOWED — adding a
  benchmark must not require touching the gate;
* removed rows (present only in the baseline) FAIL — a silently dropped
  row is how a regression hides;
* rows without a ``rounds_per_sec`` metric (e.g. the rounds-to-target
  convergence rows, the state-threading-overhead row) are not gated.

**Common-mode normalization.** The committed baseline and the fresh run
usually come from DIFFERENT machines (dev laptop vs CI runner) or load
conditions, so a uniform absolute shift carries no signal. When >= 3 rows
are gated, each row's fresh/baseline ratio is judged relative to the
MEDIAN ratio across rows, capped at 1.0: a slower-but-uniformly-slower box
stays green, while a single row that fell behind its peers fails. The cap
means a uniformly *faster* run is still gated absolutely (nothing can fail
from others speeding up). The trade-off is explicit: a genuinely uniform
code slowdown across every row reads as machine speed — per-row gates
cannot distinguish the two across hardware; ``--absolute`` restores raw
ratio gating for same-machine comparisons.

Usage (wired into .github/workflows/ci.yml after the bench step):

    python scripts/check_bench.py BENCH_round.json BENCH_round.fresh.json \
        [--tolerance 0.15] [--absolute]

The tolerance can also be set via the BENCH_REGRESSION_TOLERANCE env var
(the CLI flag wins). Exit codes: 0 green, 1 regression/missing row,
2 usage error (unreadable/empty input).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# fields that identify a row across runs; metrics and derived values are
# deliberately absent (they are what we compare, not how we match).
# async_mode/min_lag joined in PR 5 (fifo-vs-ready rows), aggregator in
# PR 6 (robust-aggregation ablation rows), the failure knobs in PR 7
# (chaos:* fault-injection rows), the wire-codec knobs in PR 8
# (codec:* / codec_frontier:* uplink-compression rows), and
# candidate_pool in PR 9 (pool:* population-scaling rows): rows missing a
# field simply omit it from their key, so pre-existing baselines still
# match — only rows that NAME a mode/aggregator/failure model/codec/pool
# are distinguished by it.
KEY_FIELDS = ("path", "target_inclusion_rate", "max_cohort", "clients",
              "scan_rounds", "async_depth", "async_mode", "min_lag",
              "aggregator", "failure_model", "crash_rate", "round_deadline",
              "latency_mode", "wire_codec", "error_feedback",
              "codec_topk_frac", "codec_sketch_dim", "candidate_pool")

METRIC = "rounds_per_sec"


def row_key(row: dict) -> tuple:
    return tuple((k, row[k]) for k in KEY_FIELDS if k in row)


def load_rows(path: str) -> dict:
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {path!r}: {e}", file=sys.stderr)
        raise SystemExit(2) from None
    if not isinstance(rows, list) or not rows:
        print(f"check_bench: {path!r} holds no benchmark rows", file=sys.stderr)
        raise SystemExit(2)
    out = {}
    for row in rows:
        key = row_key(row)
        if key in out:
            print(f"check_bench: duplicate row key {key} in {path!r}",
                  file=sys.stderr)
            raise SystemExit(2)
        out[key] = row
    return out


def compare(baseline: dict, fresh: dict, tolerance: float,
            absolute: bool = False) -> list[str]:
    """Returns the list of failure messages (empty == gate green)."""
    failures, pairs = [], []
    for key, base_row in sorted(baseline.items()):
        if METRIC not in base_row or base_row[METRIC] in (None, 0):
            continue
        name = dict(key).get("path", str(key))
        if key not in fresh:
            failures.append(f"row {key} vanished from the fresh run "
                            f"(was {base_row[METRIC]} {METRIC})")
            continue
        fresh_row = fresh[key]
        if METRIC not in fresh_row or fresh_row[METRIC] in (None, 0):
            failures.append(f"{name}: fresh row lost its {METRIC} metric")
            continue
        pairs.append((name, base_row[METRIC], fresh_row[METRIC]))

    norm = 1.0
    if not absolute and len(pairs) >= 3:
        ratios = sorted(f / b for _, b, f in pairs)
        mid = len(ratios) // 2
        median = (ratios[mid] if len(ratios) % 2
                  else (ratios[mid - 1] + ratios[mid]) / 2.0)
        norm = min(median, 1.0)
        if norm < 1.0:
            print(f"  common-mode speed factor {norm:.2%} (median ratio) — "
                  f"rows are judged relative to it")
    for name, base_v, fresh_v in pairs:
        rel = (fresh_v / base_v) / norm
        verdict = "OK" if rel >= 1.0 - tolerance else "REGRESSION"
        print(f"  [{verdict}] {name}: {base_v:.2f} -> {fresh_v:.2f} "
              f"{METRIC} ({fresh_v / base_v:.2%} of baseline, "
              f"{rel:.2%} normalized)")
        if verdict == "REGRESSION":
            failures.append(
                f"{name}: {METRIC} fell {1.0 - rel:.1%} behind the fleet "
                f"({base_v:.2f} -> {fresh_v:.2f}, tolerance "
                f"{tolerance:.0%})")
    new = set(fresh) - set(baseline)
    for key in sorted(new):
        print(f"  [NEW] {dict(key).get('path', key)} (not gated this run)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_round.json")
    ap.add_argument("fresh", help="freshly generated BENCH_round.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_TOLERANCE", "0.15")),
                    help="max allowed fractional rounds/sec drop per row "
                         "(default 0.15 = 15%%)")
    ap.add_argument("--absolute", action="store_true",
                    help="gate raw ratios without common-mode (median) "
                         "normalization — for same-machine comparisons")
    args = ap.parse_args(argv)

    print(f"check_bench: {args.fresh} vs baseline {args.baseline} "
          f"(tolerance {args.tolerance:.0%})")
    failures = compare(load_rows(args.baseline), load_rows(args.fresh),
                       args.tolerance, absolute=args.absolute)
    if failures:
        print("\ncheck_bench: FAILED", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("check_bench: green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
