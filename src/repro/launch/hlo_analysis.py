"""Back-compat shim: the scan-aware HLO analyzer moved to
``repro.analysis.hlo`` (it now also feeds the fedlint static-analysis
rules, not just the roofline). Every public name is re-exported so the
roofline API — ``analyze_file`` / ``analyze_text`` / ``parse_hlo`` /
``aggregate`` and the ``DTYPE_BYTES`` / ``COLLECTIVES`` tables — keeps
importing from here."""
from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVES, DTYPE_BYTES, Comp, aggregate, analyze_file, analyze_text,
    hlo_constants, parse_hlo, parse_input_output_alias, read_hlo_file)
