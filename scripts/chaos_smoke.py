"""CI chaos smoke: a short fault-injected federation must end sane.

Runs a handful of event-clocked rounds with 10% Bernoulli crashes, a
finite round deadline, and the divergence guard armed, then asserts the
engine's survivor accounting held up:

* the final global loss is finite (crashes lose mass, they never poison
  the aggregate);
* ``lost_clients`` was reported every round and at least one client was
  actually lost over the run (the faults really fired);
* the guard never tripped (``skipped_nonfinite`` stayed 0 — with
  corruption off there is nothing non-finite to skip);
* every crashed/deadline-lost selected client re-enqueued through the
  backlog (no silently vanished work).

This is a liveness/accounting check, not a perf gate — it runs the same
``engine.make_round_fn`` path the chaos bench rows use, but in seconds.

    PYTHONPATH=src python scripts/chaos_smoke.py
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import SMALL_MODELS, make_loss_fn

CLIENTS, N_PRIORITY, ROUNDS = 16, 4, 12


def main() -> int:
    init_fn, apply_fn = SMALL_MODELS["synth_logreg"]
    loss_fn = make_loss_fn(apply_fn)
    fedn = make_synth_federation(seed=3, n_priority=N_PRIORITY,
                                 n_nonpriority=CLIENTS - N_PRIORITY,
                                 samples_per_client=64)
    data = {"x": fedn.x, "y": fedn.y}
    params = init_fn(jax.random.PRNGKey(0))

    fed = FedConfig(num_clients=CLIENTS, num_priority=N_PRIORITY,
                    rounds=ROUNDS, local_epochs=1, epsilon=0.5,
                    warmup_frac=0.0, align_stat="loss",
                    backend="scan_async", async_depth=2, async_mode="ready",
                    min_lag=1, staleness_decay=0.8,
                    latency_mode="lognormal", round_deadline=2.0,
                    failure_model="crash", crash_rate=0.1,
                    divergence_guard=True, max_nonfinite_skips=3)
    round_fn = jax.jit(engine.make_round_fn(loss_fn, fed))
    state = engine.init_state(params, fed, CLIENTS)

    lost_total, losses, skips = 0.0, [], []
    key = jax.random.PRNGKey(0)
    for r in range(ROUNDS):
        key, rkey = jax.random.split(key)
        state, stats = round_fn(state, data, fedn.priority_mask, fedn.weights,
                                rkey, jnp.int32(r))
        for k in ("lost_clients", "skipped_nonfinite"):
            assert k in stats, f"round {r}: stats missing {k!r}"
        lost_total += float(stats["lost_clients"])
        losses.append(float(stats["global_loss"]))
        skips.append(int(stats["skipped_nonfinite"]))

    ok = True

    def check(cond, msg):
        nonlocal ok
        print(f"  [{'ok' if cond else 'FAIL'}] {msg}")
        ok = ok and bool(cond)

    print(f"[chaos_smoke] {ROUNDS} rounds, crash_rate={fed.crash_rate}, "
          f"round_deadline={fed.round_deadline}, clock={fed.latency_mode}")
    check(np.isfinite(losses[-1]), f"final global loss finite ({losses[-1]:.4f})")
    check(lost_total > 0, f"faults fired: {lost_total:.0f} client-losses accounted")
    check(max(skips) == 0,
          f"divergence guard armed but silent (max skips {max(skips)})")
    backlog = np.asarray(state.backlog)
    check(np.all(backlog >= 0) and backlog.max() > 0,
          f"lost selected clients re-enqueued (backlog max {backlog.max()})")
    if not ok:
        print("[chaos_smoke] FAILED")
        return 1
    print("[chaos_smoke] PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
