"""Mamba (S6) selective-state-space block.

Train/prefill use the chunked parallel scan (kernels/ops.ssm_scan — Pallas
on TPU, associative-scan jnp fallback elsewhere); decode is a single
recurrent step against a (conv tail, ssm state) cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import dense_init
from repro.utils import fold_in_name


def init_mamba(key, cfg):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    K, dtr = cfg.ssm_conv_dim, cfg.ssm_dt_rank
    ks = {n: fold_in_name(key, n) for n in
          ("in", "conv", "xproj", "dtproj", "out")}
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "w_in": dense_init(ks["in"], (d, 2 * di), cfg.pdtype),
        "conv_w": dense_init(ks["conv"], (K, di), cfg.pdtype, scale=K ** -0.5),
        "conv_b": jnp.zeros((di,), cfg.pdtype),
        "w_xproj": dense_init(ks["xproj"], (di, dtr + 2 * N), cfg.pdtype),
        "w_dtproj": dense_init(ks["dtproj"], (dtr, di), cfg.pdtype, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(cfg.pdtype),
        "A_log": jnp.log(A).astype(jnp.float32),                       # keep fp32
        "D": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks["out"], (di, d), cfg.pdtype),
    }


def _causal_conv(xi, w, b, K):
    """Depthwise causal conv. xi: [B,S,di]; w: [K,di]."""
    pad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, j:j + xi.shape[1], :] * w[j][None, None] for j in range(K))
    return y + b[None, None]


def _ssm_inputs(p, xi, cfg):
    """xi: [B,S,di] (post conv+silu) -> (dt, Bm, Cm) fp32."""
    N, dtr = cfg.ssm_state_dim, cfg.ssm_dt_rank
    proj = xi @ p["w_xproj"].astype(xi.dtype)                          # [B,S,dtr+2N]
    dt_r, Bm, Cm = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["w_dtproj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))           # [B,S,di]
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_block(p, x, cfg, *, mode, cache=None):
    """x: [B,S,d]. cache (decode): {'conv': [B,K-1,di], 'h': [B,di,N]}."""
    B, S, d = x.shape
    di, N, K = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_dim
    cd = cfg.cdtype
    u = x @ p["w_in"].astype(cd)                                       # [B,S,2di]
    xi, z = jnp.split(u, 2, axis=-1)

    if mode in ("train", "prefill"):
        xc = jax.nn.silu(_causal_conv(xi, p["conv_w"].astype(cd), p["conv_b"].astype(cd), K))
        dt, Bm, Cm = _ssm_inputs(p, xc, cfg)
        A = -jnp.exp(p["A_log"])
        y = kops.ssm_scan(xc, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk,
                          use_pallas=cfg.use_pallas)
        new_cache = None
        if mode == "prefill":
            # replay the tail to produce the decode cache state
            h = _final_state(xc, dt, A, Bm)
            new_cache = {"conv": xi[:, S - (K - 1):].astype(cd), "h": h}
    else:  # decode, S == 1
        conv_tail = cache["conv"]                                      # [B,K-1,di]
        window = jnp.concatenate([conv_tail, xi], axis=1)              # [B,K,di]
        xc = jnp.einsum("bkd,kd->bd", window.astype(cd), p["conv_w"].astype(cd))
        xc = jax.nn.silu(xc + p["conv_b"].astype(cd))[:, None]         # [B,1,di]
        dt, Bm, Cm = _ssm_inputs(p, xc, cfg)
        A = -jnp.exp(p["A_log"])
        h, y1 = kops.ssm_step(cache["h"], xc[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = (y1 + xc[:, 0].astype(jnp.float32) * p["D"][None]).astype(cd)[:, None]
        new_cache = {"conv": window[:, 1:], "h": h}

    y = y.astype(cd) * jax.nn.silu(z)
    return y @ p["w_out"].astype(cd), new_cache


def _final_state(xc, dt, A, Bm):
    """Sequential pass for the final SSM state (prefill->decode handoff)."""
    def step(h, inp):
        xt, dtt, Bt = inp
        dA = jnp.exp(dtt[..., None] * A[None])
        h = dA * h + (dtt * xt.astype(jnp.float32))[..., None] * Bt[:, None, :]
        return h, None
    B, S, di = xc.shape
    h0 = jnp.zeros((B, di, A.shape[1]), jnp.float32)
    xs = (xc.astype(jnp.float32).transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2))
    h, _ = jax.lax.scan(step, h0, xs)
    return h
