"""Host-side federated batch loader: deterministic shuffle-buffer iteration
over client-stacked arrays with per-round minibatch assembly.

The simulator consumes whole client datasets per round (the paper's E-epoch
protocol); this loader serves the LM-scale drivers where client corpora are
token streams larger than a round's budget.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class FederatedBatches:
    """Iterates (client-stacked) minibatches from [C, n, ...] arrays."""
    data: dict                    # leaves [C, n, ...]
    batch_size: int
    seed: int = 0
    drop_last: bool = True

    def __post_init__(self):
        first = next(iter(self.data.values()))
        self.C, self.n = first.shape[:2]
        self._rng = np.random.default_rng(self.seed)
        self._order = None
        self._cursor = self.n        # trigger reshuffle on first batch

    def _reshuffle(self):
        # independent permutation per client
        self._order = np.stack([self._rng.permutation(self.n)
                                for _ in range(self.C)])
        self._cursor = 0

    def next_batch(self) -> dict:
        """One [C, batch_size, ...] batch; reshuffles at epoch boundaries."""
        if self._cursor + self.batch_size > self.n:
            self._reshuffle()
        idx = self._order[:, self._cursor:self._cursor + self.batch_size]
        self._cursor += self.batch_size
        out = {}
        for k, v in self.data.items():
            out[k] = np.stack([v[c, idx[c]] for c in range(self.C)])
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def pack_token_documents(docs: list[np.ndarray], seq_len: int,
                         pad_id: int = 0) -> np.ndarray:
    """Greedy sequence packing: concatenate documents, split into
    (seq_len+1)-token rows (input+shifted-label layout)."""
    flat = np.concatenate(docs) if docs else np.zeros((0,), np.int32)
    n = len(flat) // (seq_len + 1)
    if n == 0:
        row = np.full((seq_len + 1,), pad_id, np.int32)
        row[:len(flat)] = flat
        return row[None]
    return flat[:n * (seq_len + 1)].reshape(n, seq_len + 1).astype(np.int32)
