"""Recursive jaxpr traversal for the jaxpr-level lint rules.

``jax.make_jaxpr`` gives a ``ClosedJaxpr`` whose equations nest more
jaxprs inside their params (``pjit``'s ``jaxpr``, ``scan``'s ``jaxpr``,
``cond``'s ``branches``, ``custom_jvp``'s ``call_jaxpr``, ...). The rules
need a flat view of every equation at any depth, every closure-captured
constant, and a lowering-stable fingerprint; this module provides exactly
those three walks and nothing jax-version-specific — sub-jaxprs are
discovered structurally (anything in ``eqn.params`` with ``.eqns``),
never by primitive name.
"""
from __future__ import annotations

import hashlib

import numpy as np


def _sub_jaxprs(value):
    """Yield every (Closed)Jaxpr nested in one eqn.params value."""
    items = value if isinstance(value, (list, tuple)) else [value]
    for item in items:
        inner = getattr(item, "jaxpr", None)   # ClosedJaxpr -> Jaxpr
        if inner is not None and hasattr(inner, "eqns"):
            yield item                          # keep the Closed wrapper
        elif hasattr(item, "eqns"):
            yield item


def iter_eqns(jaxpr):
    """Every equation of ``jaxpr`` (a Jaxpr or ClosedJaxpr), recursing into
    sub-jaxprs carried in equation params (scan bodies, cond branches,
    pjit calls) — depth-first, parents before children."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn
        for value in eqn.params.values():
            for sub in _sub_jaxprs(value):
                yield from iter_eqns(sub)


def closure_consts(closed_jaxpr) -> list[tuple[str, int]]:
    """Closure-captured constants of the program, at any nesting depth:
    one ``(dtype-and-shape label, nbytes)`` pair per const. These are the arrays a traced function
    closed over instead of taking as arguments — the exact class that XLA
    embeds as literal constants (the PR 9 federation-tensor bug)."""
    out = []
    seen = set()

    def visit(cj):
        if id(cj) in seen:
            return
        seen.add(id(cj))
        for const in getattr(cj, "consts", ()):
            shape = getattr(const, "shape", None)
            dtype = getattr(const, "dtype", None)
            if shape is None or dtype is None:
                continue
            nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
            out.append((f"{np.dtype(dtype).name}{list(shape)}", int(nbytes)))
        for eqn in iter_eqns(cj):
            for value in eqn.params.values():
                for sub in _sub_jaxprs(value):
                    if hasattr(sub, "consts"):
                        visit(sub)

    visit(closed_jaxpr)
    return out


def eqn_out_avals(eqn):
    """Shaped output avals of one equation (skips tokens/abstract units)."""
    for var in eqn.outvars:
        aval = getattr(var, "aval", None)
        if aval is not None and hasattr(aval, "shape") and hasattr(aval, "dtype"):
            yield aval


def jaxpr_fingerprint(closed_jaxpr) -> str:
    """Content hash of the program SHAPE: the printed jaxpr (whose variable
    naming is deterministic per trace) plus the avals — not the values —
    of its closure constants. Two lowerings of the same function at
    different ``round_idx``/state VALUES hash equal iff nothing about the
    values leaked into the trace as a literal, weak type, or shape — the
    recompile-stability invariant."""
    inner = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    h = hashlib.sha256(str(inner).encode())
    for desc, nbytes in closure_consts(closed_jaxpr):
        h.update(f"|const {desc} {nbytes}".encode())
    # the printed jaxpr elides weak_type, but jit's cache does not: a
    # python-scalar round_idx (weak i32) and a device one (strong i32)
    # recompile against each other — include the full in-aval reprs
    for aval in getattr(closed_jaxpr, "in_avals", ()):
        h.update(f"|in {aval}".encode())
    return h.hexdigest()
