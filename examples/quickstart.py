"""Quickstart: FedALIGN on SYNTH(1,1) in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl.simulator import run_federation
from repro.models.small import SMALL_MODELS, make_loss_fn

# 1. a federation: 10 priority clients (define the objective) + 10 free
#    clients holding noisy copies of the global data
federation = make_synth_federation(seed=0, n_priority=10, n_nonpriority=10,
                                   samples_per_client=200,
                                   label_noise_skew=1.5, random_data_skew=1.5)

# 2. the paper's model for this dataset: logistic regression on 60 dims
init_fn, apply_fn = SMALL_MODELS["synth_logreg"]
loss_fn = make_loss_fn(apply_fn)

# 3. FedALIGN: eps=0.2 loss-matching, E=5 local epochs, 10% warm-up.
#    `selection` names any SelectionStrategy registered in fl/engine.py —
#    try "topk_align" (budgeted inclusion) or "grad_sim" (update-cosine
#    friends selection); `backend` picks vmap_spatial / scan_temporal
#    client execution (identical rounds, different hardware schedule).
fed = FedConfig(num_clients=20, num_priority=10, rounds=60, local_epochs=5,
                epsilon=0.2, lr=0.1, warmup_frac=0.1, selection="fedalign",
                backend="vmap_spatial")

hist = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)), fed,
                      federation, eval_every=5, verbose=True)
s = hist.summary()
print(f"\nfinal priority-test accuracy: {s['final_acc']:.4f} "
      f"(mean non-priority clients included/round: {s['mean_included']:.1f})")

# 4. one-liner ablation: swap the selection strategy, nothing else changes
for sel in ("topk_align", "priority_only"):
    h = run_federation(loss_fn, init_fn(jax.random.PRNGKey(42)),
                       fed.replace(selection=sel, topk=5), federation,
                       eval_every=20)
    print(f"{sel:>14}: final acc {h.summary()['final_acc']:.4f}")
