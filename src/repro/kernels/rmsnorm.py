"""Pallas TPU fused RMSNorm.

Row-tiled: each grid cell normalizes ``block_r`` rows of a [R, D] input in
one VMEM pass (load, square-reduce, rsqrt, scale, store) instead of the
4-pass HLO sequence XLA emits for the unfused jnp version. Memory-bound;
the win is moving x through HBM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                       # [br, D]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps=1e-6, block_r=256, interpret=False):
    """x: [..., D]; scale: [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    block_r = min(block_r, R)
    pad = (-R) % block_r
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    Rp = R + pad

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(Rp // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, D), lambda ir: (ir, 0)),
            pl.BlockSpec((D,), lambda ir: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, D), lambda ir: (ir, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, D), x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:R].reshape(orig_shape)
