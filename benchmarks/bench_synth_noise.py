"""Paper Figure 2: SYNTH(1,1), N=20, |P|=10, E=5; label-flip + irrelevant
data noise at low/medium/high skew. eps=0.2 (0.4 for high noise), exactly
the paper's choices."""
from __future__ import annotations

from benchmarks.common import fed_suite
from repro.data.synth import NOISE_PRESETS, make_synth_federation


def run(fast=True, seeds=(0,)):
    rows = []
    rounds = 30 if fast else 200
    for level, skew in NOISE_PRESETS.items():
        fedn = make_synth_federation(seed=0, n_priority=10, n_nonpriority=10,
                                     samples_per_client=200,
                                     label_noise_factor=2.5, label_noise_skew=skew,
                                     random_data_factor=1.0, random_data_skew=skew)
        eps = 0.4 if level == "high" else 0.2
        out = fed_suite(fedn, "synth_logreg",
                        dict(num_clients=20, num_priority=10, rounds=rounds,
                             local_epochs=5, epsilon=eps, lr=0.1,
                             warmup_frac=0.1, batch_size=32), seeds=seeds)
        for r in out:
            r["noise"] = level
        rows += out
    return rows


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "acc_curve"})
