"""Shared federation CLI surface.

``launch/train.py`` and ``launch/dryrun.py`` used to mirror the same ~27
federation flags by hand, drifting one knob at a time (train grew
``--dp-delta``/``--max-nonfinite-skips`` the dry-run never saw; the async
four only existed on the dry-run side).  Both CLIs now call

    add_fed_args(parser)        # one canonical flag set
    fed_kw = fed_from_args(args)  # FedConfig overrides, defaults omitted

so a knob added here shows up in every launcher at once, and
``tests/test_pool.py`` pins the two flag sets equal.

``fed_from_args`` keeps the repo's conditional-override idiom: a knob
group only enters the returned dict when its gating flag departs from the
default, so a default invocation yields ``{}`` and the launcher's
``FedConfig``/``DRYRUN_FED`` stays LITERALLY untouched (bit-identical
configs, hence bit-identical traces).
"""
from __future__ import annotations

import argparse


def add_fed_args(parser: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Register every federation knob on ``parser`` (returns it)."""
    g = parser.add_argument_group(
        "federation", "FedConfig overrides shared by all launchers")
    g.add_argument("--async-depth", type=int, default=0,
                   help="run scan_async overlapped cohorts: the in-flight "
                        "delta buffer (async_depth stacked param-shaped "
                        "deltas, plus per-slot age/validity vectors) joins "
                        "the FederationState")
    g.add_argument("--async-mode", default="fifo", choices=["fifo", "ready"],
                   help="in-flight pop policy: strict fixed-lag pipe, or "
                        "FedBuff-style variable-lag readiness buffer (pops "
                        "every slot aged >= --min-lag, oldest first)")
    g.add_argument("--min-lag", type=int, default=1,
                   help="ready mode: rounds a buffered delta must age "
                        "before it may be applied (1 <= min_lag <= "
                        "async_depth)")
    g.add_argument("--adaptive-staleness", action="store_true",
                   help="discount applied deltas by measured drift "
                        "(staleness_decay**age * max(0, cos vs the last "
                        "applied delta)); adds the [sketch_dim] last_delta "
                        "sketch leaf to the state")
    g.add_argument("--aggregator", default="mean",
                   choices=["mean", "trimmed_mean", "median", "dp",
                            "cosine_filter"],
                   help="Aggregator registry name (core/aggregation.py): "
                        "how the gated client deltas are reduced inside "
                        "the one fused fedagg call")
    g.add_argument("--trim-frac", type=float, default=0.1,
                   help="trimmed_mean: fraction of included clients "
                        "trimmed from EACH side per coordinate (< 0.5)")
    g.add_argument("--dp-clip", type=float, default=1.0,
                   help="dp: per-client delta L2 clip bound (the DP "
                        "sensitivity)")
    g.add_argument("--dp-noise", type=float, default=0.0,
                   help="dp: Gaussian noise multiplier z (sigma = "
                        "z*dp_clip/inclusion_mass per coordinate; 0 = "
                        "clip-only)")
    g.add_argument("--dp-delta", type=float, default=1e-5,
                   help="dp: target delta for the RDP (epsilon, delta) "
                        "report printed after the run")
    g.add_argument("--outlier-cos", type=float, default=0.0,
                   help="cosine_filter: gate out clients whose sketch-"
                        "estimated delta-direction cosine to the gated "
                        "mean direction falls below this")
    g.add_argument("--latency-mode", default="none",
                   choices=["none", "lognormal"],
                   help="event-driven client clock (per-client lognormal "
                        "compute+network times; async depth > 0 requires "
                        "async_mode='ready')")
    g.add_argument("--round-deadline", type=float, default=float("inf"),
                   help="force-land in-flight slots after this many round "
                        "units with only their finished members' mass "
                        "(finite values require --latency-mode)")
    g.add_argument("--failure-model", default="none",
                   choices=["none", "crash", "dropout", "corrupt", "chaos"],
                   help="fault injection (FailureModel registry, "
                        "fl/engine.py): Bernoulli crash (delta lost "
                        "post-train), transient drop-out, delta corruption "
                        "in transit, or all three (chaos)")
    g.add_argument("--crash-rate", type=float, default=0.0)
    g.add_argument("--dropout-rate", type=float, default=0.0)
    g.add_argument("--dropout-len", type=int, default=1)
    g.add_argument("--corrupt-rate", type=float, default=0.0)
    g.add_argument("--corrupt-scale", type=float, default=0.0)
    g.add_argument("--divergence-guard", action="store_true",
                   help="skip non-finite aggregates bit-exactly and track "
                        "consecutive skips")
    g.add_argument("--max-nonfinite-skips", type=int, default=0,
                   help="halt the driver after this many CONSECUTIVE "
                        "guarded skips (0 = never halt)")
    g.add_argument("--wire-codec", default="identity",
                   choices=["identity", "int8", "topk", "sketch"],
                   help="uplink compression (WireCodec registry): encode "
                        "the flattened per-client delta rows before the "
                        "fused fedagg call; decode happens in-register "
                        "inside the kernel")
    g.add_argument("--codec-topk-frac", type=float, default=0.01,
                   help="topk: fraction of coordinates each client keeps")
    g.add_argument("--codec-sketch-dim", type=int, default=2048,
                   help="sketch: CountSketch width each client uplinks")
    g.add_argument("--no-error-feedback", dest="error_feedback",
                   action="store_false", default=True,
                   help="disable the per-client error-feedback "
                        "accumulators (biased compression)")
    g.add_argument("--candidate-pool", type=int, default=0,
                   help="sample-then-evaluate population scaling: each "
                        "round draws this many candidates (priority "
                        "clients always in-pool) and runs eval/gating/"
                        "training/fedagg on the [P] slice only, scattering "
                        "the per-client state rows back at the sampled "
                        "indices; 0 = dense rounds over every client")
    g.add_argument("--pool-weighting", default="uniform",
                   choices=["uniform", "backlog", "ema"],
                   help="non-priority candidate sampling weights: uniform "
                        "Gumbel top-k, backlog-tilted (starved clients "
                        "more likely), or inclusion-EMA-tilted (rarely "
                        "included clients more likely)")
    return parser


def fed_from_args(args: argparse.Namespace) -> dict:
    """FedConfig override kwargs for ``add_fed_args`` values.

    Returns only the knob groups whose gating flag left its default, so
    ``FedConfig(**fed_from_args(args))`` on a default command line equals
    a bare ``FedConfig()`` (and ``fed.replace(**{})`` is the identity)."""
    kw: dict = {}
    if args.async_depth > 0:
        kw.update(async_depth=args.async_depth, backend="scan_async",
                  async_mode=args.async_mode, min_lag=args.min_lag,
                  adaptive_staleness=args.adaptive_staleness)
    if args.aggregator != "mean":
        kw.update(aggregator=args.aggregator, trim_frac=args.trim_frac,
                  dp_clip=args.dp_clip, dp_noise=args.dp_noise,
                  dp_delta=args.dp_delta, outlier_cos=args.outlier_cos)
    if args.latency_mode != "none":
        kw.update(latency_mode=args.latency_mode,
                  round_deadline=args.round_deadline)
    if args.failure_model != "none":
        kw.update(failure_model=args.failure_model,
                  crash_rate=args.crash_rate,
                  dropout_rate=args.dropout_rate,
                  dropout_len=args.dropout_len,
                  corrupt_rate=args.corrupt_rate,
                  corrupt_scale=args.corrupt_scale)
    if args.divergence_guard:
        kw.update(divergence_guard=True,
                  max_nonfinite_skips=args.max_nonfinite_skips)
    if args.wire_codec != "identity":
        kw.update(wire_codec=args.wire_codec,
                  error_feedback=args.error_feedback,
                  codec_topk_frac=args.codec_topk_frac,
                  codec_sketch_dim=args.codec_sketch_dim)
    if args.candidate_pool > 0:
        kw.update(candidate_pool=args.candidate_pool,
                  pool_weighting=args.pool_weighting)
    return kw
