"""Unified federation round engine: pluggable client selection + execution
backends. The single implementation of FedALIGN's gating, eps schedule,
warm-up, and participation sampling — `core/round.py` (simulator) and
`fl/sharded.py` (pjit pod-scale rounds) are thin adapters over this module.

Two orthogonal seams:

* **SelectionStrategy** — who joins the aggregation this round. Decorator-
  registered (`@register_strategy`); a strategy maps a `SelectionContext`
  to a [C] {0,1} inclusion vector for *non-priority* clients (priority
  clients are always in, warm-up and participation are applied uniformly
  by `compute_gates`). Shipped strategies:

    fedalign      — paper rule (§3.1): |F(w_t) - F_k(w_t)| < eps_t
    all           — FedAvg over everyone (baseline 2)
    priority_only — FedAvg over priority clients (baseline 1)
    topk_align    — budgeted FedALIGN: the k best loss-matched non-priority
                    clients inside the eps band (ties at the k-th rank all
                    enter — deterministic, may exceed k on exact ties)
    grad_sim      — gradient-similarity "friends" selection after Tupitsa
                    et al. (arXiv:2402.05050): include non-priority client k
                    iff cosine(delta_k, delta_P) >= sim_threshold, where
                    delta_P is the priority-weighted mean update
    welfare       — welfare/fairness-aware selection after Travadi et al.
                    (arXiv:2302.08976): gate on the cross-round utility
                    EMAs carried in FederationState (smoothed loss gap
                    within eps_t, or inclusion EMA under the fairness
                    floor)

* **Execution backend** — how the client axis is executed:

    vmap_spatial  — clients in parallel via vmap (clients are mesh shards
                    at pod scale)
    scan_temporal — clients time-multiplexed via lax.scan (models too big
                    to replicate per client)
    scan_async    — overlapped cohorts: spatial (vmap) execution, but the
                    round's aggregated delta is NOT applied at the round
                    barrier. The cohort gathered at round t trains against
                    w_t while later rounds evaluate/gate without waiting
                    for it; its delta lands when the in-flight buffer's pop
                    policy says it is ready (``FedConfig.async_mode``:
                    "fifo" — exactly ``async_depth`` rounds late, the
                    strict pipe; "ready" — FedBuff-style variable lag, any
                    slot aged >= ``min_lag`` pops, oldest first), scaled by
                    its staleness discount (``staleness_decay ** age``,
                    optionally times the measured-drift cosine under
                    ``adaptive_staleness``). The in-flight deltas, their
                    per-slot ages, and the drift-reference sketch are
                    ordinary ``FederationState`` leaves (``state.inflight``
                    / ``state.last_delta``), so the jitted ``lax.scan``
                    driver, checkpoint/resume, and the pjit lowering carry
                    them like any other cross-round state. ``async_depth=0``
                    degenerates to the synchronous round, bit-identical to
                    vmap_spatial; ``async_mode="fifo"`` with
                    ``adaptive_staleness=False`` is bit-identical to the
                    fixed-depth PR 4 pipeline.

  The two synchronous backends produce identical rounds (same PRNG
  fan-out, same gating, same aggregation) — only the schedule over
  hardware differs. ``scan_async`` produces the same *per-round compute*
  but a pipelined *application* schedule.

Rounds thread a persistent **FederationState** — a registered pytree
carrying the global params, the server-optimizer moments, the per-client
overflow backlog, and the per-client utility EMAs. Every round function in
the repo has the signature

    round_fn(state: FederationState, ...) -> (FederationState, stats)

so cross-round behaviour (FedAdam/FedYogi server updates, backlog
fairness, welfare selection, and later staggered/async cohorts) lives in
one seam that survives the jitted ``lax.scan`` driver and checkpoints as
one pytree.

Aggregation routes through `core.aggregation.aggregate_delta`: the whole
client-stacked delta pytree fuses into one [C, M_total] buffer and hits
the `fedagg` kernel once per round (`FedConfig.use_pallas` selects the
Pallas TPU kernel; `agg_dtype` casts client deltas on the wire). The
aggregated delta then feeds the decorator-registered ServerOptimizer
(`FedConfig.server_opt`: sgd | momentum | adam | yogi) via
`apply_server_opt` — immediately in the synchronous backends, or
`async_depth` rounds later through the in-flight buffer in `scan_async`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregation import (aggregate_delta, aggregator_key,
                                    apply_server_opt, check_aggregator_config,
                                    check_codec_config, flatten_stacked,
                                    get_aggregator, inclusion_mass,
                                    resolve_aggregator, resolve_wire_codec,
                                    server_optimizer)
from repro.core.alignment import epsilon_at, global_loss_from_locals
from repro.configs.base import register_validator, validate_config
from repro.optim.schedules import make_schedule
from repro.utils import Registry, fold_in_name, tree_axpy

BACKENDS = ("vmap_spatial", "scan_temporal", "scan_async")


# ============================================================ federation state
@dataclass
class FederationState:
    """Everything FedALIGN carries across the round boundary.

    A registered pytree: jit/scan carries, donation, and
    ``checkpoint/io.py`` all treat it as one tree. Leaf layout is fixed by
    the config (optimizer choice, client count), never by round-time data —
    the pytree-structure stability ``lax.scan`` requires.

    * ``params`` — global model parameters w_t.
    * ``opt_state`` — server-optimizer moments (shape set by
      ``fed.server_opt``: ``()`` for sgd, FedAvgM momentum tree,
      adam/yogi m/v/t).
    * ``backlog`` — [C] int32 rounds each client has been dropped by
      ``max_cohort`` overflow since it last aggregated; wins cohort ties.
    * ``util_ema`` — [C] f32 EMA of the alignment gap |F_k(w_t) - F(w_t)|
      (decay ``fed.utility_ema``), the welfare strategy's utility signal.
    * ``incl_ema`` — [C] f32 EMA of the effective inclusion gates — the
      cross-round participation share welfare fairness reads.
    * ``inflight`` — the ``scan_async`` in-flight cohort buffer, or ``()``
      when ``fed.async_depth == 0``. A dict of three leaves:
      ``inflight["delta"]`` stacks the D = ``fed.async_depth`` aggregated
      cohort deltas awaiting application (params-shaped leaves with a
      leading [D] axis, wire dtype ``fed.agg_dtype``, oldest at index 0),
      ``inflight["valid"]`` is the [D] f32 occupancy mask (valid slots are
      a PREFIX: 0 once the slot has been popped or never filled), and
      ``inflight["age"]`` is the [D] i32 per-slot age — rounds the slot's
      delta has waited since it was pushed. Ages are nonincreasing along
      the ring (slot 0 is oldest), which is what lets the readiness pop
      compact the buffer with one roll.
    * ``last_delta`` — [``fed.sketch_dim``] f32 CountSketch of the most
      recent delta that actually LANDED (nonzero post-clamp scale;
      ``delta_sketch`` under the fixed ``drift_sketch_key`` projection),
      or ``()`` unless ``fed.adaptive_staleness`` asks for drift-measured
      discounts. Kept as a sketch so the extra cross-round state is
      sketch_dim-sized, never params-sized.
    * ``latency`` — the event-driven clock's per-client completion-time
      leaves (``{"compute": [C] f32, "net": [C] f32}``, round units, drawn
      ONCE by ``init_latency``), or ``()`` when ``fed.latency_mode ==
      "none"``. With the clock on, the in-flight dict gains a fourth leaf
      ``inflight["timer"]`` ([D] i32): each slot's countdown, set at push
      time by its slowest surviving member (``slot_timer``) and capped at
      ``ceil(fed.round_deadline)`` — the slot lands when it expires.
    * ``nonfinite_skips`` — scalar i32 count of CONSECUTIVE rounds the
      divergence guard skipped on a non-finite aggregate (reset to 0 by
      any finite round), or ``()`` when ``fed.divergence_guard`` is off.
    * ``ef_accum`` — the per-client error-feedback accumulators of the
      wire codec (``core/aggregation``'s WireCodec registry): params-
      shaped f32 leaves with a leading [C] client axis, each row carrying
      the compression residual x - decode(encode(x)) of that client's
      LAST transmitted delta, re-added to its next delta before encoding.
      ``()`` unless ``fed.wire_codec`` is non-identity AND
      ``fed.error_feedback`` — disabled configs keep the exact legacy
      leaf layout. A row advances when its client's delta is ENCODED
      (push time under ``scan_async``, where aggregation runs at push —
      not when the buffered delta lands), and only with a finite
      residual (a corrupted NaN delta must not poison the accumulator).
    """
    params: Any
    opt_state: Any
    backlog: Any
    util_ema: Any
    incl_ema: Any
    inflight: Any = ()
    last_delta: Any = ()
    latency: Any = ()
    nonfinite_skips: Any = ()
    ef_accum: Any = ()

    def replace(self, **kw) -> "FederationState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    FederationState,
    data_fields=["params", "opt_state", "backlog", "util_ema", "incl_ema",
                 "inflight", "last_delta", "latency", "nonfinite_skips",
                 "ef_accum"],
    meta_fields=[])


@register_validator("async")
def check_async_config(fed):
    """Validate the scan_async knobs whose bad values would corrupt the
    in-flight buffer silently (clamped indices) instead of failing.

    Registered as the ``validate_config`` "async" hook; calling it directly
    is deprecated — call ``repro.configs.base.validate_config(fed)``, the
    one entry point that runs every subsystem's checks."""
    if fed.async_depth <= 0:
        return
    if fed.async_mode not in ("fifo", "ready"):
        raise ValueError(f"unknown FedConfig.async_mode {fed.async_mode!r}; "
                         "known: 'fifo' (fixed-lag pipe) | 'ready' "
                         "(variable-lag readiness buffer)")
    if fed.async_mode == "ready" and not 1 <= fed.min_lag <= fed.async_depth:
        raise ValueError(
            f"FedConfig.min_lag={fed.min_lag} outside [1, async_depth="
            f"{fed.async_depth}]: a delta can never age past the buffer "
            "capacity (no slot would ever become ready), and it can never "
            "pop before its first birthday either — the push happens after "
            "the pop phase, so min_lag=0 would silently behave as 1")


@register_validator("clock")
def check_clock_config(fed):
    """Validate the event-clock / deadline / failure-model knobs whose bad
    values would otherwise corrupt rounds silently — a zero or negative
    deadline marks every client late and force-lands every slot with no
    finished members, a rate outside [0, 1] draws garbage Bernoullis.
    Same contract as ``check_async_config``: actionable errors at the
    engine boundary, no-op when everything is disabled. Registered as the
    ``validate_config`` "clock" hook; direct calls are deprecated."""
    lm = fed.latency_mode
    if lm not in ("none", "lognormal"):
        raise ValueError(f"unknown FedConfig.latency_mode {lm!r}; known: "
                         "'none' (no event clock) | 'lognormal' "
                         "(per-client compute + network time draws)")
    if lm != "none":
        if fed.latency_sigma < 0 or fed.latency_net_sigma < 0:
            raise ValueError(
                f"FedConfig.latency_sigma={fed.latency_sigma} / "
                f"latency_net_sigma={fed.latency_net_sigma} must be >= 0 "
                "(they are lognormal log-stds)")
        if fed.async_depth > 0 and fed.async_mode != "ready":
            raise ValueError(
                "the event-driven clock gives every in-flight slot its OWN "
                "countdown (variable lag); async_mode='fifo' constant-folds "
                f"a fixed lag of async_depth={fed.async_depth} rounds and "
                "would ignore the timers — use async_mode='ready'")
    deadline = float(fed.round_deadline)
    if deadline != float("inf"):
        if not deadline > 0:
            raise ValueError(
                f"FedConfig.round_deadline={fed.round_deadline} must be > 0 "
                "(round units): at a zero or negative deadline EVERY client "
                "is late, so every slot would force-land with no finished "
                "members' mass — disable the deadline with float('inf')")
        if lm == "none":
            raise ValueError(
                "FedConfig.round_deadline compares per-client simulated "
                "completion times against the deadline, but "
                "latency_mode='none' draws no completion times — set "
                "latency_mode='lognormal' (or leave round_deadline=inf)")
    name = resolve_failure_model(fed.failure_model)
    if name != "none":
        get_failure_model(name)            # unknown names raise here
        for knob in ("crash_rate", "dropout_rate", "corrupt_rate"):
            v = float(getattr(fed, knob))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FedConfig.{knob}={v} outside [0, 1] "
                                 "(a per-client probability)")
        if int(fed.dropout_len) < 1:
            raise ValueError(
                f"FedConfig.dropout_len={fed.dropout_len} must be >= 1 "
                "(rounds per transient drop-out window)")
    if int(fed.max_nonfinite_skips) < 0:
        raise ValueError(
            f"FedConfig.max_nonfinite_skips={fed.max_nonfinite_skips} must "
            "be >= 0 (0 = the divergence guard never halts the run)")


def init_latency(fed, num_clients):
    """Per-client completion-time leaves for the event-driven clock, or
    ``()`` when ``fed.latency_mode == "none"`` (layout fixed by config).

    Drawn ONCE per federation from a named stream off the config seed (the
    main round PRNG chain is untouched): lognormal compute time plus
    lognormal network time, in round units — the systems-heterogeneity
    model of the client-selection survey (arXiv:2211.01549)."""
    if fed.latency_mode == "none":
        return ()
    key = fold_in_name(jax.random.PRNGKey(fed.seed), "latency_model")
    kc, kn = jax.random.split(key)
    C = int(num_clients)
    compute = jnp.exp(fed.latency_mu + fed.latency_sigma
                      * jax.random.normal(kc, (C,), jnp.float32))
    net = jnp.exp(fed.latency_net_mu + fed.latency_net_sigma
                  * jax.random.normal(kn, (C,), jnp.float32))
    return {"compute": compute, "net": net}


def init_inflight(params, fed):
    """Empty in-flight cohort ring buffer for ``fed.async_depth`` (D) slots,
    or ``()`` at depth 0 (synchronous runs carry no extra leaves).

    Leaf layout is fixed by the CONFIG (depth, params shapes, wire dtype,
    and — for the ``timer`` leaf — the latency mode) — the pytree-structure
    stability the scanned driver and checkpoint round-trips require."""
    D = int(fed.async_depth)
    if D <= 0:
        return ()
    ad = jnp.dtype(fed.agg_dtype)
    buf = {
        "delta": jax.tree.map(
            lambda p: jnp.zeros((D,) + tuple(p.shape), ad), params),
        "valid": jnp.zeros((D,), jnp.float32),
        "age": jnp.zeros((D,), jnp.int32),
    }
    if fed.latency_mode != "none":
        # event-driven clock: per-slot countdown (rounds until the slot's
        # slowest surviving member finishes), set at push by slot_timer
        buf["timer"] = jnp.zeros((D,), jnp.int32)
    return buf


def init_last_delta(fed):
    """Zero reference sketch for the drift-adaptive discount, or ``()``
    when ``adaptive_staleness`` is off (layout fixed by the config)."""
    if fed.async_depth > 0 and fed.adaptive_staleness:
        return jnp.zeros((int(fed.sketch_dim),), jnp.float32)
    return ()


def init_ef_accum(params, fed, num_clients):
    """Zero per-client error-feedback accumulators for the wire codec
    (params-shaped f32 leaves with a leading [C] client axis), or ``()``
    when the codec is identity or ``fed.error_feedback`` is off — layout
    fixed by the CONFIG, like every other FederationState leaf."""
    if resolve_wire_codec(getattr(fed, "wire_codec", "identity")) == "identity":
        return ()
    if not fed.error_feedback:
        return ()
    C = int(num_clients)
    return jax.tree.map(
        lambda p: jnp.zeros((C,) + tuple(p.shape), jnp.float32), params)


def init_state(params, fed, num_clients: Optional[int] = None) -> FederationState:
    """Fresh FederationState for a federation of ``num_clients`` (defaults
    to ``fed.num_clients``): zero moments, zero backlog, zero EMAs, and an
    empty in-flight buffer (plus zero drift-reference sketch under
    ``adaptive_staleness``) when ``fed.async_depth > 0``. Latency leaves
    (event clock), the divergence-guard skip counter, and the wire codec's
    error-feedback accumulators exist only when their feature is enabled —
    disabled configs keep the exact legacy leaf layout."""
    validate_config(fed)
    C = int(num_clients if num_clients is not None else fed.num_clients)
    return FederationState(
        params=params,
        opt_state=server_optimizer(fed).init(params),
        backlog=jnp.zeros((C,), jnp.int32),
        util_ema=jnp.zeros((C,), jnp.float32),
        incl_ema=jnp.zeros((C,), jnp.float32),
        inflight=init_inflight(params, fed),
        last_delta=init_last_delta(fed),
        latency=init_latency(fed, C),
        nonfinite_skips=(jnp.zeros((), jnp.int32) if fed.divergence_guard
                         else ()),
        ef_accum=init_ef_accum(params, fed, C))


# ============================================================ selection seam
@dataclass
class SelectionContext:
    """Everything a SelectionStrategy may look at for one round.

    align_vals/global_align are the paper's matching statistic (losses by
    theory, accuracies in the experiments — fed.align_stat). delta_cos is
    only populated when the strategy declares ``needs_deltas`` (it costs a
    [C, M_total] flatten of the client updates, or a CountSketch of them
    under ``fed.grad_sim_sketch``). The cross-round fields
    (backlog/util_ema/incl_ema) come from FederationState: ``util_ema``
    is the BIAS-CORRECTED smoothed gap with THIS round's observation
    already folded in (``utility_estimate``); ``incl_ema`` and
    ``backlog`` describe previous rounds only (gates aren't fixed yet)."""
    align_vals: Any                    # [C] F_k(w_t) (or acc_k(w_t))
    global_align: Any                  # scalar F(w_t)
    eps: Any                           # scalar eps_t
    priority_mask: Any                 # [C] bool
    weights: Any = None                # [C] data fractions p_k
    participation: Any = None          # [C] bool availability, or None
    warmup: Any = False                # scalar bool: inside warm-up rounds
    delta_cos: Any = None              # [C] cosine(delta_k, delta_P)
    topk: int = 4                      # topk_align budget
    sim_threshold: float = 0.0         # grad_sim cosine threshold
    backlog: Any = None                # [C] int32 overflow backlog (state)
    util_ema: Any = None               # [C] bias-corrected loss-gap EMA
                                       # incl. this round's observation
    incl_ema: Any = None               # [C] inclusion EMA (prev. rounds)
    welfare_floor: float = 0.0         # welfare fairness floor on incl_ema


STRATEGIES = Registry("selection strategy")


def register_strategy(name: str, *, needs_deltas: bool = False,
                      warmup_excludes_nonpriority: bool = True):
    """Register ``fn(ctx: SelectionContext) -> [C] float32`` under ``name``.

    The function returns the inclusion vector for NON-priority clients;
    its values at priority positions are ignored. ``needs_deltas`` asks the
    backend to populate ``ctx.delta_cos``. ``warmup_excludes_nonpriority``
    controls whether warm-up rounds force priority-only aggregation (True
    for alignment-style rules; False for the unconditional ``all``)."""
    return STRATEGIES.register(
        name, strategy_name=name, needs_deltas=needs_deltas,
        warmup_excludes_nonpriority=warmup_excludes_nonpriority)


def get_strategy(name: str) -> Callable:
    return STRATEGIES.lookup(name)


@register_strategy("fedalign")
def _fedalign(ctx):
    return (jnp.abs(ctx.align_vals - ctx.global_align) < ctx.eps).astype(jnp.float32)


@register_strategy("all", warmup_excludes_nonpriority=False)
def _all(ctx):
    return jnp.ones(ctx.priority_mask.shape, jnp.float32)


@register_strategy("priority_only")
def _priority_only(ctx):
    return jnp.zeros(ctx.priority_mask.shape, jnp.float32)


@register_strategy("topk_align")
def _topk_align(ctx):
    C = ctx.align_vals.shape[0]
    k = int(ctx.topk)
    if k <= 0:
        return jnp.zeros((C,), jnp.float32)
    diff = jnp.abs(ctx.align_vals - ctx.global_align)
    cand = ~ctx.priority_mask.astype(bool)
    if ctx.participation is not None:
        cand = cand & ctx.participation.astype(bool)
    ranked = jnp.where(cand, diff, jnp.inf)
    kth = jnp.sort(ranked)[min(k, C) - 1]
    return ((ranked <= kth) & (ranked < ctx.eps)).astype(jnp.float32)


@register_strategy("grad_sim", needs_deltas=True)
def _grad_sim(ctx):
    if ctx.delta_cos is None:
        raise ValueError("grad_sim needs ctx.delta_cos (client-update cosine "
                         "similarities); this backend did not provide deltas")
    return (ctx.delta_cos >= ctx.sim_threshold).astype(jnp.float32)


@register_strategy("welfare")
def _welfare(ctx):
    """Welfare/fairness-aware selection (Travadi et al., arXiv:2302.08976):
    include non-priority client k when its SMOOTHED alignment gap (the
    loss-gap EMA, utility of including k for the priority objective) is
    inside the eps band, or when its inclusion EMA has starved below the
    fairness floor. utility_ema=0 degenerates to plain fedalign."""
    if ctx.util_ema is None or ctx.incl_ema is None:
        raise ValueError(
            "welfare needs ctx.util_ema/ctx.incl_ema (cross-round client "
            "utility EMAs from FederationState); this caller is stateless — "
            "thread a FederationState through the round")
    aligned = ctx.util_ema < ctx.eps
    starved = ctx.incl_ema < ctx.welfare_floor
    return (aligned | starved).astype(jnp.float32)


def compute_gates(ctx: SelectionContext, selection: str = "fedalign"):
    """I_{k,t} per client — THE shared gating implementation.

    Priority clients are always included; the strategy decides non-priority
    inclusion; warm-up (strategy-dependent) and participation sampling are
    applied on top."""
    strat = get_strategy(selection)
    pri = ctx.priority_mask.astype(jnp.float32)
    gates = pri + (1.0 - pri) * strat(ctx)
    if strat.warmup_excludes_nonpriority:
        gates = jnp.where(jnp.asarray(ctx.warmup), pri, gates)
    if ctx.participation is not None:
        gates = gates * ctx.participation.astype(jnp.float32)
    return gates


def cosine_to_priority(flat_deltas, weights, priority_mask):
    """[C, M] client deltas -> [C] cosine vs the priority-weighted mean delta
    (the grad_sim statistic; f32 accumulation regardless of input dtype)."""
    f = flat_deltas.astype(jnp.float32)
    wp = weights.astype(jnp.float32) * priority_mask.astype(jnp.float32)
    d_pri = jnp.einsum("c,cm->m", wp, f) / jnp.maximum(jnp.sum(wp), 1e-30)
    dots = f @ d_pri
    norms = jnp.sqrt(jnp.sum(f * f, axis=1)) * jnp.sqrt(jnp.sum(d_pri * d_pri))
    return dots / jnp.maximum(norms, 1e-12)


def cohort_select(gates, align_vals, global_align, priority_mask, k: int,
                  backlog=None, backlog_boost=0.0):
    """Deterministic gather order for the gate-before-train cohort.

    Returns (cohort_idx [K], cohort_gates [K], effective_gates [C]).

    Slots are filled included-first: priority clients, then included
    non-priority clients ranked by alignment match |F_k - F|, then excluded
    clients as zero-gate padding (their slot trains but is dropped by the
    aggregation's gate weighting). Overflow policy — more than K clients
    gate in — drops the WORST-matched non-priority clients this round.
    ``backlog`` ([C] rounds spent dropped by overflow, from
    FederationState) breaks match-quality ties: at equal |F_k - F| the
    longer-starved client wins the slot, so overflow rotates instead of
    permanently starving the same well-aligned clients. At backlog 0 (or
    ``backlog=None``) ties fall back to client index — the original
    drop-worst policy, unchanged. ``backlog_boost`` > 0 promotes backlog
    from tie-breaker to rank term: a non-priority client's rank becomes
    ``|F_k - F| - backlog_boost * backlog``, so a starved client overtakes
    slightly BETTER-matched rivals once its debt grows — float-valued
    match gaps almost never tie exactly, so the pure tie-break cannot
    rotate those cohorts. Priority clients pin to the front regardless of
    any boost; ``backlog_boost=0`` (the default) is bit-identical to the
    tie-break-only policy. ``effective_gates`` is the [C] inclusion
    vector the aggregation actually honours (== ``gates`` when nothing
    overflowed)."""
    pri = priority_mask.astype(bool)
    C = gates.shape[0]
    diff = jnp.abs(align_vals - global_align).astype(jnp.float32)
    bl = (jnp.zeros((C,), jnp.float32) if backlog is None
          else backlog.astype(jnp.float32))
    boost = float(backlog_boost)
    if boost != 0.0:
        # boosted rank: backlog debt buys down the match gap. Priority
        # moves from -1.0 to -inf so no boosted non-priority rank (which
        # can go arbitrarily negative) can ever displace a priority client.
        rank = jnp.where(pri, -jnp.inf,
                         jnp.minimum(diff, 1e30) - jnp.float32(boost) * bl)
    else:
        # python-level branch on the float literal: the boost-off trace is
        # LITERALLY the legacy trace (bit-identity pinned by tests)
        rank = jnp.where(pri, -1.0, jnp.minimum(diff, 1e30))
    key = jnp.where(gates > 0, rank, jnp.inf)
    # lexicographic: (boosted) rank, then backlog (older debts first), then
    # client index — deterministic and identical to the stable argsort of
    # ``key`` whenever every backlog is 0
    order = jnp.lexsort((jnp.arange(C), -bl, key))
    cohort_idx = order[:k]
    cohort_gates = gates[cohort_idx]
    eff_gates = jnp.zeros_like(gates).at[cohort_idx].set(cohort_gates)
    return cohort_idx, cohort_gates, eff_gates


def backlog_update(backlog, gates, eff_gates):
    """Cross-round overflow-fairness ledger: +1 for every client that gated
    in but lost its slot to the cohort budget, reset for clients the
    aggregation honoured, untouched for clients the selection excluded."""
    dropped = (gates > 0) & (eff_gates == 0)
    included = eff_gates > 0
    return jnp.where(dropped, backlog + 1,
                     jnp.where(included, jnp.zeros_like(backlog), backlog))


def utility_update(fed, util_ema, align_vals, global_align):
    """Loss-gap EMA step (decay ``fed.utility_ema``) with this round's
    observation |F_k(w_t) - F(w_t)| folded in. The carried EMA is RAW
    (zero-initialized); consumers debias it with ``utility_estimate``."""
    beta = jnp.float32(fed.utility_ema)
    gap = jnp.abs(align_vals - global_align).astype(jnp.float32)
    return beta * util_ema + (1.0 - beta) * gap


def utility_estimate(fed, util_ema, round_idx):
    """Bias-corrected smoothed gap (adam-style 1 - beta^t divisor).

    The raw zero-initialized EMA UNDERestimates the gap for the first
    ~1/(1-beta) rounds, which would admit badly-misaligned clients into
    the welfare gate early in training; the EMA has been updated
    ``round_idx + 1`` times when the gate reads it (every round updates
    it, warm-up included), so the correction is exact."""
    beta = jnp.float32(fed.utility_ema)
    t = jnp.asarray(round_idx, jnp.float32) + 1.0
    return util_ema / jnp.maximum(1.0 - beta ** t, 1e-12)


def inclusion_update(fed, incl_ema, eff_gates):
    """Inclusion-history EMA step over the EFFECTIVE gates (what the
    aggregation honoured, overflow included)."""
    beta = jnp.float32(fed.utility_ema)
    return beta * incl_ema + (1.0 - beta) * eff_gates.astype(jnp.float32)


def server_delta(fed, global_params, client_params, weights, gates, *,
                 key=None, ef_accum=None):
    """(6a) renormalized gated delta aggregation: one fused fedagg on the
    gated client deltas, honouring ``fed.agg_dtype``'s reduced-precision
    wire format, WITHOUT the ServerOptimizer step. The synchronous round
    applies the result immediately (``apply_server_opt``); the
    ``scan_async`` round pushes it into the in-flight buffer instead
    (``async_apply``) — the reduction runs at PUSH time, so every
    registered ``fed.aggregator`` (robust, dp, cosine-filtered) commutes
    with the buffer for free. ``key`` feeds stochastic aggregators
    (``aggregator_key(fed, round_idx)`` for dp noise).
    ``client_params``/``weights``/``gates`` may live in cohort space
    [K, ...]: zero gates drop padding slots, so the result matches the
    dense [C, ...] aggregation whenever every included client made the
    cohort. With a non-identity ``fed.wire_codec`` and ``ef_accum`` (the
    matching per-client error-feedback rows, cohort-gathered when
    ``client_params`` is) the call returns ``(delta, new_ef_accum)`` —
    because this runs at push time, scan_async's accumulator advances
    when the delta is encoded, not when it lands. THE aggregation-routing
    seam — the sharded pod rounds call it too
    (core/aggregation.aggregate_delta)."""
    return aggregate_delta(global_params, client_params, weights, gates,
                           fed=fed, key=key, ef_accum=ef_accum)


def staleness_discount(fed, age=None):
    """Scale applied to a delta that waited in the in-flight buffer.

    With ``age=None`` (the fifo pipe, where every applied delta aged
    exactly ``fed.async_depth`` rounds) the discount is the compile-time
    python constant ``staleness_decay ** async_depth`` — the PR 4
    semantics, kept constant-folded so the fifo path stays bit-identical.
    With a (traced) ``age`` it is the measured-staleness discount
    ``staleness_decay ** age`` the variable-lag ``ready`` mode uses."""
    if age is None:
        return float(fed.staleness_decay) ** int(fed.async_depth)
    return jnp.float32(fed.staleness_decay) ** age.astype(jnp.float32)


def drift_sketch_key(fed):
    """The ONE projection key for every drift sketch of a run.

    Unlike ``sketch_key`` (grad_sim folds the round index in — each round
    scores clients against each other, never across rounds), drift sketches
    are compared ACROSS rounds (this pop's delta vs the last applied one),
    so every sketch of the run must use the same CountSketch projection or
    their cosine estimates nothing. Derived via ``fold_in_name`` (crc32),
    so the stream is deterministic across processes."""
    from repro.utils import fold_in_name
    return fold_in_name(jax.random.PRNGKey(fed.seed), "async_drift_sketch")


def drift_factor(sketch, last_sketch):
    """max(0, cos(delta, last applied delta)) estimated on CountSketches.

    The clamp at 0 means a stale delta pointing AWAY from where the model
    is currently moving is dropped entirely rather than applied negatively.
    Before any delta has been applied the reference sketch is all-zero —
    no drift evidence — and the factor falls back to 1 (the constant
    schedule alone)."""
    dot = jnp.vdot(sketch.astype(jnp.float32), last_sketch.astype(jnp.float32))
    n_last = jnp.sqrt(jnp.sum(last_sketch.astype(jnp.float32) ** 2))
    n_new = jnp.sqrt(jnp.sum(sketch.astype(jnp.float32) ** 2))
    cos = dot / jnp.maximum(n_new * n_last, 1e-12)
    return jnp.where(n_last > 0, jnp.maximum(cos, 0.0), 1.0)


def _apply_stale(fed, carry, delta, age):
    """Apply ONE popped in-flight delta through the ServerOptimizer with
    its staleness scale. ``carry = (params, opt_state, last_delta)``; runs
    inside ``lax.cond`` on the slot's readiness, so non-popping rounds
    leave params, moments (adam's t included), and the drift reference
    untouched."""
    params, opt_state, last = carry
    # fifo: every pop has aged exactly async_depth rounds -> the python-
    # constant discount (bit-identical to the PR 4 pipeline). ready: the
    # slot's measured age.
    scale = (staleness_discount(fed) if fed.async_mode == "fifo"
             else staleness_discount(fed, age))
    if fed.adaptive_staleness:
        sk = delta_sketch(delta, drift_sketch_key(fed), int(fed.sketch_dim))
        scale = scale * drift_factor(sk, last)
        # the reference advances only when the delta actually moved the
        # model (scale > 0) — raw sketch, direction not scale. A clamped
        # delta must NOT become the reference: with an oscillating stream
        # (+d, -d, +d, ...) it would flip the reference each pop and zero
        # every later update, freezing training while stats still report
        # pops; keeping the last LANDED direction damps the oscillation
        # and lets aligned deltas through.
        last = jnp.where(scale > 0, sk, last)
        # a fully-clamped pop is DROPPED, optimizer included: scale 0
        # through apply_server_opt would still decay momentum (moving
        # params along the stale residual) and tick adam's t — the same
        # moments-untouched invariant warm-up rounds honour applies here
        new_params, new_opt = jax.lax.cond(
            scale > 0,
            lambda s: apply_server_opt(fed, params, opt_state, delta,
                                       scale=s),
            lambda s: (params, opt_state),
            scale)
        return new_params, new_opt, last
    new_params, new_opt = apply_server_opt(fed, params, opt_state, delta,
                                           scale=scale)
    return new_params, new_opt, last


def async_apply(fed, global_params, opt_state, inflight, agg_delta,
                last_delta=(), push_timer=None):
    """One tick of the scan_async application state machine.

    1. Every valid slot ages one round (and, under the event clock, its
       countdown timer ticks down one round).
    2. The READY slots are popped oldest-first and each applied through the
       configured ServerOptimizer with its own staleness scale
       (``_apply_stale``), under ``lax.cond`` per slot — rounds where
       nothing is ready (pipeline warm-up) leave params AND optimizer
       moments untouched. Readiness: ``async_mode="fifo"`` — the slot that
       aged exactly ``async_depth`` rounds (at most one per round, the
       strict PR 4 pipe); ``"ready"`` — every slot whose age reached
       ``min_lag`` (prefix of the ring, possibly several per round); with
       the EVENT CLOCK (``fed.latency_mode != "none"``, the buffer carries
       a ``timer`` leaf) — every slot whose countdown expired, an
       arbitrary subset of the ring since timers are set per slot by the
       cohort's slowest surviving member. A FULL buffer with no ready slot
       force-pops the oldest (the FedBuff overflow rule) so the fresh
       delta always has a slot.
    3. The buffer compacts (one roll for the prefix pops; a stable
       permutation under the clock, where the ready set need not be a
       prefix) and this round's fresh ``agg_delta`` is pushed behind the
       survivors at age 0 — with its countdown set to ``push_timer``
       (``slot_timer``; REQUIRED when the buffer is clocked).

    Returns ``(new_params, new_opt_state, new_inflight, new_last_delta,
    info)`` with ``info = {"applied_valid": popped count (f32),
    "applied_age": oldest applied age (i32, 0 when nothing landed)}``.
    The buffer leaves keep their config-fixed [D, ...] shapes, so the
    whole transition is a legal ``lax.scan`` carry step."""
    valid = inflight["valid"] > 0
    D = int(valid.shape[0])
    age = inflight["age"] + valid.astype(jnp.int32)
    occ = jnp.sum(valid.astype(jnp.int32))
    carry = (global_params, opt_state, last_delta)
    clocked = "timer" in inflight
    if clocked:
        if push_timer is None:
            raise ValueError(
                "this in-flight buffer carries countdown timers "
                "(latency_mode != 'none') but no push_timer was given — "
                "compute one with slot_timer(fed, state.latency, gates)")
        timer = jnp.maximum(inflight["timer"] - valid.astype(jnp.int32), 0)
        # event-driven readiness: a slot lands when its countdown expires,
        # not when it crosses a uniform min_lag — so the ready set is an
        # arbitrary subset of the ring, not a prefix
        ready = valid & (timer <= 0)
        force = (occ >= D) & (jnp.sum(ready.astype(jnp.int32)) == 0)
        ready = ready.at[0].set(ready[0] | force)
        for i in range(D):                 # static unroll: D is small
            delta_i = jax.tree.map(lambda b, i=i: b[i], inflight["delta"])
            carry = jax.lax.cond(
                ready[i],
                lambda c, d=delta_i, i=i: _apply_stale(fed, c, d, age[i]),
                lambda c: c,
                carry)
    elif fed.async_mode == "fifo":
        # single-pop pipe: at most slot 0 can ever be ready (one push per
        # round keeps ages distinct), so the trace holds ONE conditional
        # optimizer apply — not D unrolled copies. The occ >= D term is
        # the same capacity guard the ready branch's force-pop provides.
        ready = jnp.zeros((D,), bool).at[0].set(
            valid[0] & ((age[0] >= int(fed.async_depth)) | (occ >= D)))
        delta0 = jax.tree.map(lambda b: b[0], inflight["delta"])
        carry = jax.lax.cond(
            ready[0],
            lambda c: _apply_stale(fed, c, delta0, age[0]),
            lambda c: c,
            carry)
    else:
        thr = int(fed.min_lag)
        # prefix-closed readiness: ages are nonincreasing along the ring,
        # so "every slot with age >= thr" IS a prefix — the cumprod makes
        # that robust to hand-built states instead of assuming it
        ready = jnp.cumprod((valid & (age >= thr)).astype(jnp.int32)) > 0
        force = (occ >= D) & ~ready[0] & valid[0]
        ready = ready.at[0].set(ready[0] | force)
        for i in range(D):                 # static unroll: D is small
            delta_i = jax.tree.map(lambda b, i=i: b[i], inflight["delta"])
            carry = jax.lax.cond(
                ready[i],
                lambda c, d=delta_i, i=i: _apply_stale(fed, c, d, age[i]),
                lambda c: c,
                carry)
    new_params, new_opt, new_last = carry

    k = jnp.sum(ready.astype(jnp.int32))
    pos = occ - k                          # fresh delta lands behind survivors
    idx = jnp.arange(D)

    if clocked:
        # the ready set need not be a prefix, so compaction is a stable
        # permutation — survivors first in original (push) order — instead
        # of the roll the prefix modes use
        keep = valid & ~ready
        perm = jnp.argsort(jnp.where(keep, idx, idx + D))

        def gather_push(buf, d):
            return jax.lax.dynamic_update_slice_in_dim(
                jnp.take(buf, perm, axis=0), d.astype(buf.dtype)[None], pos,
                axis=0)

        survivor_timer = jnp.where(idx < pos, jnp.take(timer, perm), 0)
        new_inflight = {
            "delta": jax.tree.map(gather_push, inflight["delta"], agg_delta),
            "valid": (idx <= pos).astype(jnp.float32),
            "age": jnp.where(idx < pos, jnp.take(age, perm), 0),
            "timer": jnp.where(idx == pos,
                               jnp.asarray(push_timer, jnp.int32),
                               survivor_timer),
        }
    else:
        def shift_push(buf, d):
            return jax.lax.dynamic_update_slice_in_dim(
                jnp.roll(buf, -k, axis=0), d.astype(buf.dtype)[None], pos,
                axis=0)

        new_inflight = {
            "delta": jax.tree.map(shift_push, inflight["delta"], agg_delta),
            "valid": (idx <= pos).astype(jnp.float32),
            "age": jnp.where(idx < pos, jnp.roll(age, -k), 0),
        }
    info = {"applied_valid": k.astype(jnp.float32),
            "applied_age": jnp.max(jnp.where(ready, age, 0))}
    return new_params, new_opt, new_inflight, new_last, info


def drain_inflight(fed, state: FederationState) -> FederationState:
    """Flush a scan_async pipeline at end of run: apply every still-valid
    in-flight cohort delta oldest-first through the ServerOptimizer — each
    with the discount it would have received in-stream (the constant
    ``staleness_decay ** async_depth`` under fifo, its measured age under
    ``ready``, times the drift factor under ``adaptive_staleness``) — and
    return the state with an emptied buffer. A real async server does
    exactly this at shutdown — straggler cohorts are absorbed, not
    dropped. No-op for synchronous states (``inflight == ()``)."""
    if not isinstance(state.inflight, dict):
        return state
    valid = state.inflight["valid"]
    age = state.inflight["age"]
    carry = (state.params, state.opt_state, state.last_delta)
    D = int(valid.shape[0])
    for i in range(D):                     # static unroll: D is small
        delta_i = jax.tree.map(lambda b, i=i: b[i], state.inflight["delta"])
        carry = jax.lax.cond(
            valid[i] > 0,
            lambda c, d=delta_i, i=i: _apply_stale(fed, c, d, age[i]),
            lambda c: c,
            carry)
    params, opt_state, last = carry
    # zeroing the whole dict keeps whatever leaves the config gave the
    # buffer (the event clock's "timer" leaf included) — layout-stable
    empty = jax.tree.map(jnp.zeros_like, state.inflight)
    return state.replace(params=params, opt_state=opt_state, inflight=empty,
                         last_delta=last)


def delta_sketch(delta, key, dim: int):
    """[dim] CountSketch (sparse Johnson-Lindenstrauss) of a parameter-delta
    pytree: every coordinate lands in one random bucket with a random sign.

    One O(M) pass, no [dim, M] projection matrix is ever materialized — the
    streaming-friendly delta score for grad_sim. The hash/sign streams
    derive from ``key`` and the leaf index only, so every client is
    projected identically and sketched cosines estimate the true delta
    cosines (error ~ 1/sqrt(dim))."""
    out = jnp.zeros((dim,), jnp.float32)
    for i, leaf in enumerate(jax.tree.leaves(delta)):
        x = leaf.reshape(-1).astype(jnp.float32)
        kh, ks = jax.random.split(jax.random.fold_in(key, i))
        h = jax.random.randint(kh, (x.size,), 0, dim)
        s = jax.random.rademacher(ks, (x.size,), dtype=jnp.float32)
        out = out + jax.ops.segment_sum(s * x, h, num_segments=dim)
    return out


def sketch_key(fed, round_idx):
    """Per-round projection key — shared by every client (and by both
    backends, so sketched rounds stay backend-identical)."""
    return jax.random.fold_in(jax.random.PRNGKey(fed.seed ^ 0x5E7C), round_idx)


def participation_mask(fed, key, priority_mask, round_idx, client_ids=None):
    """Paper App. C.3 / A.4: Bernoulli participation sampling (priority set
    never empty) plus straggler cadence (non-priority client k joins every
    2 + k % period rounds).

    ``client_ids`` carries a candidate-pool round's [P] global identities:
    the Bernoulli draw keys on the identity (``fold_in``) and the
    straggler cadence uses the GLOBAL client index, so a client's
    availability schedule is the same whichever pool it got sampled into.
    Dense rounds (``client_ids=None``) keep the legacy shaped draw —
    bit-identical trace."""
    C = priority_mask.shape[0]
    ids = jnp.arange(C) if client_ids is None else client_ids
    if fed.participation < 1.0:
        part = _identity_bernoulli(key, fed.participation, C, client_ids)
        part = part | (jnp.sum(part & priority_mask) == 0) & priority_mask
    else:
        part = jnp.ones((C,), bool)
    if fed.straggler_period > 0:
        cadence = 2 + ids % fed.straggler_period
        available = (round_idx % cadence) == 0
        part = part & (available | priority_mask)
    return part


def pool_select(fed, key, priority_mask, backlog, incl_ema, pool: int):
    """Draw one round's candidate pool: [P] sorted global client indices.

    Priority clients are ALWAYS in-pool (score pinned at +inf); the
    remaining P - num_priority slots go to non-priority clients sampled
    WITHOUT replacement via the Gumbel-top-k trick — score = log(weight) +
    Gumbel noise, take the top P. ``fed.pool_weighting`` sets the weight:

      uniform — every non-priority client equally likely (weight 1)
      backlog — weight 1 + backlog_k: clients starved by cohort overflow
                get sampled back in sooner
      ema     — weight (1 + eps) - incl_ema_k: clients the aggregation has
                rarely honoured get a boost (welfare-style coverage)

    The returned indices are SORTED ascending, so the pool's index space
    is a stable, order-preserving slice of the dense one — the gather /
    scatter contract every pooled round relies on."""
    g = jax.random.gumbel(key, priority_mask.shape, jnp.float32)
    if fed.pool_weighting == "backlog":
        g = g + jnp.log1p(backlog.astype(jnp.float32))
    elif fed.pool_weighting == "ema":
        g = g + jnp.log(jnp.maximum(
            1.0 + 1e-6 - incl_ema.astype(jnp.float32), 1e-6))
    score = jnp.where(priority_mask.astype(bool), jnp.inf, g)
    _, idx = jax.lax.top_k(score, int(pool))
    return jnp.sort(idx)


# ============================================================ failure models
@dataclass
class FailurePlan:
    """One round's fault-injection views, produced by a registered
    FailureModel. A ``None`` field injects nothing — callers branch on
    None at python level, so the fault-free trace stays untouched.

    * ``available`` — [C] bool: clients present this round. Transient
      drop-outs fold into the participation mask, so selection never sees
      an absent client.
    * ``crashed`` — [C] bool: clients that trained but whose delta is LOST
      before aggregation — their slot mass is masked out (partial-cohort
      landing) and the backlog re-enqueues them so they win cohort ties
      when they return.
    * ``corrupt`` — [C] bool: clients whose delta is corrupted in transit
      (NaN'd or scaled rows, injected through the ``delta_transform``
      seam)."""
    available: Any = None
    crashed: Any = None
    corrupt: Any = None


FAILURE_MODELS = Registry("failure model", aliases={None: "none", "": "none"})


def register_failure_model(name: str):
    """Register ``fn(fed, key, round_idx, num_clients, client_ids=None) ->
    FailurePlan`` under ``name`` (decorator, like ``register_strategy`` /
    ``register_aggregator``). ``key`` is the round's failure stream
    (``failure_key``); models must draw ONLY from it (optionally split by
    ``fold_in_name``) so injected faults are bit-reproducible, resume-safe,
    and independent of the main round PRNG chain. ``client_ids`` carries
    the [P] global client identities of a candidate-pool round: with it,
    per-client draws must key on the IDENTITY (``jax.random.fold_in``), so
    a client's fault stream is independent of which pool it landed in."""
    return FAILURE_MODELS.register(name, failure_name=name)


def resolve_failure_model(name) -> str:
    """Canonical failure-model name: None/'' mean 'none' (disabled)."""
    return str(FAILURE_MODELS.resolve(name))


def get_failure_model(name) -> Callable:
    return FAILURE_MODELS.lookup(name)


def failure_key(fed, round_idx):
    """The round's fault-injection PRNG: a named stream off the config seed
    folded with the ABSOLUTE round index. Resuming at round r replays
    exactly the faults the uninterrupted run would have injected, and the
    main round rng chain never advances differently with faults on."""
    base = fold_in_name(jax.random.PRNGKey(fed.seed), "failure_model")
    return jax.random.fold_in(base, round_idx)


def failure_plan(fed, round_idx, num_clients, client_ids=None):
    """Evaluate the configured FailureModel for one round, or None when
    disabled (callers keep the fault-free trace untouched). With
    ``client_ids`` (a candidate-pool round's [P] global identities) the
    plan's masks live in POOL space, drawn per-identity so a client's
    fault stream does not depend on who else got sampled."""
    name = resolve_failure_model(fed.failure_model)
    if name == "none":
        return None
    return FAILURE_MODELS[name](fed, failure_key(fed, round_idx), round_idx,
                                int(num_clients), client_ids=client_ids)


@register_failure_model("none")
def _fm_none(fed, key, round_idx, num_clients, client_ids=None):
    return FailurePlan()


def _identity_bernoulli(key, rate, num_clients, client_ids):
    """[num_clients] Bernoulli draws. Dense rounds (``client_ids=None``)
    keep the legacy one-shot shaped draw (bit-identity); pool rounds key
    each draw on the client IDENTITY via ``fold_in``, so the draw for
    client k is the same whichever pool k landed in — O(P), never O(C)."""
    if client_ids is None:
        return jax.random.bernoulli(key, rate, (num_clients,))
    keys = jax.vmap(jax.random.fold_in, (None, 0))(key, client_ids)
    return jax.vmap(lambda k: jax.random.bernoulli(k, rate))(keys)


def _crashed_mask(fed, key, num_clients, client_ids=None):
    return _identity_bernoulli(fold_in_name(key, "crash"),
                               fed.crash_rate, num_clients, client_ids)


def _corrupt_mask(fed, key, num_clients, client_ids=None):
    return _identity_bernoulli(fold_in_name(key, "corrupt"),
                               fed.corrupt_rate, num_clients, client_ids)


def _dropout_available(fed, round_idx, num_clients, client_ids=None):
    # window-stateless draw: one Bernoulli per (window, client), a window
    # spanning dropout_len rounds — the SAME clients sit out every round
    # of the window, reproduced exactly from any resume point (and, under
    # pooling, whichever candidate pools the window's rounds sampled)
    window = round_idx // max(int(fed.dropout_len), 1)
    base = fold_in_name(jax.random.PRNGKey(fed.seed), "failure_dropout")
    k = jax.random.fold_in(base, window)
    return ~_identity_bernoulli(k, fed.dropout_rate, num_clients, client_ids)


@register_failure_model("crash")
def _fm_crash(fed, key, round_idx, num_clients, client_ids=None):
    """Per-round Bernoulli crash: the client trains, then dies before its
    delta reaches the server."""
    return FailurePlan(crashed=_crashed_mask(fed, key, num_clients,
                                             client_ids))


@register_failure_model("dropout")
def _fm_dropout(fed, key, round_idx, num_clients, client_ids=None):
    """Transient drop-out: clients disappear for whole ``dropout_len``-round
    windows (folded into the participation mask)."""
    return FailurePlan(
        available=_dropout_available(fed, round_idx, num_clients, client_ids))


@register_failure_model("corrupt")
def _fm_corrupt(fed, key, round_idx, num_clients, client_ids=None):
    """Delta corruption in transit: NaN'd (``corrupt_scale == 0``) or scaled
    rows, injected through the ``delta_transform`` seam."""
    return FailurePlan(corrupt=_corrupt_mask(fed, key, num_clients,
                                             client_ids))


@register_failure_model("chaos")
def _fm_chaos(fed, key, round_idx, num_clients, client_ids=None):
    """All three fault classes composed. Each draws from its own named
    substream, so chaos with two rates zeroed matches the remaining single
    model bit-for-bit."""
    return FailurePlan(
        available=_dropout_available(fed, round_idx, num_clients, client_ids),
        crashed=_crashed_mask(fed, key, num_clients, client_ids),
        corrupt=_corrupt_mask(fed, key, num_clients, client_ids))


def corruption_transform(fed, corrupt_mask):
    """Build the ``delta_transform`` that poisons the masked clients' trained
    params in transit: ``corrupt_scale == 0`` garbles the payload to NaN
    (what the divergence guard exists to catch); any other value scales the
    delta (a scaled-delta fault the robust aggregators can absorb)."""
    scale = float(fed.corrupt_scale)

    def tf(client_params, global_params, client_idx):
        m = corrupt_mask[client_idx]

        def leaf(cp, gp):
            mm = m.reshape(m.shape + (1,) * (cp.ndim - 1))
            bad = (jnp.full_like(cp, jnp.nan) if scale == 0.0
                   else gp[None] + scale * (cp - gp[None]))
            return jnp.where(mm, bad, cp)

        return jax.tree.map(leaf, client_params, global_params)

    return tf


# ============================================================ event clock
def client_latency(latency):
    """[C] simulated completion time (round units): compute + network."""
    return latency["compute"] + latency["net"]


def lost_mask(fed, state, plan):
    """[C] bool of clients whose trained delta never reaches the server this
    round — crashed, or (under a finite deadline) slower than
    ``fed.round_deadline`` — or None when nothing can be lost (fault-free
    trace untouched). Lost clients keep their SELECTION gates for the
    backlog ledger (+1 this round, so they win cohort ties when they
    return) but contribute zero aggregation mass: the slot lands with only
    its finished members through the zero-mass-safe fedagg path."""
    lost = None
    if plan is not None and plan.crashed is not None:
        lost = plan.crashed
    if (fed.latency_mode != "none"
            and float(fed.round_deadline) != float("inf")):
        late = client_latency(state.latency) > jnp.float32(fed.round_deadline)
        lost = late if lost is None else (lost | late)
    return lost


def aggregate_finite(fed, agg_delta, loss=None):
    """Divergence guard predicate: scalar bool "this round's aggregate may
    touch the model" — every ``agg_delta`` leaf finite AND (when given) the
    eval loss finite — or None when ``fed.divergence_guard`` is off, so
    callers branch at python level and keep the unguarded trace."""
    if not fed.divergence_guard:
        return None
    finite = jnp.asarray(True) if loss is None else jnp.isfinite(loss)
    for leaf in jax.tree.leaves(agg_delta):
        finite = finite & jnp.all(jnp.isfinite(leaf))
    return finite


def skips_update(state, finite):
    """Advance the consecutive non-finite skip counter: +1 on a guarded
    skip, reset on any finite round, pass-through when the guard is off
    (``finite is None``)."""
    if finite is None:
        return state.nonfinite_skips
    return jnp.where(finite, jnp.zeros_like(state.nonfinite_skips),
                     state.nonfinite_skips + 1)


def slot_timer(fed, latency, eff_gates):
    """i32 countdown for the slot pushed this round: the ceiling of its
    slowest SURVIVING included member's completion time, clamped to
    [1, ceil(round_deadline)]. A delta can never land the round it was
    pushed (floor 1); the deadline cap is the force-landing — late members
    were already masked out of ``eff_gates`` by ``lost_mask``, so a capped
    slot carries only its finished members' mass. An all-lost cohort
    pushes an empty (zero-mass) slot with timer 1."""
    t = jnp.max(jnp.where(eff_gates > 0, client_latency(latency), 0.0))
    t = jnp.ceil(t).astype(jnp.int32)
    deadline = float(fed.round_deadline)
    if deadline != float("inf"):
        t = jnp.minimum(t, jnp.int32(math.ceil(deadline)))
    return jnp.maximum(t, 1)


# ============================================================ local training
def local_solver(loss_fn, fed):
    """Returns f(global_params, data, rng, lr) -> local params after E epochs
    of minibatch SGD (or FedProx when fed.algorithm == 'fedprox')."""
    E = fed.local_epochs
    prox_mu = fed.prox_mu if fed.algorithm == "fedprox" else 0.0

    def solve(global_params, data, rng, lr):
        n = data["y"].shape[0]
        bs = min(fed.batch_size, n)
        steps = n // bs

        def epoch(params, ekey):
            perm = jax.random.permutation(ekey, n)[:steps * bs].reshape(steps, bs)

            def step(p, idx):
                batch = jax.tree.map(lambda a: a[idx], data)
                grads = jax.grad(lambda q: loss_fn(q, batch)[0])(p)
                if prox_mu > 0.0:
                    grads = jax.tree.map(lambda g, q, w0: g + prox_mu * (q - w0),
                                         grads, p, global_params)
                return tree_axpy(-lr, grads, p), None

            params, _ = jax.lax.scan(step, params, perm)
            return params, None

        ekeys = jax.random.split(rng, E)
        params, _ = jax.lax.scan(epoch, global_params, ekeys)
        return params

    return solve


# ============================================================ backend seam
def _eval_vmap(loss_fn, params, data):
    return jax.vmap(lambda d: loss_fn(params, d))(data)


def _eval_scan(loss_fn, params, data):
    return jax.lax.map(lambda d: loss_fn(params, d), data)


def _train_vmap(solver, global_params, data, keys, lr, gates=None):
    # vmap lowers lax.cond to a select (both branches execute), so a gate
    # cannot skip work here — the cohort gather is the vmap-side saving.
    return jax.vmap(lambda d, k: solver(global_params, d, k, lr))(data, keys)


def _train_scan(solver, global_params, data, keys, lr, gates=None):
    """Time-multiplexed local training. When ``gates`` is given (known
    before training — gate-before-train strategies), gated-out clients
    skip their E local epochs entirely via lax.cond; their slot returns
    the unmodified global params, which the aggregation drops at gate 0."""
    def body(carry, inp):
        if gates is None:
            d, k = inp
            return carry, solver(global_params, d, k, lr)
        d, k, g = inp
        p = jax.lax.cond(g > 0,
                         lambda: solver(global_params, d, k, lr),
                         lambda: global_params)
        return carry, p

    xs = (data, keys) if gates is None else (data, keys, gates)
    _, stacked = jax.lax.scan(body, 0, xs)
    return stacked


_BACKENDS = {
    "vmap_spatial": (_eval_vmap, _train_vmap),
    "scan_temporal": (_eval_scan, _train_scan),
    # scan_async schedules CLIENTS spatially (vmap) like vmap_spatial — the
    # "scan" in its name is the round axis: cohorts overlap ACROSS rounds
    # of the driver's lax.scan via the in-flight FederationState buffer.
    "scan_async": (_eval_vmap, _train_vmap),
}


# ============================================================ the round
def make_round_fn(loss_fn: Callable, fed, *, backend: Optional[str] = None,
                  delta_transform: Optional[Callable] = None) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics); batch = {'x','y'} (or tokens).

    Returns round_fn(state, data, priority_mask, weights, rng, round_idx)
    -> (new_state, stats), with ``state`` a FederationState (build one with
    ``init_state``). ``data`` leaves have leading client axis [C, n, ...].
    ``backend`` defaults to ``fed.backend``; both backends produce
    identical rounds.

    ``delta_transform(client_params, global_params, client_idx) ->
    client_params`` is an adversarial-injection seam for benchmarks/tests
    ONLY: it rewrites the trained client params right before aggregation
    (``client_idx`` carries client IDENTITIES, so cohort-space rounds can
    target specific clients). The Byzantine attack rows in
    benchmarks/bench_round.py use it to model scaled-delta attackers that
    the loss-gap gate cannot see; production rounds leave it None.

    Round order depends on the strategy. Strategies that gate from the eval
    pre-pass alone (``not needs_deltas``) run **eval -> gates -> train**:
    gates are fixed before any local epoch, so the scan backend cond-skips
    gated-out clients and, when ``fed.max_cohort > 0``, only the K gathered
    included clients train at all (see ``cohort_select`` for the
    backlog-aware overflow policy). Delta-based strategies (grad_sim) keep
    the train-first order — their statistic needs the client updates
    (exact [C, M_total] flatten, or a CountSketch under
    ``fed.grad_sim_sketch``).

    ``backend="scan_async"`` with ``fed.async_depth = D > 0`` defers the
    APPLICATION of the round's aggregated delta through the
    ``FederationState.inflight`` buffer (``async_apply``): round t's
    cohort trains against w_t, later rounds gate without waiting for it,
    and its delta lands once the ``fed.async_mode`` pop policy declares it
    ready — after exactly D rounds ("fifo") or once it aged
    ``fed.min_lag`` rounds ("ready", oldest-first, possibly several per
    round) — scaled by its staleness discount (constant
    ``staleness_decay ** D`` under fifo, measured ``staleness_decay **
    age`` under ready, times the drift cosine when
    ``fed.adaptive_staleness``). At D = 0 the async round degenerates to
    the synchronous one and is bit-identical to ``vmap_spatial``.

    ``fed.candidate_pool = P`` (0 < P < C) decouples population size from
    round cost: the round draws a candidate pool of P clients
    (``pool_select`` — priority always in-pool, non-priority Gumbel-top-k
    sampled from the round PRNG stream), runs eval/gating/cohort/train/
    fedagg on the [P] slice only, and scatter-updates the per-client state
    leaves at the sampled indices — dense [C] leaves are touched by one
    gather and one scatter, so rounds/sec is flat in C. ``candidate_pool
    = 0`` (and P >= C) is the dense round, bit-identical to the legacy
    trace for every strategy x backend."""
    backend = backend or fed.backend
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; known: {BACKENDS}")
    if fed.async_depth > 0 and backend != "scan_async":
        raise ValueError(
            f"FedConfig.async_depth={fed.async_depth} requires the "
            f"'scan_async' backend; {backend!r} applies every delta at its "
            "own round barrier and would silently ignore the in-flight "
            "buffer (set async_depth=0 or backend='scan_async')")
    validate_config(fed)
    # stochastic aggregators (dp) get a per-round key; deterministic ones
    # keep a key-free trace (python-level branch, not a traced cond)
    agg_needs_key = get_aggregator(fed.aggregator).needs_key
    # fault injection / event clock / divergence guard / wire codec are
    # python-level flags: disabled configs produce literally the
    # fault-free (resp. identity-wire) trace
    failure_on = resolve_failure_model(fed.failure_model) != "none"
    clock_on = fed.latency_mode != "none"
    codec_on = (resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
                != "identity")
    ef_on = codec_on and bool(fed.error_feedback)
    eval_clients, train_clients = _BACKENDS[backend]
    strategy = get_strategy(fed.selection)
    solver = local_solver(loss_fn, fed)
    sched = make_schedule(fed)
    warmup_rounds = int(fed.warmup_frac * fed.rounds)
    gate_before_train = not strategy.needs_deltas
    # static pipeline depth: 0 (and thus the fully synchronous application
    # path, bit-identical to vmap_spatial) unless scan_async asks for more
    async_depth = int(fed.async_depth) if backend == "scan_async" else 0
    # candidate pool size (0 disables); the wrapper below python-branches
    # on it per federation size, so disabled (and P >= C) rounds run the
    # dense body with LITERALLY the legacy trace
    pool = int(getattr(fed, "candidate_pool", 0))

    def _round_body(state: FederationState, data, priority_mask, weights,
                    rng, round_idx, client_ids=None):
        global_params = state.params
        C = priority_mask.shape[0]
        lr = sched(round_idx)
        eps = epsilon_at(fed, round_idx)

        # (2) local loss/accuracy of the *received* model. The paper's
        # experiments (§3.1 "In practice...") match ACCURACIES with eps=0.2;
        # the theory matches losses. Both are supported via fed.align_stat.
        local_losses, local_metrics = eval_clients(loss_fn, global_params, data)
        if fed.align_stat == "accuracy" and "acc" in local_metrics:
            align_vals = local_metrics["acc"]
        else:
            align_vals = local_losses
        # (3) global (priority) statistic F(w_t) resp. acc(w_t)
        g_loss = global_loss_from_locals(local_losses, priority_mask, weights)
        g_align = global_loss_from_locals(align_vals, priority_mask, weights)

        # cross-round utility EMA folds in this round's gap BEFORE gating —
        # the welfare strategy gates on the smoothed signal
        util_ema = utility_update(fed, state.util_ema, align_vals, g_align)

        # participation sampling (paper App. C.3 / A.4)
        rng, pkey = jax.random.split(rng)
        part = participation_mask(fed, pkey, priority_mask, round_idx,
                                  client_ids=client_ids)

        # fault injection: the plan's availability folds into participation
        # (selection never sees a dropped-out client); crashes and
        # deadline-late clients are masked AFTER training (lost_mask);
        # corruption rides the delta_transform seam
        plan = (failure_plan(fed, round_idx, C, client_ids=client_ids)
                if failure_on else None)
        if plan is not None and plan.available is not None:
            part = part & plan.available
        lost = lost_mask(fed, state, plan)
        tf = delta_transform
        if plan is not None and plan.corrupt is not None:
            ctf = corruption_transform(fed, plan.corrupt)
            if delta_transform is None:
                tf = ctf
            else:
                def tf(cp, gp, idx, _user=delta_transform, _ctf=ctf):
                    return _user(_ctf(cp, gp, idx), gp, idx)

        warm = round_idx < warmup_rounds

        # per-client PRNG fan-out is by client IDENTITY (index in [C]), so
        # gathered cohorts train with exactly the keys the dense round uses
        rng, lkey = jax.random.split(rng)
        if client_ids is None:
            lkeys = jax.random.split(lkey, C)
        else:
            # pool rounds fan out by GLOBAL identity in O(P) — splitting C
            # keys would put the population size back on the round's
            # critical path, the exact cost pooling exists to remove
            lkeys = jax.vmap(jax.random.fold_in, (None, 0))(lkey, client_ids)

        akey = aggregator_key(fed, round_idx) if agg_needs_key else None
        # carried error-feedback rows; reassigned by the aggregation site
        # when the codec + EF are on, passed through untouched otherwise
        ef_accum = state.ef_accum

        def make_ctx(delta_cos=None):
            return SelectionContext(
                align_vals=align_vals, global_align=g_align, eps=eps,
                priority_mask=priority_mask, weights=weights,
                participation=part, warmup=warm, delta_cos=delta_cos,
                topk=fed.topk, sim_threshold=fed.sim_threshold,
                backlog=state.backlog,
                util_ema=utility_estimate(fed, util_ema, round_idx),
                incl_ema=state.incl_ema, welfare_floor=fed.welfare_floor)

        if gate_before_train:
            # (4) gates first — they only need the eval pre-pass
            sel_gates = compute_gates(make_ctx(), fed.selection)
            gates = sel_gates
            k = min(int(fed.max_cohort), C) if fed.max_cohort > 0 else 0
            if k > 0:
                # (5) gather-train-scatter: only K cohort slots run E epochs;
                # overflow ties resolve toward the longest-backlogged client
                cohort_idx, cohort_gates, gates = cohort_select(
                    sel_gates, align_vals, g_align, priority_mask, k,
                    backlog=state.backlog,
                    backlog_boost=float(fed.backlog_boost))
                cohort_params = train_clients(
                    solver, global_params,
                    jax.tree.map(lambda a: a[cohort_idx], data),
                    lkeys[cohort_idx], lr, gates=cohort_gates)
                if tf is not None:
                    cohort_params = tf(cohort_params, global_params,
                                       cohort_idx)
                agg_w, agg_g = weights[cohort_idx], cohort_gates
                if lost is not None:
                    # crashed / deadline-late: trained, but the delta never
                    # arrives — mass masked out; sel_gates stay, so the
                    # backlog re-enqueues them (+1, tie-winning on return)
                    keep = 1.0 - lost.astype(jnp.float32)
                    agg_g = agg_g * keep[cohort_idx]
                    gates = gates * keep
                if ef_on:
                    # only the K cohort slots encoded a delta this round:
                    # their EF rows gather with the cohort and scatter back
                    # advanced; everyone else's accumulator is untouched
                    cohort_ef = jax.tree.map(lambda a: a[cohort_idx],
                                             state.ef_accum)
                    agg_delta, cohort_ef = server_delta(
                        fed, global_params, cohort_params, agg_w, agg_g,
                        key=akey, ef_accum=cohort_ef)
                    ef_accum = jax.tree.map(
                        lambda full, sub: full.at[cohort_idx].set(sub),
                        state.ef_accum, cohort_ef)
                else:
                    agg_delta = server_delta(fed, global_params,
                                             cohort_params, agg_w, agg_g,
                                             key=akey)
            else:
                # (5) dense: everyone trains, but the scan backend still
                # cond-skips gated-out clients (no epochs for gate 0)
                client_params = train_clients(solver, global_params, data,
                                              lkeys, lr, gates=gates)
                if tf is not None:
                    client_params = tf(client_params, global_params,
                                       jnp.arange(C))
                if lost is not None:
                    gates = gates * (1.0 - lost.astype(jnp.float32))
                agg_w, agg_g = weights, gates
                if ef_on:
                    agg_delta, ef_accum = server_delta(
                        fed, global_params, client_params, agg_w, agg_g,
                        key=akey, ef_accum=state.ef_accum)
                else:
                    agg_delta = server_delta(fed, global_params,
                                             client_params, agg_w, agg_g,
                                             key=akey)
        else:
            # (5) train-first: the statistic needs the client updates
            sel_gates = None
            client_params = train_clients(solver, global_params, data, lkeys, lr)
            if tf is not None:
                # before the delta statistic on purpose: a realistic attacker
                # influences grad_sim scores with the very delta it submits
                client_params = tf(client_params, global_params,
                                   jnp.arange(C))
            deltas = jax.tree.map(lambda ck, g: ck - g[None],
                                  client_params, global_params)
            if fed.grad_sim_sketch:
                # streamed-friendly score: CountSketch each delta instead of
                # the exact [C, M_total] flatten (same projection per client)
                skey = sketch_key(fed, round_idx)
                sketches = jax.vmap(
                    lambda d: delta_sketch(d, skey, int(fed.sketch_dim)))(deltas)
                delta_cos = cosine_to_priority(sketches, weights, priority_mask)
            else:
                delta_cos = cosine_to_priority(flatten_stacked(deltas),
                                               weights, priority_mask)
            # (4) gates from the selection strategy (core/alignment rule et al.)
            gates = compute_gates(make_ctx(delta_cos), fed.selection)
            sel_gates = gates
            if lost is not None:
                gates = gates * (1.0 - lost.astype(jnp.float32))
            agg_w, agg_g = weights, gates
            if ef_on:
                agg_delta, ef_accum = server_delta(
                    fed, global_params, client_params, agg_w, agg_g,
                    key=akey, ef_accum=state.ef_accum)
            else:
                agg_delta = server_delta(fed, global_params, client_params,
                                         agg_w, agg_g, key=akey)

        # divergence guard: a non-finite aggregate (poisoned delta, loss
        # overflow) must never touch params or optimizer moments — and a
        # non-finite EVAL loss means the model already diverged, so its
        # delta is not trusted either
        finite = aggregate_finite(fed, agg_delta, g_loss)

        # (6) apply — at the round barrier (sync, and scan_async at depth
        # 0), or through the in-flight buffer's readiness policy
        # (scan_async: fixed fifo lag, variable-lag "ready" pops, or the
        # event clock's per-slot countdown timers)
        if async_depth > 0:
            if finite is not None:
                # a non-finite aggregate must not enter the buffer: zero it
                # so the slot lands as a bit-exact no-op contribution
                agg_delta = jax.tree.map(
                    lambda d: jnp.where(finite, d, jnp.zeros_like(d)),
                    agg_delta)
            push_timer = (slot_timer(fed, state.latency, gates)
                          if clock_on else None)
            new_global, opt_state, inflight, last_delta, ainfo = async_apply(
                fed, global_params, state.opt_state, state.inflight,
                agg_delta, last_delta=state.last_delta,
                push_timer=push_timer)
        else:
            # zero-inclusion rounds (every gate 0 — e.g. participation
            # sampling missed everyone outside warm-up) must be true no-ops:
            # running the optimizer on the all-zero delta would still decay
            # momentum and tick adam/yogi's step count. Skip the whole
            # ServerOptimizer apply when the aggregator's inclusion mass is
            # zero — or, under the divergence guard, when the aggregate is
            # non-finite — leaving params AND moments bit-identical.
            mass = inclusion_mass(fed, agg_w, agg_g)
            pred = mass > 0
            if finite is not None:
                pred = pred & finite
            new_global, opt_state = jax.lax.cond(
                pred,
                lambda: apply_server_opt(fed, global_params, state.opt_state,
                                         agg_delta),
                lambda: (global_params, state.opt_state))
            inflight = state.inflight
            last_delta = state.last_delta

        nonfinite_skips = skips_update(state, finite)

        # cross-round state: backlog ledger + inclusion EMA follow the
        # EFFECTIVE gates the aggregation honoured
        backlog = backlog_update(state.backlog,
                                 gates if sel_gates is None else sel_gates,
                                 gates)
        incl_ema = inclusion_update(fed, state.incl_ema, gates)
        new_state = FederationState(params=new_global, opt_state=opt_state,
                                    backlog=backlog, util_ema=util_ema,
                                    incl_ema=incl_ema, inflight=inflight,
                                    last_delta=last_delta,
                                    latency=state.latency,
                                    nonfinite_skips=nonfinite_skips,
                                    ef_accum=ef_accum)

        npri = (1.0 - priority_mask.astype(jnp.float32))
        included_mass = jnp.sum(npri * weights * gates)
        stats = {
            "round": round_idx,
            "lr": lr,
            "eps": eps,
            "global_loss": g_loss,
            "local_losses": local_losses,
            "gates": gates,
            "backlog": backlog,
            "theta_round": 1.0 / (1.0 + included_mass),   # paper eq. (7) term
            "included_nonpriority": jnp.sum(npri * gates),
            "warmup": warm.astype(jnp.int32) if hasattr(warm, "astype") else jnp.int32(warm),
        }
        if async_depth > 0:
            # async-only keys (python-level branch: the depth-0 trace stays
            # literally the vmap_spatial trace). "staleness" is the MEASURED
            # age of the oldest delta applied this round — 0 on rounds where
            # nothing landed (pipeline warm-up included), so loss-curve
            # tooling never attributes warm-up rounds to stale updates.
            stats["staleness"] = ainfo["applied_age"]
            stats["applied_valid"] = ainfo["applied_valid"]
            stats["inflight_occupancy"] = jnp.sum(inflight["valid"])
        if lost is not None:
            # survivor accounting: how many clients this round trained but
            # never delivered (crash + deadline-late)
            stats["lost_clients"] = jnp.sum(lost.astype(jnp.float32))
        if fed.divergence_guard:
            # consecutive non-finite skips — run_federation halts-and-
            # reports once this crosses fed.max_nonfinite_skips
            stats["skipped_nonfinite"] = nonfinite_skips
        return new_state, stats

    def round_fn(state: FederationState, data, priority_mask, weights, rng,
                 round_idx):
        C = priority_mask.shape[0]
        # python branch on static shapes: candidate_pool = 0 (disabled) and
        # candidate_pool >= C both fall through to the dense body — the
        # parity guarantee is trivially the identity of traces
        if not 0 < pool < C:
            return _round_body(state, data, priority_mask, weights, rng,
                               round_idx)
        # the pool key is split FIRST (only on this branch), so the rest of
        # the round consumes the same per-purpose chain order as dense
        # rounds: participation, then local keys
        rng, pool_key = jax.random.split(rng)
        pool_idx = pool_select(fed, pool_key, priority_mask, state.backlog,
                               state.incl_ema, pool)

        def take(a):
            return a[pool_idx]

        # [P] view of the federation: per-client leaves gather at the
        # sampled indices, global leaves (params, moments, in-flight
        # buffer, drift sketch, skip counter) pass through untouched
        view = state.replace(
            backlog=take(state.backlog),
            util_ema=take(state.util_ema),
            incl_ema=take(state.incl_ema),
            latency=(jax.tree.map(take, state.latency) if clock_on
                     else state.latency),
            ef_accum=(jax.tree.map(take, state.ef_accum) if ef_on
                      else state.ef_accum))
        sub, stats = _round_body(
            view, jax.tree.map(take, data), take(priority_mask),
            take(weights), rng, round_idx, client_ids=pool_idx)

        # scatter the pool's per-client leaves back at the sampled
        # indices; every out-of-pool row is bit-identical to before the
        # round (pinned by tests/test_pool.py)
        new_state = sub.replace(
            backlog=state.backlog.at[pool_idx].set(sub.backlog),
            util_ema=state.util_ema.at[pool_idx].set(sub.util_ema),
            incl_ema=state.incl_ema.at[pool_idx].set(sub.incl_ema),
            latency=state.latency,      # read-only: drawn once at init
            ef_accum=(jax.tree.map(
                lambda full, s: full.at[pool_idx].set(s),
                state.ef_accum, sub.ef_accum) if ef_on else state.ef_accum))
        # per-client stats scatter to the dense [C] layout (out-of-pool
        # rows report 0) so loss-curve tooling keeps one index space
        for name in ("local_losses", "gates"):
            stats[name] = (jnp.zeros((C,), stats[name].dtype)
                           .at[pool_idx].set(stats[name]))
        stats["backlog"] = new_state.backlog
        stats["pool_idx"] = pool_idx
        return new_state, stats

    return round_fn
