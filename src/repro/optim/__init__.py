from repro.optim.optimizers import Optimizer, adam, adamw, sgd  # noqa: F401
from repro.optim.schedules import (constant_schedule, cosine_schedule,  # noqa: F401
                                   make_schedule, paper_decay_schedule)
