"""Gate-before-train cohort execution: gather-train-scatter (max_cohort)
and cond-skip rounds must be bit-equal (to dtype tolerance) to the dense
train-everyone round for every registered strategy on both backends — and
for every server optimizer (the moments see the SAME aggregated delta
either way). The overflow policy must be deterministic, backlog must make
overflow fair across rounds, and the sharded adapters must agree with
their dense counterparts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=11, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])
PARAMS = INIT(jax.random.PRNGKey(0))

STRATEGIES = sorted(engine.STRATEGIES)


def _run(fed, backend, r=2, seed=1, state=None, rounds=1):
    """``rounds`` consecutive state-threaded rounds; returns the final
    (state, stats) pair — multi-round runs exercise the cross-round carry
    (optimizer moments, backlog, EMAs)."""
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    if state is None:
        state = engine.init_state(PARAMS, fed, C)
    for i in range(rounds):
        state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(seed + i),
                          jnp.int32(r + i))
    return state, stats


def _assert_rounds_equal(a, b, atol=1e-6):
    (sa, ta), (sb, tb) = a, b
    np.testing.assert_array_equal(np.asarray(ta["gates"]),
                                  np.asarray(tb["gates"]))
    # the WHOLE cross-round state must agree: params, optimizer moments,
    # backlog, and utility EMAs
    for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_allclose(np.asarray(la, np.float64),
                                   np.asarray(lb, np.float64), atol=atol)


# =================================================== cohort == dense parity
@pytest.mark.parametrize("backend", engine.BACKENDS)
@pytest.mark.parametrize("selection", STRATEGIES)
def test_cohort_round_equals_dense_round(selection, backend):
    """K >= #included: the gathered cohort round reproduces the dense round
    exactly (same per-client PRNG keys, same gates, same aggregation)."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
                    epsilon=0.5, warmup_frac=0.0, align_stat="loss",
                    selection=selection, topk=2, sim_threshold=0.0,
                    welfare_floor=0.05)
    dense = _run(fed, backend)
    cohort = _run(fed.replace(max_cohort=C), backend)
    _assert_rounds_equal(dense, cohort)


@pytest.mark.parametrize("backend", engine.BACKENDS)
@pytest.mark.parametrize("server_opt", ["momentum", "adam", "yogi"])
@pytest.mark.parametrize("selection", ["fedalign", "topk_align", "welfare"])
def test_cohort_parity_per_server_optimizer(selection, server_opt, backend):
    """Server-optimizer moments thread through BOTH execution paths: three
    consecutive rounds with adam/yogi/momentum state must end identically
    whether clients train densely or through the cohort gather."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=0.5, warmup_frac=0.0, align_stat="loss",
                    selection=selection, topk=2, welfare_floor=0.05,
                    server_opt=server_opt, server_lr=0.7)
    dense = _run(fed, backend, rounds=3)
    cohort = _run(fed.replace(max_cohort=C), backend, rounds=3)
    _assert_rounds_equal(dense, cohort, atol=5e-6)


@pytest.mark.parametrize("backend", engine.BACKENDS)
@pytest.mark.parametrize("selection", ["fedalign", "topk_align", "all"])
def test_cohort_parity_under_participation_and_stragglers(selection, backend):
    """Partial participation + straggler cadence shrink the included set;
    the cohort gather must still agree with train-everyone."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    selection=selection, topk=3, participation=0.6,
                    straggler_period=3)
    for seed in range(3):
        dense = _run(fed, backend, r=seed, seed=seed)
        cohort = _run(fed.replace(max_cohort=C), backend, r=seed, seed=seed)
        _assert_rounds_equal(dense, cohort)


@pytest.mark.parametrize("backend", engine.BACKENDS)
def test_cohort_parity_during_warmup(backend):
    """Warm-up rounds are priority-only; a tight cohort (K = #priority)
    still matches the dense warm-up round."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, warmup_frac=0.5,
                    epsilon=1e9, local_epochs=1, align_stat="loss")
    dense = _run(fed, backend, r=0)
    cohort = _run(fed.replace(max_cohort=3), backend, r=0)
    # K < C overflows nothing during warm-up (only priority gates in), but
    # backlog ledgers still agree; compare the full state
    _assert_rounds_equal(dense, cohort)


def test_cohort_parity_bf16_wire():
    """agg_dtype != float32 exercises the delta wire format in cohort space."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    agg_dtype="bfloat16")
    dense = _run(fed, "vmap_spatial")
    cohort = _run(fed.replace(max_cohort=C), "vmap_spatial")
    _assert_rounds_equal(dense, cohort)


def test_grad_sim_ignores_max_cohort():
    """Delta-based strategies keep the train-first order: max_cohort must
    not change their round at all."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    selection="grad_sim", sim_threshold=0.0)
    _assert_rounds_equal(_run(fed, "vmap_spatial"),
                         _run(fed.replace(max_cohort=2), "vmap_spatial"))


# =================================================== overflow policy
def test_cohort_overflow_drops_worst_matched():
    """More included clients than slots: priority always kept, then the
    best loss-matched non-priority; stats report the EFFECTIVE gates."""
    gates = jnp.ones((6,), jnp.float32)
    align = jnp.asarray([0.0, 0.0, 0.9, 0.1, 0.5, 0.3])
    pm = jnp.asarray([1, 1, 0, 0, 0, 0], jnp.float32)
    idx, cg, eff = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 4)
    # slots: priority 0,1 first, then non-priority by |align| = 0.1 (3), 0.3 (5)
    np.testing.assert_array_equal(np.asarray(idx), [0, 1, 3, 5])
    np.testing.assert_array_equal(np.asarray(cg), [1, 1, 1, 1])
    np.testing.assert_array_equal(np.asarray(eff), [1, 1, 0, 1, 0, 1])


def test_cohort_padding_slots_carry_zero_gates():
    """Fewer included than K: padding slots hold excluded clients with gate
    0 so they cannot contribute to the aggregation."""
    gates = jnp.asarray([1, 0, 1, 0], jnp.float32)
    align = jnp.asarray([0.0, 0.1, 0.2, 0.3])
    pm = jnp.asarray([1, 0, 0, 0], jnp.float32)
    idx, cg, eff = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 4)
    np.testing.assert_array_equal(np.asarray(cg[:2]), [1, 1])
    assert float(jnp.sum(cg)) == 2.0
    np.testing.assert_array_equal(np.asarray(eff), np.asarray(gates))


def test_cohort_overflow_round_reports_effective_gates():
    """End-to-end: K smaller than the included set caps the aggregation and
    the reported inclusion stats."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    max_cohort=4)
    _, stats = _run(fed, "vmap_spatial")
    gates = np.asarray(stats["gates"])
    assert gates.sum() == 4.0
    assert np.all(gates[np.asarray(PM)] == 1.0)          # priority kept
    assert float(stats["included_nonpriority"]) == 1.0   # 4 slots - 3 priority


# =================================================== backlog fairness
def test_backlog_breaks_overflow_ties():
    """A client dropped by overflow in round t is preferred at EQUAL match
    quality in round t+1: the backlog it accrued wins the tie that client
    index would otherwise lose."""
    gates = jnp.ones((4,), jnp.float32)
    align = jnp.asarray([0.0, 0.2, 0.2, 0.2])           # exact 3-way tie
    pm = jnp.asarray([1, 0, 0, 0], jnp.float32)
    backlog = jnp.zeros((4,), jnp.int32)

    # round t: K=2 -> priority 0 + tie broken by index -> client 1 in,
    # clients 2 and 3 dropped by overflow
    idx, _, eff = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 2,
                                       backlog=backlog)
    np.testing.assert_array_equal(np.asarray(idx), [0, 1])
    backlog = engine.backlog_update(backlog, gates, eff)
    np.testing.assert_array_equal(np.asarray(backlog), [0, 0, 1, 1])

    # round t+1, same tie: the backlogged clients 2,3 now outrank client 1
    # (among themselves the tie falls back to index: 2 before 3)
    idx, _, eff = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 2,
                                       backlog=backlog)
    np.testing.assert_array_equal(np.asarray(idx), [0, 2])
    backlog = engine.backlog_update(backlog, gates, eff)
    np.testing.assert_array_equal(np.asarray(backlog), [0, 1, 0, 2])

    # round t+2: client 3 (backlog 2) finally wins the slot
    idx, _, eff = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 2,
                                       backlog=backlog)
    np.testing.assert_array_equal(np.asarray(idx), [0, 3])


def test_backlog_zero_preserves_drop_worst():
    """At backlog 0 the policy is EXACTLY the original drop-worst stable
    sort (ties by client index)."""
    gates = jnp.ones((5,), jnp.float32)
    align = jnp.asarray([0.0, 0.3, 0.1, 0.3, 0.2])
    pm = jnp.asarray([1, 0, 0, 0, 0], jnp.float32)
    a = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 3)
    b = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 3,
                             backlog=jnp.zeros((5,), jnp.int32))
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    np.testing.assert_array_equal(np.asarray(a[0]), [0, 2, 4])


def test_backlog_boost_rescues_float_match_starvation():
    """The case the pure tie-break cannot touch: float-valued match gaps
    almost never tie exactly, so a client 5e-4 worse-matched loses the
    slot EVERY round no matter how much backlog it accrues — and with
    ``backlog_boost`` > 0 its debt buys down the gap until it rotates
    in."""
    gates = jnp.ones((3,), jnp.float32)
    align = jnp.asarray([0.0, 0.2, 0.2005])      # near-tie, NOT a tie
    pm = jnp.asarray([1, 0, 0], jnp.float32)

    # boost off: even a huge ledger never flips a non-tied comparison
    idx, _, _ = engine.cohort_select(
        gates, align, jnp.float32(0.0), pm, 2,
        backlog=jnp.asarray([0, 0, 1000], jnp.int32))
    np.testing.assert_array_equal(np.asarray(idx), [0, 1])

    # boost on: each starved round buys 1e-4 of the 5e-4 gap; client 2
    # takes the slot once its debt covers the gap (the ledger tie-break
    # finishes the last sub-ulp step), then the slot keeps rotating —
    # winning resets the debt, so neither client starves again
    backlog = jnp.zeros((3,), jnp.int32)
    winners = []
    for _ in range(8):
        idx, _, eff = engine.cohort_select(gates, align, jnp.float32(0.0),
                                           pm, 2, backlog=backlog,
                                           backlog_boost=1e-4)
        winners.append(int(np.asarray(idx)[1]))
        backlog = engine.backlog_update(backlog, gates, eff)
    first = winners.index(2)
    assert winners[:first] == [1] * first and first >= 4
    assert set(winners) == {1, 2} and winners[first + 1] == 1


def test_backlog_boost_zero_bit_identical():
    """``backlog_boost=0`` (the default) is LITERALLY the tie-break-only
    policy — same outputs on a float-match case with a live ledger."""
    gates = jnp.ones((5,), jnp.float32)
    align = jnp.asarray([0.0, 0.31, 0.1007, 0.3, 0.2003])
    pm = jnp.asarray([1, 0, 0, 0, 0], jnp.float32)
    backlog = jnp.asarray([0, 4, 0, 2, 7], jnp.int32)
    a = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 3,
                             backlog=backlog)
    b = engine.cohort_select(gates, align, jnp.float32(0.0), pm, 3,
                             backlog=backlog, backlog_boost=0.0)
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_backlog_boost_never_displaces_priority():
    """No amount of boosted debt outranks a priority client: the boosted
    rank pins priority at -inf, not the legacy -1.0 a deep-enough debt
    could undercut."""
    gates = jnp.ones((3,), jnp.float32)
    align = jnp.asarray([0.5, 0.0, 0.0])
    pm = jnp.asarray([1, 0, 0], jnp.float32)
    idx, cg, _ = engine.cohort_select(
        gates, align, jnp.float32(0.0), pm, 2,
        backlog=jnp.asarray([0, 100000, 0], jnp.int32), backlog_boost=10.0)
    assert 0 in np.asarray(idx)


def test_backlog_boost_threads_through_engine_round():
    """fed.backlog_boost reaches cohort_select: with a huge boost an
    overflowing cohort rotates its non-priority slot from round to round;
    with boost off the same (distinct-float-matched) winners repeat."""
    for boost, expect_rotation in ((1000.0, True), (0.0, False)):
        fed = FedConfig(num_clients=C, num_priority=3, rounds=10,
                        local_epochs=1, epsilon=1e9, warmup_frac=0.0,
                        align_stat="loss", max_cohort=4,
                        backlog_boost=boost)
        fn = jax.jit(engine.make_round_fn(LOSS, fed))
        state = engine.init_state(PARAMS, fed, C)
        picks = []
        for i in range(2):
            state, stats = fn(state, DATA, PM, W, jax.random.PRNGKey(1),
                              jnp.int32(2 + i))
            picks.append(tuple(np.nonzero(np.asarray(stats["gates"]))[0]))
        assert (picks[0] != picks[1]) == expect_rotation, picks


def test_backlog_untouched_for_selection_excluded():
    """Only OVERFLOW accrues backlog: clients the strategy never gated in
    keep their ledger, included clients reset it."""
    backlog = jnp.asarray([0, 3, 2, 5], jnp.int32)
    gates = jnp.asarray([1, 1, 0, 1], jnp.float32)      # 2 never gated in
    eff = jnp.asarray([1, 0, 0, 1], jnp.float32)        # 1 dropped by budget
    out = np.asarray(engine.backlog_update(backlog, gates, eff))
    np.testing.assert_array_equal(out, [0, 4, 2, 0])


def test_backlog_threads_through_engine_round():
    """End-to-end: an overflowing cohort round writes the ledger into the
    carried FederationState and the stats."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    max_cohort=4)
    state, stats = _run(fed, "vmap_spatial")
    backlog = np.asarray(state.backlog)
    np.testing.assert_array_equal(backlog, np.asarray(stats["backlog"]))
    # everyone gated in (eps=inf); 4 slots -> C-4 non-priority dropped
    assert backlog.sum() == C - 4
    assert np.all(backlog[np.asarray(PM)] == 0)
    # a second overflowing round rotates the slot to a backlogged client
    # only on an exact match-quality tie; either way the ledger grows for
    # still-dropped clients and resets for aggregated ones
    state2, stats2 = _run(fed, "vmap_spatial", r=3, seed=3, state=state)
    gates2 = np.asarray(stats2["gates"])
    b2 = np.asarray(state2.backlog)
    assert np.all(b2[gates2 > 0] == 0)
    assert np.all(b2[(gates2 == 0) & ~np.asarray(PM)] >= 1)


# =================================================== scan cond-skip
def test_scan_backend_skips_gated_out_clients():
    """The temporal backend must branch (lax.cond), not select: its HLO
    contains a conditional whose true branch holds the local epochs."""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=0.0, warmup_frac=0.0, align_stat="loss")
    fn = engine.make_round_fn(LOSS, fed, backend="scan_temporal")
    state = engine.init_state(PARAMS, fed, C)
    text = jax.jit(fn).lower(state, DATA, PM, W, jax.random.PRNGKey(0),
                             jnp.int32(0)).as_text()
    assert "stablehlo.if" in text or "stablehlo.case" in text
