"""End-to-end simulator runs: FedALIGN trains, beats baselines on aligned
federations, local baseline works, checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs.base import FedConfig
from repro.data.synth import make_synth_federation
from repro.fl.simulator import evaluate, run_federation, run_local_baseline
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)


def _fed(rounds=20, **kw):
    base = dict(num_clients=12, num_priority=6, rounds=rounds, local_epochs=3,
                epsilon=0.2, lr=0.1, warmup_frac=0.1, batch_size=32)
    base.update(kw)
    return FedConfig(**base)


def test_federation_improves_accuracy():
    fedn = make_synth_federation(seed=0, n_priority=6, n_nonpriority=6,
                                 samples_per_client=100)
    params0 = INIT(jax.random.PRNGKey(0))
    _, acc0 = evaluate(LOSS, params0, fedn.test_x, fedn.test_y)
    hist = run_federation(LOSS, params0, _fed(), fedn, eval_every=5)
    assert hist.test_acc[-1] > acc0 + 0.15
    assert hist.test_acc[-1] > 0.5


def test_fedalign_beats_all_under_noise():
    fedn = make_synth_federation(seed=0, n_priority=6, n_nonpriority=6,
                                 samples_per_client=100,
                                 label_noise_factor=2.5, label_noise_skew=5.0)
    accs = {}
    for sel in ("fedalign", "all"):
        hist = run_federation(LOSS, INIT(jax.random.PRNGKey(0)),
                              _fed(selection=sel), fedn, eval_every=5)
        accs[sel] = hist.summary()["best_acc"]
    assert accs["fedalign"] >= accs["all"] - 0.01


def test_history_theta_consistency():
    fedn = make_synth_federation(seed=1, n_priority=6, n_nonpriority=6,
                                 samples_per_client=60)
    hist = run_federation(LOSS, INIT(jax.random.PRNGKey(0)), _fed(rounds=10),
                          fedn, eval_every=1)
    th = np.asarray(hist.theta_round)
    assert np.all(th > 0) and np.all(th <= 1.0)
    # warm-up rounds include nobody -> theta == 1
    assert th[0] == 1.0
    tT = hist.theta_T(gamma=10.0, E=3)
    assert 0 < tT <= 1.0


def test_local_baseline_runs():
    fedn = make_synth_federation(seed=2, n_priority=2, n_nonpriority=2,
                                 samples_per_client=50)
    accs = run_local_baseline(LOSS, INIT, _fed(rounds=4), fedn, client_ids=[0, 2])
    assert set(accs) == {0, 2}
    assert all(0 <= a <= 1 for a in accs.values())


def test_checkpoint_roundtrip(tmp_path):
    params = INIT(jax.random.PRNGKey(3))
    params = jax.tree.map(lambda x: x + 1.5, params)
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, params, step=7)
    restored, step, meta = load_pytree(path,
                                       jax.tree.map(jnp.zeros_like, params))
    assert step == 7
    assert meta is None                  # no writer metadata recorded
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
