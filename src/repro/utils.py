"""Small shared utilities: pytree math, PRNG fan-out, parameter counting."""
from __future__ import annotations

import functools
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


class Registry(dict):
    """One generic name -> implementation table for every pluggable seam.

    The strategy / aggregator / wire-codec / failure-model / server-
    optimizer registries used to be copy-pasted dict + decorator +
    resolver triples whose unknown-name errors drifted apart; this class
    is the single implementation. It IS a dict — existing call sites like
    ``sorted(engine.STRATEGIES)`` or ``"mean" in AGGREGATORS`` keep
    working — plus:

    * ``register(name, **attrs)`` — decorator factory; stamps ``attrs``
      on the function (``strategy_name``, ``needs_deltas``, ...) and
      refuses duplicate names.
    * ``resolve(name)`` — the canonical registered name with the seam's
      aliases applied (e.g. aggregator ``None``/``"none"`` -> ``"mean"``).
    * ``lookup(name)`` — resolve + fetch, raising the ONE consistent
      unknown-name error that lists the valid entries.
    * ``names()`` — sorted registered names (what the error shows).
    """

    def __init__(self, kind: str, *, aliases: dict | None = None):
        super().__init__()
        self.kind = kind
        self.aliases = dict(aliases or {})

    def register(self, name: str, **attrs):
        def deco(fn):
            if name in self:
                raise ValueError(f"duplicate {self.kind} {name!r}")
            for k, v in attrs.items():
                setattr(fn, k, v)
            self[name] = fn
            return fn
        return deco

    def resolve(self, name):
        return self.aliases.get(name, name)

    def lookup(self, name):
        canonical = self.resolve(name)
        if canonical not in self:
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered: {self.names()}")
        return self[canonical]

    def names(self) -> list:
        return sorted(self)


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x: Pytree, y: Pytree) -> Pytree:
    """a * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return functools.reduce(jnp.add, jax.tree.leaves(leaves))


def tree_sq_norm(tree: Pytree) -> jax.Array:
    return tree_dot(tree, tree)


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def param_count(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def param_bytes(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def fold_in_name(key: jax.Array, name: str) -> jax.Array:
    """Derive a named sub-key deterministically from a string.

    Uses crc32, NOT python's builtin ``hash`` — str hashing is salted per
    process (PYTHONHASHSEED), so builtin-hash-derived keys silently gave
    every process a different "seeded" model init: benchmark loss curves
    and paper runs were unreproducible across invocations."""
    h = np.uint32(zlib.crc32(name.encode()) % (2**31 - 1))
    return jax.random.fold_in(key, h)


def split_like(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    return {n: fold_in_name(key, n) for n in names}


def has_nan(tree: Pytree) -> jax.Array:
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree) if jnp.issubdtype(x.dtype, jnp.floating)]
    return functools.reduce(jnp.logical_or, leaves, jnp.asarray(False))


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b
