"""The paper's own experiment configurations (§4 + App. B/C), as data.

Each entry pairs a FedConfig with the dataset/model used by the matching
benchmark suite — the single source of truth for the reproduction runs.
"""
from __future__ import annotations

from repro.configs.base import FedConfig

# §4 Fig. 1 — benchmark datasets, full participation
FIG1 = {
    "fmnist": dict(model="logreg", dataset="fmnist",
                   fed=FedConfig(num_clients=60, num_priority=2, rounds=200,
                                 local_epochs=5, epsilon=0.2, lr=0.1,
                                 warmup_frac=0.1)),
    "emnist": dict(model="mlp2", dataset="emnist",
                   fed=FedConfig(num_clients=25, num_priority=2, rounds=200,
                                 local_epochs=5, epsilon=0.2, lr=0.1,
                                 warmup_frac=0.1)),
    "cifar": dict(model="cnn", dataset="cifar",
                  fed=FedConfig(num_clients=60, num_priority=2, rounds=200,
                                local_epochs=5, epsilon=0.2, lr=0.01,
                                warmup_frac=0.1)),
}

# §4 Fig. 2 — SYNTH(1,1): eps=0.2 (0.4 for high noise), N=20, |P|=10
FIG2 = {
    level: dict(model="synth_logreg",
                fed=FedConfig(num_clients=20, num_priority=10, rounds=200,
                              local_epochs=5, lr=0.1, warmup_frac=0.1,
                              epsilon=0.4 if level == "high" else 0.2),
                skew=skew)
    for level, skew in (("low", 0.5), ("medium", 1.5), ("high", 5.0))
}

# App. C.2 — FedProx adaptation (mu = 1, 4 priority clients)
FIG4 = dict(model="logreg", dataset="fmnist",
            fed=FedConfig(num_clients=60, num_priority=4, rounds=150,
                          local_epochs=5, epsilon=0.2, lr=0.1,
                          warmup_frac=0.1, algorithm="fedprox", prox_mu=1.0))

# App. C.3 — partial participation (fraction 0.3, 18 priority)
FIG5 = dict(model="logreg", dataset="fmnist",
            fed=FedConfig(num_clients=60, num_priority=18, rounds=150,
                          local_epochs=5, epsilon=0.2, lr=0.1,
                          warmup_frac=0.1, participation=0.3))

# App. C.4 — priority-count / local-epoch sweeps
FIG6 = [dict(n_priority=2, E=5), dict(n_priority=6, E=5),
        dict(n_priority=18, E=5), dict(n_priority=6, E=3)]
