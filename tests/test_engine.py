"""Unified federation engine: fused multi-leaf aggregation parity, backend
equivalence across selection strategies, strategy semantics, and gate
regressions (warm-up / partial participation / straggler cadence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core.aggregation import aggregate_clients, flatten_stacked
from repro.data.synth import make_synth_federation
from repro.fl import engine
from repro.models.small import SMALL_MODELS, make_loss_fn

INIT, APPLY = SMALL_MODELS["synth_logreg"]
LOSS = make_loss_fn(APPLY)
FEDN = make_synth_federation(seed=7, n_priority=3, n_nonpriority=5,
                             samples_per_client=64)
DATA = {"x": jnp.asarray(FEDN.x), "y": jnp.asarray(FEDN.y)}
PM = jnp.asarray(FEDN.priority_mask)
W = jnp.asarray(FEDN.weights)
C = int(PM.shape[0])

STRATEGIES = ["fedalign", "all", "priority_only", "topk_align", "grad_sim",
              "welfare"]


def _tree(C=6, dtype=jnp.float32, seed=0):
    """Client-stacked pytree with non-divisible leaf sizes (incl. a [C]
    scalar-per-client leaf) — the fused path must split it back exactly."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "w1": jax.random.normal(ks[0], (C, 7, 13)).astype(dtype),
        "b1": jax.random.normal(ks[1], (C, 13)).astype(dtype),
        "w2": jax.random.normal(ks[2], (C, 13, 3)).astype(dtype),
        "scale": jax.random.normal(ks[3], (C,)).astype(dtype),
    }


def _wg(C=6, seed=1):
    k = jax.random.PRNGKey(seed)
    w = jax.random.uniform(k, (C,)) + 0.1
    g = (jax.random.uniform(jax.random.fold_in(k, 1), (C,)) > 0.4).astype(jnp.float32)
    g = g.at[0].set(1.0)                     # never all-zero
    return w, g


# ===================================================== fused multi-leaf parity
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_per_leaf_reference(dtype):
    tree = _tree(dtype=dtype)
    w, g = _wg()
    fused = aggregate_clients(tree, w, g, fused=True)
    per_leaf = aggregate_clients(tree, w, g, fused=False)
    for a, b in zip(jax.tree.leaves(fused), jax.tree.leaves(per_leaf)):
        assert a.dtype == b.dtype == dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_pallas_interpret_matches_jnp(dtype):
    """interpret=True runs the actual Pallas kernel grid on CPU; M_total is
    not a multiple of the block so the pad/slice path is exercised too."""
    tree = _tree(dtype=dtype)
    w, g = _wg()
    ref = aggregate_clients(tree, w, g, fused=False)
    pal = aggregate_clients(tree, w, g, fused=True, use_pallas=True,
                            interpret=True)
    for a, b in zip(jax.tree.leaves(pal), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_fused_kernel_called_once_per_round():
    """The fused path must lower to a single [C, M_total] contraction: its
    HLO contains exactly one dot over the client axis (vs one per leaf)."""
    tree = _tree()
    w, g = _wg()
    text = jax.jit(
        lambda t, w, g: aggregate_clients(t, w, g, fused=True)
    ).lower(tree, w, g).compile().as_text()
    assert text.count(" dot(") == 1
    text_pl = jax.jit(
        lambda t, w, g: aggregate_clients(t, w, g, fused=False)
    ).lower(tree, w, g).compile().as_text()
    assert text_pl.count(" dot(") == len(jax.tree.leaves(tree))


def test_flatten_stacked_shape_and_order():
    tree = _tree()
    buf = flatten_stacked(tree)
    M = sum(leaf.size // 6 for leaf in jax.tree.leaves(tree))
    assert buf.shape == (6, M) and buf.dtype == jnp.float32


# ===================================================== backend equivalence
def _round_per_backend(fed, seed=0, r=1):
    """One round per registered backend (scan_async runs at depth 0, i.e.
    its synchronous degenerate), all from the same state."""
    state = engine.init_state(INIT(jax.random.PRNGKey(0)), fed, C)
    outs = []
    for backend in engine.BACKENDS:
        fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
        outs.append(fn(state, DATA, PM, W, jax.random.PRNGKey(seed),
                       jnp.int32(r)))
    return outs


@pytest.mark.parametrize("selection", STRATEGIES)
def test_backends_identical_per_strategy(selection):
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=2,
                    epsilon=0.5, warmup_frac=0.0, align_stat="loss",
                    selection=selection, topk=2, sim_threshold=0.0,
                    welfare_floor=0.05)
    (pv, sv), *others = _round_per_backend(fed)
    for pt, st in others:
        np.testing.assert_array_equal(np.asarray(sv["gates"]),
                                      np.asarray(st["gates"]))
        np.testing.assert_allclose(np.asarray(sv["local_losses"]),
                                   np.asarray(st["local_losses"]), atol=1e-6)
        # the full carried state (params, moments, backlog, EMAs) must agree
        for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(pt)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_backends_identical_under_participation_and_stragglers():
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, local_epochs=1,
                    epsilon=1e9, warmup_frac=0.0, align_stat="loss",
                    participation=0.6, straggler_period=3)
    for seed in range(3):
        (pv, sv), *others = _round_per_backend(fed, seed=seed, r=seed)
        for pt, st in others:
            np.testing.assert_array_equal(np.asarray(sv["gates"]),
                                          np.asarray(st["gates"]))
            for a, b in zip(jax.tree.leaves(pv), jax.tree.leaves(pt)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)


def test_unknown_backend_and_strategy_raise():
    fed = FedConfig()
    with pytest.raises(ValueError, match="backend"):
        engine.make_round_fn(LOSS, fed, backend="nope")
    with pytest.raises(ValueError, match="strategy"):
        engine.make_round_fn(LOSS, fed.replace(selection="nope"))


# ===================================================== strategy semantics
def _ctx(losses, pm, **kw):
    pm = jnp.asarray(pm, bool)
    losses = jnp.asarray(losses, jnp.float32)
    defaults = dict(align_vals=losses, global_align=jnp.float32(0.0),
                    eps=jnp.float32(1.0), priority_mask=pm)
    defaults.update(kw)
    return engine.SelectionContext(**defaults)


def test_topk_align_budgets_inclusion():
    # non-priority diffs: 0.1, 0.2, 0.3, 0.9 — eps=1.0 admits all four,
    # topk=2 must keep only the two best-matched
    losses = [0.0, 0.1, 0.2, 0.3, 0.9]
    pm = [1, 0, 0, 0, 0]
    gates = engine.compute_gates(_ctx(losses, pm, topk=2), "topk_align")
    np.testing.assert_array_equal(np.asarray(gates), [1, 1, 1, 0, 0])
    # a big enough budget degenerates to plain fedalign
    g_all = engine.compute_gates(_ctx(losses, pm, topk=10), "topk_align")
    g_fa = engine.compute_gates(_ctx(losses, pm), "fedalign")
    np.testing.assert_array_equal(np.asarray(g_all), np.asarray(g_fa))
    # eps still bounds the band: nothing outside it enters even with budget
    g_eps = engine.compute_gates(_ctx(losses, pm, topk=10,
                                      eps=jnp.float32(0.25)), "topk_align")
    np.testing.assert_array_equal(np.asarray(g_eps), [1, 1, 1, 0, 0])


def test_topk_align_zero_budget_is_priority_only():
    losses = [0.0, 0.1, 0.2]
    pm = [1, 0, 0]
    gates = engine.compute_gates(_ctx(losses, pm, topk=0), "topk_align")
    np.testing.assert_array_equal(np.asarray(gates), [1, 0, 0])


def test_grad_sim_thresholds_cosine():
    losses = [0.0, 0.0, 0.0, 0.0]
    pm = [1, 0, 0, 0]
    cos = jnp.asarray([1.0, 0.9, 0.1, -0.5])
    gates = engine.compute_gates(
        _ctx(losses, pm, delta_cos=cos, sim_threshold=0.5), "grad_sim")
    np.testing.assert_array_equal(np.asarray(gates), [1, 1, 0, 0])
    # priority in even when its own cosine is low (always included)
    gates = engine.compute_gates(
        _ctx(losses, [0, 1, 0, 1], delta_cos=cos, sim_threshold=0.5),
        "grad_sim")
    np.testing.assert_array_equal(np.asarray(gates), [1, 1, 0, 1])


def test_grad_sim_without_deltas_raises():
    with pytest.raises(ValueError, match="delta_cos"):
        engine.compute_gates(_ctx([0.0, 0.0], [1, 0]), "grad_sim")


def test_cosine_to_priority_geometry():
    # client 0 (priority) defines the direction; client 1 aligned, client 2
    # orthogonal, client 3 opposed
    deltas = jnp.asarray([[1.0, 0.0], [2.0, 0.0], [0.0, 3.0], [-1.0, 0.0]])
    w = jnp.ones((4,)) * 0.25
    pm = jnp.asarray([1, 0, 0, 0], jnp.float32)
    cos = np.asarray(engine.cosine_to_priority(deltas, w, pm))
    np.testing.assert_allclose(cos, [1.0, 1.0, 0.0, -1.0], atol=1e-6)


def test_register_strategy_decorator_roundtrip():
    @engine.register_strategy("_test_even_clients")
    def even_only(ctx):
        C = ctx.priority_mask.shape[0]
        return (jnp.arange(C) % 2 == 0).astype(jnp.float32)

    try:
        gates = engine.compute_gates(_ctx([0.0] * 4, [1, 0, 0, 0]),
                                     "_test_even_clients")
        np.testing.assert_array_equal(np.asarray(gates), [1, 0, 1, 0])
        # and it is reachable end-to-end through FedConfig.selection
        fed = FedConfig(num_clients=C, num_priority=3, rounds=4,
                        local_epochs=1, warmup_frac=0.0, align_stat="loss",
                        selection="_test_even_clients")
        fn = jax.jit(engine.make_round_fn(LOSS, fed))
        _, stats = fn(engine.init_state(INIT(jax.random.PRNGKey(0)), fed, C),
                      DATA, PM, W, jax.random.PRNGKey(0), jnp.int32(0))
        got = np.asarray(stats["gates"])
        want = np.maximum(np.asarray(PM, np.float32),
                          (np.arange(C) % 2 == 0).astype(np.float32))
        np.testing.assert_array_equal(got, want)
    finally:
        engine.STRATEGIES.pop("_test_even_clients", None)


# ===================================================== gate regressions
def _run_round(fed, r=0, seed=0, backend="vmap_spatial"):
    fn = jax.jit(engine.make_round_fn(LOSS, fed, backend=backend))
    return fn(engine.init_state(INIT(jax.random.PRNGKey(0)), fed, C),
              DATA, PM, W, jax.random.PRNGKey(seed), jnp.int32(r))


@pytest.mark.parametrize("selection", ["fedalign", "topk_align", "grad_sim"])
def test_warmup_is_priority_only_for_alignment_strategies(selection):
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, warmup_frac=0.5,
                    epsilon=1e9, local_epochs=1, align_stat="loss",
                    selection=selection, topk=C, sim_threshold=-1.0)
    _, stats = _run_round(fed, r=0)          # warm-up round
    np.testing.assert_array_equal(np.asarray(stats["gates"]),
                                  np.asarray(PM, np.float32))
    assert int(stats["warmup"]) == 1
    _, stats = _run_round(fed, r=6)          # post warm-up
    assert np.asarray(stats["gates"]).sum() > np.asarray(PM).sum()
    assert int(stats["warmup"]) == 0


def test_warmup_does_not_gate_select_all():
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, warmup_frac=0.5,
                    epsilon=1e9, local_epochs=1, align_stat="loss",
                    selection="all")
    _, stats = _run_round(fed, r=0)
    assert np.all(np.asarray(stats["gates"]) == 1.0)


def test_partial_participation_masks_gates_and_protects_priority():
    fed = FedConfig(num_clients=C, num_priority=3, rounds=10, warmup_frac=0.0,
                    epsilon=1e9, local_epochs=1, participation=0.4,
                    align_stat="loss")
    seen_excluded = False
    for seed in range(6):
        _, stats = _run_round(fed, seed=seed)
        gates = np.asarray(stats["gates"])
        assert gates[np.asarray(PM)].sum() >= 1      # priority never empty
        assert set(np.unique(gates)).issubset({0.0, 1.0})
        if gates.sum() < C:
            seen_excluded = True
    assert seen_excluded


def test_straggler_cadence_pinned():
    """Non-priority client k joins every 2 + k % period rounds; priority
    clients are never stragglers. (App. A.4 arbitrary participation.)"""
    fed = FedConfig(num_clients=C, num_priority=3, rounds=20, warmup_frac=0.0,
                    epsilon=1e9, local_epochs=1, straggler_period=3,
                    align_stat="loss")
    seen = np.stack([np.asarray(_run_round(fed, r=r)[1]["gates"])
                     for r in range(6)])
    assert np.all(seen[:, :3] == 1.0)                # priority every round
    for k in range(3, C):
        cadence = 2 + k % 3
        for r in range(6):
            assert seen[r, k] == (1.0 if r % cadence == 0 else 0.0), (r, k)


def test_agg_dtype_bf16_round_close_to_f32():
    """agg_dtype plumbs through the engine: bf16 wire deltas stay close to
    the exact f32 aggregation after one round."""
    fed32 = FedConfig(num_clients=C, num_priority=3, rounds=4, local_epochs=2,
                      epsilon=1e9, warmup_frac=0.0, align_stat="loss")
    fed16 = fed32.replace(agg_dtype="bfloat16")
    s32, _ = _run_round(fed32)
    s16, _ = _run_round(fed16)
    num = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(s32.params), jax.tree.leaves(s16.params)))
    den = sum(float(jnp.sum(jnp.abs(a))) for a in jax.tree.leaves(s32.params))
    assert num < 0.02 * max(den, 1e-9), (num, den)


# ===================================================== sharded integration
def test_sharded_uses_engine_gating():
    """fl/sharded.py must not re-implement gating privately."""
    import inspect
    from repro.fl import sharded
    src = inspect.getsource(sharded)
    assert "def _gates" not in src          # no private gate implementation
    assert "engine.compute_gates" in src
    assert "engine.cohort_select" in src    # and no private gather copy


@pytest.mark.parametrize("selection", ["topk_align", "grad_sim", "welfare"])
def test_sharded_spatial_new_strategies_smoke(selection):
    from repro.fl import sharded
    from tests.test_sharded import MODEL, _batch
    fed = FedConfig(local_epochs=1, epsilon=1e9, lr=0.05, selection=selection,
                    topk=1, sim_threshold=-1.0)
    step = jax.jit(sharded.make_spatial_round(MODEL, fed, 4))
    state = engine.init_state(MODEL.init(jax.random.PRNGKey(0)), fed, 4)
    _, stats = step(state, _batch())
    gates = np.asarray(stats["gates"])
    assert set(np.unique(gates)).issubset({0.0, 1.0})
    assert np.all(gates[:2] == 1.0)                  # priority always in
    if selection == "topk_align":
        assert gates[2:].sum() <= 1                  # budget respected
