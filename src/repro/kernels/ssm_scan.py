"""Pallas TPU chunked selective scan (Mamba S6 recurrence).

Grid: (batch, d_inner tiles, time chunks). The time-chunk axis is the
sequential (innermost) grid dimension, so the SSM state h [block_d, N]
persists in VMEM scratch across chunks — the HBM traffic is exactly one
read of (x, dt, B, C) and one write of y; the O(S) state history never
leaves the core. Inside a chunk the recurrence is stepped with a
fori_loop over rows already resident in VMEM.

This is the TPU adaptation of the CUDA selective-scan: instead of a
warp-parallel prefix scan in shared memory, we exploit the sequential TPU
grid + VMEM-resident carry, and tile d_inner (the embarrassingly parallel
axis) across grid cells / cores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, o_ref, h_ref, *, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)                     # [bd, N]
    Dp = D_ref[...].astype(jnp.float32)                    # [bd]

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)            # [bd]
        dtt = dt_ref[0, t, :].astype(jnp.float32)          # [bd]
        Bt = B_ref[0, t, :].astype(jnp.float32)            # [N]
        Ct = C_ref[0, t, :].astype(jnp.float32)            # [N]
        dA = jnp.exp(dtt[:, None] * A)                     # [bd, N]
        h = dA * h + (dtt * xt)[:, None] * Bt[None, :]
        y = jnp.sum(h * Ct[None, :], axis=1) + Dp * xt     # [bd]
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def ssm_scan_pallas(x, dt, A, B, C, D, *, chunk=256, block_d=512, interpret=False):
    """Shapes as ref.ssm_scan_ref: x/dt [Bt,S,Di], B/C [Bt,S,N], A [Di,N], D [Di]."""
    Bt, S, Di = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    block_d = min(block_d, Di)
    assert Di % block_d == 0
    nch, nd = S // chunk, Di // block_d

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(Bt, nd, nch),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return out
