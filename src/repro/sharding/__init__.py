from repro.sharding.specs import (auto_batch_specs, auto_param_specs,  # noqa: F401
                                  auto_tree_specs, dp_axes, shaped_with)
