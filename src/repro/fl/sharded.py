"""Pod-scale FedALIGN: the communication round as a single pjit program.

Two execution modes, chosen by model size (DESIGN.md §3):

* **spatial** — clients ARE the (pod, data) mesh shards. Client-stacked
  params [C, ...] are vmapped through E local SGD steps in parallel; the
  gated aggregation contracts the client axis, lowering to ONE all-reduce
  over (pod, data) — FedALIGN's entire server communication.

* **temporal** — for models too large to replicate per client (jamba-398b,
  llava-34b): params stay (data, model)-sharded (FSDP+TP); the client
  cohort is traversed with lax.scan, each client running its local steps
  on the full mesh; gated updates accumulate in the scan carry. The
  federation semantics are identical — clients are time-multiplexed
  instead of space-multiplexed.

The server statistic F(w_t) is computed on a server-held global batch
(paper §3.1: "the server transmits ... also its associated loss"), so the
gate needs no second pass over clients.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import aggregate_clients
from repro.utils import tree_axpy, tree_cast

FSDP_ARCHS = {"jamba-1.5-large-398b", "llava-next-34b"}


def needs_fsdp(cfg) -> bool:
    return cfg.name in FSDP_ARCHS


def _local_steps(model, params, batch, lr, n_steps):
    """E local SGD steps on one client's batch. Returns (params', F_k(w_t))."""
    loss0, _ = model.loss_fn(params, batch)

    def step(p, _):
        loss, grads = jax.value_and_grad(
            lambda q: model.loss_fn(q, batch)[0])(p)
        return tree_axpy(-lr, grads, p), loss

    params, _ = jax.lax.scan(step, params, None, length=n_steps)
    return params, loss0


def _gates(local_losses, server_loss, eps, priority_mask):
    pri = priority_mask.astype(jnp.float32)
    aligned = (jnp.abs(local_losses - server_loss) < eps).astype(jnp.float32)
    return pri + (1.0 - pri) * aligned


def make_spatial_round(model, fed, num_clients: int):
    """Returns round_step(params, batch) -> (params', stats).

    batch: client-stacked arrays [C, b, ...] + server_* arrays (global data).
    priority_mask/weights [C] ride inside batch so everything is one pytree.
    """
    E = fed.local_epochs
    lr = fed.lr

    def round_step(params, batch):
        client_batch = batch["clients"]
        pm = batch["priority_mask"]
        w = batch["weights"]

        server_loss, _ = model.loss_fn(params, batch["server"])

        client_params, local_losses = jax.vmap(
            lambda cb: _local_steps(model, params, cb, lr, E))(client_batch)

        gates = _gates(local_losses, server_loss, jnp.float32(fed.epsilon), pm)
        if fed.agg_dtype != "float32":
            # aggregate client DELTAS on the wire in reduced precision:
            # w <- w + agg(cast(w_k - w)); halves FedALIGN's server all-reduce
            ad = jnp.dtype(fed.agg_dtype)
            deltas = jax.tree.map(lambda ck, g: (ck - g[None]).astype(ad),
                                  client_params, params)
            agg = aggregate_clients(deltas, w, gates)
            new_params = jax.tree.map(
                lambda g, d: (g + d.astype(jnp.float32)).astype(g.dtype),
                params, agg)
        else:
            new_params = aggregate_clients(client_params, w, gates)
            new_params = jax.tree.map(lambda n, p: n.astype(p.dtype),
                                      new_params, params)
        stats = {
            "server_loss": server_loss,
            "local_losses": local_losses,
            "gates": gates,
            "theta_round": 1.0 / (1.0 + jnp.sum((1 - pm.astype(jnp.float32)) * w * gates)),
        }
        return new_params, stats

    return round_step


def make_temporal_round(model, fed, cohort: int):
    """FSDP variant: scan over a client cohort; accumulate gated updates.

    batch['clients'] leaves are [C, b, ...] with C the SCAN axis (unsharded);
    the inner batch dim b is sharded over (pod, data).
    """
    E = fed.local_epochs
    lr = fed.lr

    def round_step(params, batch):
        pm = batch["priority_mask"]
        w = batch["weights"]
        server_loss, _ = model.loss_fn(params, batch["server"])

        def per_client(carry, inp):
            acc_num, acc_den = carry
            cbatch, pm_k, w_k = inp
            p_k, loss0 = _local_steps(model, params, cbatch, lr, E)
            gate = _gates(loss0[None], server_loss, jnp.float32(fed.epsilon),
                          pm_k[None])[0]
            wg = w_k * gate
            acc_num = jax.tree.map(
                lambda a, pk: a + wg * pk.astype(jnp.float32), acc_num, p_k)
            return (acc_num, acc_den + wg), (loss0, gate)

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (num, den), (losses, gates) = jax.lax.scan(
            per_client, (zeros, jnp.float32(0)),
            (batch["clients"], pm, w))
        new_params = jax.tree.map(
            lambda n, p: (n / jnp.maximum(den, 1e-30)).astype(p.dtype), num, params)
        stats = {
            "server_loss": server_loss,
            "local_losses": losses,
            "gates": gates,
            "theta_round": 1.0 / (1.0 + jnp.sum((1 - pm.astype(jnp.float32)) * w * gates)),
        }
        return new_params, stats

    return round_step


def make_round_step(model, fed, num_clients: int, *, fsdp: bool):
    return (make_temporal_round(model, fed, num_clients) if fsdp
            else make_spatial_round(model, fed, num_clients))


# ----------------------------------------------------------------- serving
def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model):
    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)
    return serve_step
