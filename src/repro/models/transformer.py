"""Unified decoder-only LM covering dense / MoE / hybrid (jamba) / xLSTM /
VLM architectures.

Layers are grouped into repeating *periods* (dense: 1 block, jamba: 8,
xlstm: 2); period parameters are stacked on a leading axis and the stack is
traversed with ``lax.scan`` (+ optional remat) so the HLO stays one-period
sized regardless of depth — essential for compiling 40 full-size dry-run
configs on a CPU host.

API (all functional):
    init(key, cfg)                                   -> params
    forward(params, tokens, cfg, ...)                -> hidden [B,S,d]
    loss_fn(params, batch, cfg)                      -> (loss, metrics)
    prefill(params, batch, cfg)                      -> (cache, last_logits)
    decode_step(params, cache, tokens, pos, cfg)     -> (logits, cache)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import gqa_attention_block, init_gqa, init_mla, mla_attention_block
from repro.models.moe import init_moe, moe_apply
from repro.models.ssm import init_mamba, mamba_block
from repro.models.xlstm import init_mlstm, init_slstm, mlstm_block, slstm_block
from repro.utils import fold_in_name


# ------------------------------------------------------------------ block init
def _init_block(key, cfg, kind):
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": L.init_rmsnorm(d, cfg.pdtype)}
    mixer = kind["mixer"]
    if mixer == "attn":
        p["attn"] = init_mla(fold_in_name(key, "attn"), cfg) if cfg.mla \
            else init_gqa(fold_in_name(key, "attn"), cfg)
    elif mixer == "mamba":
        p["mamba"] = init_mamba(fold_in_name(key, "mamba"), cfg)
    elif mixer == "mlstm":
        p["mlstm"] = init_mlstm(fold_in_name(key, "mlstm"), cfg)
    elif mixer == "slstm":
        p["slstm"] = init_slstm(fold_in_name(key, "slstm"), cfg)
    else:
        raise ValueError(mixer)
    if kind["ffn"] == "dense":
        p["norm2"] = L.init_rmsnorm(d, cfg.pdtype)
        p["mlp"] = L.init_swiglu(fold_in_name(key, "mlp"), d, cfg.d_ff, cfg.pdtype)
    elif kind["ffn"] == "moe":
        p["norm2"] = L.init_rmsnorm(d, cfg.pdtype)
        p["moe"] = init_moe(fold_in_name(key, "moe"), cfg)
    return p


def _apply_block(p, x, cfg, kind, *, positions, mode, cache):
    new_cache = None
    aux = jnp.float32(0)
    mixer = kind["mixer"]
    h = L.rmsnorm(p["norm1"], x)
    if mixer == "attn":
        fn = mla_attention_block if cfg.mla else gqa_attention_block
        h, new_cache = fn(p["attn"], h, cfg, positions=positions, mode=mode, cache=cache)
    elif mixer == "mamba":
        h, new_cache = mamba_block(p["mamba"], h, cfg, mode=mode, cache=cache)
    elif mixer == "mlstm":
        h, new_cache = mlstm_block(p["mlstm"], h, cfg, mode=mode, cache=cache)
    elif mixer == "slstm":
        h, new_cache = slstm_block(p["slstm"], h, cfg, mode=mode, cache=cache)
    from jax.ad_checkpoint import checkpoint_name
    h = checkpoint_name(h, "mixer_out")
    x = x + h
    if kind["ffn"] == "dense":
        x = x + L.swiglu_apply(p["mlp"], L.rmsnorm(p["norm2"], x), cfg.cdtype)
    elif kind["ffn"] == "moe":
        y, moe_aux = moe_apply(p["moe"], L.rmsnorm(p["norm2"], x), cfg)
        x = x + y
        aux = aux + cfg.router_aux_coef * moe_aux["lb_loss"]
    return x, new_cache, aux


# ------------------------------------------------------------------- model init
def init(key, cfg):
    kinds = cfg.layer_kinds()
    params: dict[str, Any] = {
        "embed": L.embed_init(fold_in_name(key, "embed"), (cfg.vocab_size, cfg.d_model),
                              cfg.pdtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(fold_in_name(key, "lm_head"),
                                         (cfg.d_model, cfg.vocab_size), cfg.pdtype)
    if cfg.vlm:
        # learned projector bias stub (ViT weights are external / frozen)
        params["img_norm"] = L.init_rmsnorm(cfg.d_model, cfg.pdtype)

    # leading dense layers outside the scan (e.g. DeepSeek-MoE layer 0)
    dense_kind = {"mixer": kinds[0]["mixer"], "ffn": "dense"}
    params["pre_blocks"] = [
        _init_block(fold_in_name(key, f"pre{i}"), cfg, dense_kind)
        for i in range(cfg.first_dense)
    ]

    def init_period(k):
        return {f"l{j}": _init_block(fold_in_name(k, f"l{j}"), cfg, kind)
                for j, kind in enumerate(kinds)}

    pkeys = jax.random.split(fold_in_name(key, "periods"), cfg.n_periods)
    params["periods"] = jax.vmap(init_period)(pkeys)
    return params


# ------------------------------------------------------------------- embeddings
def _embed_inputs(params, tokens, cfg, image_embeds=None):
    x = params["embed"][tokens].astype(cfg.cdtype)
    if cfg.vlm and image_embeds is not None:   # decode steps carry no new images
        img = L.rmsnorm(params["img_norm"], image_embeds.astype(cfg.cdtype))
        x = jnp.concatenate([img, x], axis=1)
    return x


# --------------------------------------------------------------------- forward
def forward(params, tokens, cfg, *, mode, positions=None, caches=None,
            image_embeds=None):
    """Returns (hidden [B,S',d], new_caches, aux)."""
    kinds = cfg.layer_kinds()
    x = _embed_inputs(params, tokens, cfg, image_embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)

    aux_total = jnp.float32(0)
    pre_caches = []
    for i, bp in enumerate(params["pre_blocks"]):
        c_in = caches["pre"][i] if caches is not None else None
        x, c, aux = _apply_block(bp, x, cfg, {"mixer": kinds[0]["mixer"], "ffn": "dense"},
                                 positions=positions, mode=mode, cache=c_in)
        pre_caches.append(c)
        aux_total = aux_total + aux

    def period_fn(carry, scanned):
        xc, aux_acc = carry
        p_period, cache_period = scanned
        new_caches = {}
        for j, kind in enumerate(kinds):
            c_in = cache_period[f"l{j}"] if cache_period is not None else None
            xc, c, aux = _apply_block(p_period[f"l{j}"], xc, cfg, kind,
                                      positions=positions, mode=mode, cache=c_in)
            new_caches[f"l{j}"] = c
        return (xc, aux_acc + aux), new_caches

    if cfg.remat and mode == "train":
        if cfg.remat_policy == "save_mixer":
            # keep the expensive mixer (attention / SSM scan) outputs; only
            # recompute the cheap norm/FFN elementwise chains in backward
            policy = jax.checkpoint_policies.save_only_these_names("mixer_out")
            period_fn = jax.checkpoint(period_fn, policy=policy)
        else:
            period_fn = jax.checkpoint(period_fn)

    scan_caches = caches["periods"] if caches is not None else None
    if scan_caches is None:
        # substitute a None-free placeholder: scan needs matching pytrees
        (x, aux_total), out_caches = jax.lax.scan(
            lambda c, pp: period_fn(c, (pp, _none_cache_like(kinds))),
            (x, aux_total), params["periods"])
    else:
        (x, aux_total), out_caches = jax.lax.scan(
            period_fn, (x, aux_total), (params["periods"], scan_caches))

    x = L.rmsnorm(params["final_norm"], x)
    new_caches = {"pre": pre_caches, "periods": out_caches} \
        if (mode != "train") else None
    return x, new_caches, aux_total


def _none_cache_like(kinds):
    return {f"l{j}": None for j in range(len(kinds))}


# --------------------------------------------------------------------- heads
def _unembed_last(params, hidden, cfg):
    """Logits for the final position only: [B,d] @ [d,V]."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (hidden[:, -1].astype(jnp.float32) @ w.astype(jnp.float32))


# ----------------------------------------------------------------------- train
def loss_fn(params, batch, cfg):
    """batch: tokens/labels/mask [B,S(text)] (+ image_embeds for VLM).

    Returns (scalar loss, metrics dict). Image positions carry no loss.
    """
    tokens = batch["tokens"]
    image_embeds = batch.get("image_embeds")
    hidden, _, aux = forward(params, tokens, cfg, mode="train",
                             image_embeds=image_embeds)
    if cfg.vlm:
        n_img = image_embeds.shape[1]
        hidden = hidden[:, n_img:]
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"].T
    s_loss, s_cnt = L.chunked_softmax_xent(hidden, w, batch["labels"], batch["mask"],
                                           cfg.loss_chunk)
    task_loss = s_loss / jnp.maximum(s_cnt, 1)
    loss = task_loss + aux
    return loss, {"task_loss": task_loss, "aux_loss": aux, "tokens": s_cnt}


# --------------------------------------------------------------------- serving
def make_cache(cfg, batch_size, cache_len):
    """Zero-initialized decode cache for every layer (stacked per period)."""
    kinds = cfg.layer_kinds()
    W = min(cfg.sliding_window, cache_len) if cfg.sliding_window else cache_len
    B = batch_size
    H = cfg.num_heads
    cd = cfg.cdtype

    def one(kind):
        m = kind["mixer"]
        if m == "attn":
            if cfg.mla:
                return {"c_kv": jnp.zeros((B, W, cfg.kv_lora_rank), cd),
                        "k_rope": jnp.zeros((B, W, cfg.qk_rope_head_dim), cd),
                        "len": jnp.zeros((), jnp.int32)}
            return {"k": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), cd),
                    "v": jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), cd),
                    "len": jnp.zeros((), jnp.int32)}
        if m == "mamba":
            return {"conv": jnp.zeros((B, cfg.ssm_conv_dim - 1, cfg.d_inner), cd),
                    "h": jnp.zeros((B, cfg.d_inner, cfg.ssm_state_dim), jnp.float32)}
        if m == "mlstm":
            di = int(cfg.mlstm_proj_factor * cfg.d_model)
            hd = di // H
            return {"conv": jnp.zeros((B, cfg.ssm_conv_dim - 1, di), cd),
                    "C": jnp.zeros((B, H, hd, hd), jnp.float32),
                    "n": jnp.zeros((B, H, hd), jnp.float32),
                    "m": jnp.full((B, H), -1e30, jnp.float32)}
        if m == "slstm":
            d = cfg.d_model
            return {"conv": jnp.zeros((B, cfg.ssm_conv_dim - 1, d), cd),
                    "h": jnp.zeros((B, d), jnp.float32),
                    "c": jnp.zeros((B, d), jnp.float32),
                    "n": jnp.zeros((B, d), jnp.float32),
                    "m": jnp.full((B, d), -1e30, jnp.float32)}
        raise ValueError(m)

    period = {f"l{j}": one(kind) for j, kind in enumerate(kinds)}
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), period)
    kinds0 = {"mixer": kinds[0]["mixer"], "ffn": "dense"}
    pre = [one(kinds0) for _ in range(cfg.first_dense)]
    return {"pre": pre, "periods": stacked}


def prefill(params, batch, cfg):
    tokens = batch["tokens"]
    hidden, caches, _ = forward(params, tokens, cfg, mode="prefill",
                                image_embeds=batch.get("image_embeds"))
    return caches, _unembed_last(params, hidden, cfg)


def decode_step(params, caches, tokens, pos, cfg):
    """tokens: [B,1]; pos: scalar absolute position. -> (logits [B,V], caches)."""
    positions = jnp.asarray(pos).reshape(1)
    hidden, new_caches, _ = forward(params, tokens, cfg, mode="decode",
                                    positions=positions, caches=caches)
    return _unembed_last(params, hidden, cfg), new_caches
