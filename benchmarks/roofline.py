"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch x shape x mesh) record in results/dryrun:

    compute term    = FLOPs_dev / peak_FLOPs        (197 TFLOP/s bf16, v5e)
    memory term     = bytes_dev / HBM_bw            (819 GB/s)
    collective term = coll_bytes_dev / link_bw      (~50 GB/s/link ICI)

FLOPs/bytes/collective-bytes are the SCAN-CORRECTED per-device numbers from
repro.analysis.hlo (XLA's cost_analysis counts while bodies once; we
multiply by known_trip_count along the call graph). Rows also carry the
per-program collective CALL COUNTS at trip-count multiplicity
(``coll_n_by_op``) and the total collective bytes (``coll_bytes_dev``),
the same numbers fedlint's collective-budget rule gates on. MODEL_FLOPS
(useful
compute) is 6*N*D for training, 2*N_active*D for inference, computed from
the config; the ratio MODEL_FLOPS / (FLOPs_dev * devices) flags remat /
dispatch / padding waste.

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

FIX_NOTES = {
    "compute": "raise arithmetic efficiency: bigger per-device tiles, fuse "
               "elementwise chains, drop fp32 staging",
    "memory": "cut HBM traffic: fuse attention/scan intermediates (Pallas), "
              "keep activations bf16, remat less",
    "collective": "cut bytes on the wire: shard to kill resharding "
                  "all-gathers, overlap TP collectives, aggregate deltas "
                  "in bf16",
}


def model_flops(rec, cfg) -> float:
    """Useful FLOPs for the whole program execution (all devices)."""
    from repro.configs import INPUT_SHAPES
    shape = INPUT_SHAPES[rec["shape"]]
    N = rec["n_params"]
    N_act = active_params(cfg, N)
    if shape.kind == "train":
        # FedALIGN round: E local steps (6ND each) + the gating forward
        # (2ND); the server-batch forward is negligible and ignored.
        E = rec["meta"].get("local_steps", 5)
        D = shape.global_batch * shape.seq_len
        return (6 * E + 2) * N_act * D
    if shape.kind == "prefill":
        return 2 * N_act * shape.global_batch * shape.seq_len
    # decode: one token per sequence + attention reads don't count as FLOPs
    return 2 * N_act * shape.global_batch


def active_params(cfg, n_params) -> float:
    """MoE: only top_k (+shared) experts are active per token."""
    if not cfg.moe:
        return n_params
    # expert params per MoE layer
    ep_layer = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts
    n_moe_layers = sum(1 for i in range(cfg.num_layers - cfg.first_dense)
                       if cfg.layer_kinds()[i % cfg.period]["ffn"] == "moe")
    total_expert = ep_layer * n_moe_layers
    active_expert = total_expert * cfg.top_k / cfg.num_experts
    shared = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_shared_experts * n_moe_layers
    return n_params - total_expert + active_expert  # shared already in n_params


def analyze_record(path: str, *, use_hlo=True) -> dict | None:
    rec = json.load(open(path))
    if rec["status"] != "ok":
        return rec if rec["status"] == "skipped" else None
    from repro.configs import get_config
    from repro.launch.dryrun import adapt_config
    cfg = adapt_config(get_config(rec["arch"]), rec["shape"])

    hlo_path = path.replace(".json", ".hlo.txt.gz")
    if use_hlo and os.path.exists(hlo_path):
        from repro.analysis.hlo import analyze_file
        agg = analyze_file(hlo_path)
        flops_dev = agg["flops"]
        bytes_dev = agg["bytes"]
        coll_dev = agg["coll_total"]
        coll_by_op = {k: float(v) for k, v in agg["coll"].items()}
        coll_n_by_op = {k: float(v) for k, v in agg["coll_n"].items()}
    else:   # fall back to (scan-undercounted) XLA numbers
        flops_dev = rec.get("flops_per_device") or 0
        bytes_dev = rec.get("bytes_per_device") or 0
        coll_by_op = rec.get("collective_bytes_per_device", {})
        coll_dev = sum(coll_by_op.values())
        coll_n_by_op = {}

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec, cfg)
    hlo_total = flops_dev * rec["devices"]
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "devices": rec["devices"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else None,
        "coll_by_op": coll_by_op,
        "coll_n_by_op": coll_n_by_op,
        "coll_bytes_dev": coll_dev,
        "peak_bytes_dev": (rec.get("memory") or {}).get("peak_memory_in_bytes"),
        "fits_hbm": ((rec.get("memory") or {}).get("peak_memory_in_bytes", 0)
                     or 0) < 16e9,
        "note": FIX_NOTES[dominant],
        "status": "ok",
    }


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def run(fast=True, dir="results/dryrun", multi_pod=False):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("multi_pod", False) != multi_pod:
            continue
        out = analyze_record(path)
        if out is not None:
            rows.append(out)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = run(dir=args.dir, multi_pod=args.multi_pod)
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collective':>11s} {'dominant':>10s} {'useful':>7s} {'fits':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:12s} {'skipped':>9s}")
            continue
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        print(f"{r['arch']:24s} {r['shape']:12s} {fmt_s(r['compute_s']):>9s} "
              f"{fmt_s(r['memory_s']):>9s} {fmt_s(r['collective_s']):>11s} "
              f"{r['dominant']:>10s} {ur:>7s} {str(r['fits_hbm']):>5s}")
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            wr = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            wr.writeheader()
            for r in rows:
                if r.get("status") == "ok":
                    wr.writerow(r)


if __name__ == "__main__":
    main()
