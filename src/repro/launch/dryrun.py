"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, with memory/cost analysis and collective-bytes
extraction for the roofline (EXPERIMENTS.md SS Dry-run / SS Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k [--multi-pod] [--out results/dryrun]

No real arrays are ever allocated: params/batches/caches enter as
jax.ShapeDtypeStruct with NamedShardings attached.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count at first init).

import argparse
import gzip
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, ALIASES, INPUT_SHAPES, get_config
from repro.configs.base import FedConfig
from repro.configs.cli import add_fed_args, fed_from_args
from repro.fl import sharded
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.sharding.specs import (auto_batch_specs, auto_param_specs,
                                  auto_tree_specs, dp_axes,
                                  federation_state_specs, shaped_with)
from repro.utils import param_count

# shape-point skips with reasons (DESIGN.md SS4)
SKIPS = {
    ("whisper-medium", "long_500k"):
        "enc-dec audio: bounded decoder context; 524k-token transcript has no analogue",
}

# archs needing a sliding-window variant to run long_500k sub-quadratically
WINDOW_FOR_LONG = 8192

DRYRUN_FED = FedConfig(local_epochs=5, epsilon=0.2, lr=0.01)
TEMPORAL_COHORT = 4


def adapt_config(cfg, shape_name: str):
    """Per-shape config adjustments (documented in DESIGN.md)."""
    if shape_name == "long_500k" and cfg.pattern == "attn":
        # full-attention archs run long context via a sliding-window variant
        cfg = cfg.replace(sliding_window=WINDOW_FOR_LONG)
    if shape_name == "long_500k" and cfg.pattern == "jamba":
        # jamba's sparse attention layers use a window; mamba layers are O(1)
        cfg = cfg.replace(sliding_window=WINDOW_FOR_LONG)
    return cfg


def optimize_config(cfg, *, multi_pod: bool, model_axis: int = 16):
    """Beyond-paper perf variant (EXPERIMENTS.md SSPerf): bf16 attention
    matmuls everywhere; sequence-parallel attention when head counts don't
    divide the model axis; expert-parallel MoE when expert counts do."""
    kw = dict(attn_bf16=True,
              dp_axes=("pod", "data") if multi_pod else ("data",))
    # sequence-parallel attention pays off only when the score all-reduces
    # GSPMD would otherwise emit are huge (wide models with head counts not
    # divisible by the model axis). For small-d models the per-layer
    # reshards cost more than they save (granite: 2.6x regression — SSPerf).
    if (cfg.num_heads % model_axis or cfg.num_kv_heads % model_axis) \
            and cfg.d_model >= 4096:
        kw["seq_shard_attn"] = True
        # per-device scores [B,KV,G,Sq/16,block] must fit alongside params
        kw["attn_block_kv"] = 256
    # expert-parallel pays when experts are FINE-GRAINED: the all-to-all of
    # dispatched activations replaces expert-weight gathers, a win only when
    # weights are large relative to per-token activations (deepseek 1408-dim
    # experts: 2.3x; jamba 24576-dim experts: 1.8x REGRESSION — SSPerf).
    if cfg.moe and cfg.num_experts % model_axis == 0 and cfg.moe_d_ff <= 4096:
        kw["expert_parallel"] = True
    return cfg.replace(**kw)


def _token_batch_shapes(cfg, C, b, S, *, stacked: bool):
    """ShapeDtypeStructs for one client-stacked token batch."""
    lead = (C, b) if stacked else (b,)
    S_text = S - cfg.num_image_tokens if cfg.vlm else S
    d = {
        "tokens": jax.ShapeDtypeStruct(lead + (S_text,), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (S_text,), jnp.int32),
        "mask": jax.ShapeDtypeStruct(lead + (S_text,), jnp.float32),
    }
    if cfg.vlm:
        d["image_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_image_tokens, cfg.d_model), cfg.cdtype)
    if cfg.encdec:
        d["frames"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_frames, cfg.d_model), cfg.cdtype)
    return d


def build_train(cfg, shape, mesh, fed=DRYRUN_FED):
    model = get_model(cfg)
    fsdp = sharded.needs_fsdp(cfg)
    dp = dp_axes(mesh)
    dpsize = int(np.prod([mesh.shape[a] for a in dp]))
    B, S = shape.global_batch, shape.seq_len

    if fsdp:    # temporal: cohort scanned, inner batch sharded over dp
        C = TEMPORAL_COHORT
        b = B // C
        cspec_prefix = (None, dp)
    else:       # spatial: clients = dp shards
        C = dpsize
        b = B // C
        cspec_prefix = (dp, None)

    clients = _token_batch_shapes(cfg, C, b, S, stacked=True)
    server = _token_batch_shapes(cfg, None, min(b, 8) * 1, S, stacked=False)
    batch_shapes = {
        "clients": clients,
        "server": server,
        "priority_mask": jax.ShapeDtypeStruct((C,), jnp.float32),
        "weights": jax.ShapeDtypeStruct((C,), jnp.float32),
    }

    def batch_spec(leaf, *, is_client):
        nd = len(leaf.shape)
        if not is_client:
            sp = [None] * nd
            if leaf.shape and leaf.shape[0] % dpsize == 0 and leaf.shape[0] >= dpsize:
                sp[0] = dp
            return P(*sp)
        sp = list(cspec_prefix) + [None] * (nd - 2)
        return P(*sp)

    batch_specs = {
        "clients": jax.tree.map(lambda l: batch_spec(l, is_client=True), clients),
        "server": jax.tree.map(lambda l: batch_spec(l, is_client=False), server),
        "priority_mask": P(),
        "weights": P(),
    }

    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_specs = auto_param_specs(param_shapes, mesh, fsdp=fsdp,
                                   expert_parallel=cfg.expert_parallel)
    # the round input/output is the full FederationState: params keep their
    # auto specs, optimizer moments inherit them, client-state replicates
    from repro.fl import engine
    state_shapes = jax.eval_shape(
        lambda p: engine.init_state(p, fed, C), param_shapes)
    state_specs = federation_state_specs(fed, param_specs)

    step = sharded.make_round_step(model, fed, C, fsdp=fsdp)
    args = (shaped_with(state_shapes, state_specs, mesh),
            shaped_with(batch_shapes, batch_specs, mesh))
    in_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs))
    out_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs),
                     None)
    meta = {"mode": "train", "clients": C, "per_client_batch": b,
            "fsdp": fsdp, "local_steps": fed.local_epochs,
            "server_opt": fed.server_opt, "aggregator": fed.aggregator}
    return step, args, in_shardings, out_shardings, meta, param_shapes


def build_prefill(cfg, shape, mesh):
    model = get_model(cfg)
    fsdp = sharded.needs_fsdp(cfg)
    B, S = shape.global_batch, shape.seq_len
    batch_shapes = _token_batch_shapes(cfg, None, B, S, stacked=False)
    batch_specs = auto_batch_specs(batch_shapes, mesh)
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_specs = auto_param_specs(param_shapes, mesh, fsdp=fsdp,
                                   expert_parallel=cfg.expert_parallel)
    step = sharded.make_prefill_step(model)
    args = (shaped_with(param_shapes, param_specs, mesh),
            shaped_with(batch_shapes, batch_specs, mesh))
    in_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs))
    # output KV caches must be sharded too, or each device materializes the
    # full [layers, B, S, KV, hd] cache (llava: 16GB/device unsharded)
    with mesh:      # seq_shard_attn constraints need an ambient mesh
        out_shapes = jax.eval_shape(step, *args)
    cache_specs = auto_tree_specs(out_shapes[0], mesh, model_dim_order="last")
    dp = dp_axes(mesh)
    dpsize = int(np.prod([mesh.shape[a] for a in dp]))
    logit_spec = P(dp, None) if B % dpsize == 0 and B >= dpsize else P(None, None)
    out_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs),
                     NamedSharding(mesh, logit_spec))
    meta = {"mode": "prefill", "batch": B, "seq": S, "fsdp": fsdp}
    return step, args, in_shardings, out_shardings, meta, param_shapes


def build_decode(cfg, shape, mesh):
    model = get_model(cfg)
    fsdp = sharded.needs_fsdp(cfg)
    B, S = shape.global_batch, shape.seq_len
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_specs = auto_param_specs(param_shapes, mesh, fsdp=fsdp,
                                   expert_parallel=cfg.expert_parallel)
    cache_shapes = jax.eval_shape(lambda: model.make_cache(B, S))
    cache_specs = auto_tree_specs(cache_shapes, mesh)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    dp = dp_axes(mesh)
    dpsize = int(np.prod([mesh.shape[a] for a in dp]))
    tok_spec = P(dp, None) if B % dpsize == 0 and B >= dpsize else P(None, None)

    step = sharded.make_serve_step(model)
    args = (shaped_with(param_shapes, param_specs, mesh),
            shaped_with(cache_shapes, cache_specs, mesh),
            jax.ShapeDtypeStruct(tok.shape, tok.dtype, sharding=NamedSharding(mesh, tok_spec)),
            jax.ShapeDtypeStruct(pos.shape, pos.dtype, sharding=NamedSharding(mesh, P())))
    in_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs),
                    NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
    out_shardings = (None, jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs))
    meta = {"mode": "decode", "batch": B, "cache_len": S, "fsdp": fsdp,
            "window": cfg.sliding_window}
    return step, args, in_shardings, out_shardings, meta, param_shapes


BUILDERS = {"train": build_train, "prefill": build_prefill, "decode": build_decode}

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b")
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the (per-device) HLO."""
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes_blob, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(shapes_blob):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[op] = out.get(op, 0) + nbytes
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool, fed=DRYRUN_FED,
            variant: str = "baseline", cfg_overrides: dict | None = None):
    shape = INPUT_SHAPES[shape_name]
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
    cfg = adapt_config(get_config(arch), shape_name)
    if variant == "opt":
        cfg = optimize_config(cfg, multi_pod=multi_pod)
        fed = fed.replace(agg_dtype="bfloat16")   # bf16 deltas on the wire
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    builder = BUILDERS[shape.kind]
    t0 = time.time()
    build = (builder(cfg, shape, mesh, fed) if shape.kind == "train"
             else builder(cfg, shape, mesh))
    step, args, in_sh, out_sh, meta, param_shapes = build

    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # jax < 0.5 returned [dict]
        cost = cost[0] if cost else None
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)

    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "meta": meta, "variant": variant,
        "n_params": param_count(param_shapes),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops") if cost else None,
        "bytes_per_device": cost.get("bytes accessed") if cost else None,
        "collective_bytes_per_device": coll,
        "memory": _mem_dict(mem),
        "devices": int(np.prod(list(mesh.shape.values()))),
    }
    return rec, hlo_text


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    # every federation knob — async/aggregator/clock/failure/guard/codec/
    # pool — comes from the shared surface so this CLI can never drift
    # from the trainer's (tests/test_pool.py pins the two flag sets equal)
    add_fed_args(ap)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--dump-hlo", default=None, metavar="DIR",
                    help="write each lowered target's optimized HLO "
                         "(gzip, one file per combo) plus a .lintmeta.json "
                         "sidecar into DIR, so fedlint (scripts/fedlint.py "
                         "--hlo-dir DIR) and the roofline analyze the same "
                         "artifacts instead of re-lowering; default: the "
                         "HLO goes next to the records in --out")
    return ap


def main():
    args = build_parser().parse_args()

    # a default command line yields {} -> fed stays LITERALLY DRYRUN_FED,
    # so the lowered round is bit-identical to the pre-CLI-refactor one
    fed = DRYRUN_FED.replace(**fed_from_args(args))

    archs = ARCH_IDS if args.arch == "all" else [ALIASES.get(args.arch, args.arch)]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    os.makedirs(args.out, exist_ok=True)
    hlo_dir = args.dump_hlo or args.out
    os.makedirs(hlo_dir, exist_ok=True)
    failures = []
    for a in archs:
        cfg_name = get_config(a).name
        for s in shapes:
            tag = f"{cfg_name}__{s}__{'multi' if args.multi_pod else 'single'}"
            if args.variant != "baseline":
                tag += f"__{args.variant}"
            if args.async_depth > 0:
                tag += f"__async{args.async_depth}"
                if args.async_mode != "fifo":
                    tag += f"__{args.async_mode}{args.min_lag}"
                if args.adaptive_staleness:
                    tag += "__adaptive"
            if args.aggregator != "mean":
                tag += f"__{args.aggregator}"
            if args.latency_mode != "none":
                tag += f"__clock-{args.latency_mode}"
                if args.round_deadline != float("inf"):
                    tag += f"-dl{args.round_deadline:g}"
            if args.failure_model != "none":
                tag += f"__{args.failure_model}"
            if args.divergence_guard:
                tag += "__guard"
            if args.wire_codec != "identity":
                tag += f"__codec-{args.wire_codec}"
                if not args.error_feedback:
                    tag += "-noef"
            if args.candidate_pool > 0:
                tag += f"__pool{args.candidate_pool}"
                if args.pool_weighting != "uniform":
                    tag += f"-{args.pool_weighting}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip-existing] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                out = run_one(cfg_name, s, multi_pod=args.multi_pod,
                              variant=args.variant, fed=fed)
                if isinstance(out, tuple):
                    rec, hlo_text = out
                    with gzip.open(os.path.join(hlo_dir, tag + ".hlo.txt.gz"),
                                   "wt") as hf:
                        hf.write(hlo_text)
                    if args.dump_hlo:
                        # sidecar the knobs fedlint's allowances key on, so
                        # lint_hlo_text over the dump needs no re-lowering
                        devices = rec.get("devices", 0)
                        lint_meta = {"tag": tag, "pod": True, "rounds": 1,
                                     "m_total": rec["n_params"],
                                     "devices": devices,
                                     "devices_per_pod":
                                         devices // 2 if args.multi_pod
                                         else devices,
                                     "aggregator": fed.aggregator,
                                     "wire_codec": fed.wire_codec,
                                     "agg_dtype": fed.agg_dtype}
                        with open(os.path.join(hlo_dir,
                                               tag + ".lintmeta.json"),
                                  "w") as mf:
                            json.dump(lint_meta, mf, indent=1)
                else:
                    rec = out
            except Exception as e:  # noqa: BLE001 — record failures, keep going
                rec = {"arch": cfg_name, "shape": s, "multi_pod": args.multi_pod,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            if rec["status"] == "error":
                failures.append((tag, rec["error"]))
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  -> {rec['status']}"
                  + (f" compile={rec.get('compile_s')}s" if rec["status"] == "ok" else
                     f" {rec.get('reason', rec.get('error', ''))[:200]}"), flush=True)
    # a broken lowering must fail the process, not just leave an error
    # record on disk — CI was going green on status:error JSONs
    if failures:
        print(f"\n[dryrun] {len(failures)} target(s) FAILED to lower/compile:")
        for tag, err in failures:
            print(f"  FAIL {tag}: {err[:200]}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
