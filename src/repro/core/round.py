"""FedALIGN communication-round engine (vmap in-silico federation).

One jitted ``round_fn`` executes a full communication round:

  1. server broadcasts w_t (implicit: vmap over the client axis);
  2. every client evaluates F_k(w_t) on its local data (full batch);
  3. server loss F(w_t) = sum_{k in P} p_k F_k(w_t);
  4. gates I_{k,t} from the FedALIGN rule (core/alignment.py);
  5. E local epochs of minibatch SGD (or FedProx) per client;
  6. renormalized gated aggregation (core/aggregation.py).

Works for any (loss_fn, params) pair — the paper's logreg/2NN/CNN and the
LM-scale models alike. For pod-scale runs see fl/sharded.py, which maps the
client axis onto the device mesh instead of vmap.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import aggregate_clients
from repro.core.alignment import epsilon_at, global_loss_from_locals, inclusion_gates
from repro.optim.schedules import make_schedule
from repro.utils import tree_axpy


def _local_solver(loss_fn, fed):
    """Returns f(global_params, data, rng, lr) -> local params after E epochs."""
    E = fed.local_epochs
    prox_mu = fed.prox_mu if fed.algorithm == "fedprox" else 0.0

    def solve(global_params, data, rng, lr):
        n = data["y"].shape[0]
        bs = min(fed.batch_size, n)
        steps = n // bs

        def epoch(params, ekey):
            perm = jax.random.permutation(ekey, n)[:steps * bs].reshape(steps, bs)

            def step(p, idx):
                batch = jax.tree.map(lambda a: a[idx], data)
                grads = jax.grad(lambda q: loss_fn(q, batch)[0])(p)
                if prox_mu > 0.0:
                    grads = jax.tree.map(lambda g, q, w0: g + prox_mu * (q - w0),
                                         grads, p, global_params)
                return tree_axpy(-lr, grads, p), None

            params, _ = jax.lax.scan(step, params, perm)
            return params, None

        ekeys = jax.random.split(rng, E)
        params, _ = jax.lax.scan(epoch, global_params, ekeys)
        return params

    return solve


def make_round_fn(loss_fn: Callable, fed) -> Callable:
    """loss_fn(params, batch)->(loss, metrics); batch={'x','y'} (or tokens).

    Returns round_fn(global_params, data, priority_mask, weights, rng,
    round_idx) -> (new_global, stats). ``data`` leaves have leading client
    axis [C, n, ...]."""
    solver = _local_solver(loss_fn, fed)
    sched = make_schedule(fed)
    warmup_rounds = int(fed.warmup_frac * fed.rounds)

    def round_fn(global_params, data, priority_mask, weights, rng, round_idx):
        C = priority_mask.shape[0]
        lr = sched(round_idx)
        eps = epsilon_at(fed, round_idx)

        # (2) local loss/accuracy of the *received* model. The paper's
        # experiments (§3.1 "In practice...") match ACCURACIES with eps=0.2;
        # the theory matches losses. Both are supported via fed.align_stat.
        local_losses, local_metrics = jax.vmap(
            lambda d: loss_fn(global_params, d))(data)
        if fed.align_stat == "accuracy" and "acc" in local_metrics:
            align_vals = local_metrics["acc"]
        else:
            align_vals = local_losses
        # (3) global (priority) statistic F(w_t) resp. acc(w_t)
        g_loss = global_loss_from_locals(local_losses, priority_mask, weights)
        g_align = global_loss_from_locals(align_vals, priority_mask, weights)

        # participation sampling (paper App. C.3 / A.4)
        rng, pkey = jax.random.split(rng)
        if fed.participation < 1.0:
            part = jax.random.bernoulli(pkey, fed.participation, (C,))
            # never let the priority set go empty
            part = part | (jnp.sum(part & priority_mask) == 0) & priority_mask
        else:
            part = jnp.ones((C,), bool)
        if fed.straggler_period > 0:
            # App. A.4 arbitrary participation: straggler k joins every
            # (2 + k % period) rounds; priority clients are never stragglers
            cadence = 2 + jnp.arange(C) % fed.straggler_period
            available = (round_idx % cadence) == 0
            part = part & (available | priority_mask)

        warm = round_idx < warmup_rounds
        gates_open = inclusion_gates(align_vals, g_align, eps, priority_mask,
                                     warmup=False, participation_mask=part,
                                     selection=fed.selection)
        gates_warm = inclusion_gates(align_vals, g_align, eps, priority_mask,
                                     warmup=True, participation_mask=part,
                                     selection=fed.selection)
        gates = jnp.where(warm, gates_warm, gates_open)

        # (5) local training for every client (masked clients train too but
        #     are dropped at aggregation — fine at simulator scale)
        rng, lkey = jax.random.split(rng)
        lkeys = jax.random.split(lkey, C)
        client_params = jax.vmap(lambda d, k: solver(global_params, d, k, lr))(data, lkeys)

        # (6) renormalized gated aggregation
        new_global = aggregate_clients(client_params, weights, gates)

        npri = (1.0 - priority_mask.astype(jnp.float32))
        included_mass = jnp.sum(npri * weights * gates)
        stats = {
            "round": round_idx,
            "lr": lr,
            "eps": eps,
            "global_loss": g_loss,
            "local_losses": local_losses,
            "gates": gates,
            "theta_round": 1.0 / (1.0 + included_mass),   # paper eq. (7) term
            "included_nonpriority": jnp.sum(npri * gates),
            "warmup": warm.astype(jnp.int32) if hasattr(warm, "astype") else jnp.int32(warm),
        }
        return new_global, stats

    return round_fn
