"""Uniclass-shard federated partitioning (McMahan et al. / paper App. B.1)
over class-structured synthetic stand-ins for FMNIST / EMNIST / CIFAR-10.

The container is offline, so the three benchmark datasets are replaced by
Gaussian class-prototype data with *matching* input dims, class counts and
shard statistics:

    fmnist : 784 dims, 10 classes, 120 shards x 500, 2 shards/client, N=60
    emnist : 784 dims, 47 classes, 600 shards x 180, 24 shards/client (bal.)
    cifar  : 32x32x3,  10 classes, 120 shards x 500, 2 shards/client

Distributional structure (uniclass shards -> extreme label skew per client)
is what drives the paper's heterogeneity claims and is preserved exactly.
"""
from __future__ import annotations


import numpy as np

from repro.data.synth import Federation

SPECS = {
    "fmnist": dict(dim=(784,), classes=10, shards=120, shard_size=500,
                   shards_per_client=2, clients=60),
    "emnist": dict(dim=(784,), classes=47, shards=600, shard_size=180,
                   shards_per_client=24, clients=25),
    "cifar": dict(dim=(32, 32, 3), classes=10, shards=120, shard_size=500,
                  shards_per_client=2, clients=60),
}


def _prototype_data(rng, n, dim, classes, sep=0.2, noise=1.5, protos=None, y=None):
    """Gaussian class-prototype data: x = mu_c + noise, structured enough
    that class identity is learnable by the paper's models."""
    if protos is None:
        protos = rng.normal(0, sep / np.sqrt(np.prod(dim)),
                            size=(classes,) + tuple(dim))
    if y is None:
        y = rng.integers(0, classes, n)
    x = protos[y] + rng.normal(0, noise / np.sqrt(np.prod(dim)), size=(n,) + tuple(dim))
    return x.astype(np.float32), y.astype(np.int32), protos


def make_benchmark_federation(dataset="fmnist", seed=0, n_priority=2,
                              clients=None, samples_per_client=None,
                              test_samples=2000) -> Federation:
    """Uniclass shards, ``shards_per_client`` each, first ``n_priority``
    clients are priority. Matches the paper's N=60, |P|=2 default."""
    spec = dict(SPECS[dataset])
    if clients is not None:
        spec["clients"] = clients
    rng = np.random.default_rng(seed)
    # uniclass shards BY CONSTRUCTION: round-robin classes across shards so
    # every shard holds exactly one class (paper App. B.1 guarantee)
    shard_classes = np.arange(spec["shards"]) % spec["classes"]
    y_all = np.repeat(shard_classes, spec["shard_size"])
    x, y, protos = _prototype_data(rng, len(y_all), spec["dim"],
                                   spec["classes"], y=y_all)
    shards_x = x.reshape((spec["shards"], spec["shard_size"]) + tuple(spec["dim"]))
    shards_y = y.reshape(spec["shards"], spec["shard_size"])

    C = spec["clients"]
    spc = spec["shards_per_client"]
    assert C * spc <= spec["shards"], (C, spc, spec["shards"])
    assign = rng.permutation(spec["shards"])[:C * spc].reshape(C, spc)

    cx = shards_x[assign].reshape((C, spc * spec["shard_size"]) + tuple(spec["dim"]))
    cy = shards_y[assign].reshape(C, spc * spec["shard_size"])
    if samples_per_client is not None:
        keep = min(samples_per_client, cx.shape[1])
        sel = rng.permutation(cx.shape[1])[:keep]
        cx, cy = cx[:, sel], cy[:, sel]
    # per-client shuffle
    for i in range(C):
        p = rng.permutation(cx.shape[1])
        cx[i], cy[i] = cx[i][p], cy[i][p]

    priority_mask = np.zeros(C, bool)
    priority_mask[:n_priority] = True
    weights = np.full(C, 1.0 / n_priority, np.float32)

    test_x, test_y, _ = _prototype_data(rng, test_samples, spec["dim"],
                                        spec["classes"], protos=protos)
    # global test drawn from the same prototypes, restricted to priority classes
    pri_classes = np.unique(cy[:n_priority])
    keep = np.isin(test_y, pri_classes)
    return Federation(cx, cy, priority_mask, weights,
                      test_x[keep], test_y[keep],
                      client_test_x=None, client_test_y=None)
