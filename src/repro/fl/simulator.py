"""In-silico federation driver: whole-run scanned FedALIGN rounds,
evaluation + history logging. This is the engine behind every paper
experiment (benchmarks/bench_*.py).

The driver is NOT a per-round python loop: rounds are executed as
``lax.scan`` chunks of ``eval_every`` rounds inside one jitted program with
a donated ``FederationState`` carry (params + server-optimizer moments +
overflow backlog + utility EMAs travel as ONE pytree), so the host
dispatches (and syncs) once per eval point instead of once per round.
Per-round stats come back as stacked device arrays and cross to the host
in one transfer per chunk.

Runs are resumable: ``save_federation_state``/``load_federation_state``
checkpoint the full (state, rng) pair via ``checkpoint/io.py``, and
``run_federation(state=..., rng=..., start_round=...)`` continues a run
bit-identically — the PRNG stream is split once per round inside the scan
body, so chunking and resume points never perturb it. This covers the
``scan_async`` backend's in-flight cohort buffer too: staggered cohorts
are just more FederationState, so async pipelines checkpoint, resume, and
chunk mid-flight with no extra machinery.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree
from repro.core.metrics import History
from repro.core.round import init_state, make_round_fn
from repro.data.synth import Federation


@functools.partial(jax.jit, static_argnames=("loss_fn",))
def _eval_batches(loss_fn, params, xb, yb):
    """[m, batch, ...] test shards -> (sum of per-batch mean losses, accs)."""
    def body(carry, b):
        loss, m = loss_fn(params, b)
        return carry, (loss, m["acc"])

    _, (losses, accs) = jax.lax.scan(body, 0, {"x": xb, "y": yb})
    return jnp.sum(losses), jnp.sum(accs)


@functools.partial(jax.jit, static_argnames=("loss_fn",))
def _eval_one(loss_fn, params, b):
    loss, m = loss_fn(params, b)
    return loss, m["acc"]


def evaluate(loss_fn, params, x, y, batch=4096):
    """Mean loss and accuracy over a test set: one jitted scan over the
    full-size batches (plus one call for the remainder) and a SINGLE
    device->host transfer, instead of a ``float()`` sync per batch."""
    x, y = jnp.asarray(x), jnp.asarray(y)
    n = y.shape[0]
    bs = min(batch, n)
    m, rem = divmod(n, bs)
    loss_tot = acc_tot = jnp.float32(0.0)
    if m:
        ls, as_ = _eval_batches(loss_fn, params,
                                x[:m * bs].reshape(m, bs, *x.shape[1:]),
                                y[:m * bs].reshape(m, bs, *y.shape[1:]))
        loss_tot, acc_tot = ls * bs, as_ * bs
    if rem:
        lr_, ar_ = _eval_one(loss_fn, params,
                             {"x": x[m * bs:], "y": y[m * bs:]})
        loss_tot, acc_tot = loss_tot + lr_ * rem, acc_tot + ar_ * rem
    out = np.asarray(jnp.stack([loss_tot, acc_tot])) / n
    return float(out[0]), float(out[1])


def _state_fingerprint(fed) -> Optional[dict]:
    """The run knobs whose resume mismatch changes NO leaf shape — a fifo
    resume of a ready-mode buffer (or a different min_lag) would silently
    reinterpret the slot ages, and a resume under a different aggregator
    silently changes what the restored optimizer moments mean — so they
    ride the checkpoint as validatable metadata instead. Only non-default
    knobs are recorded (an empty fingerprint is omitted), keeping old
    checkpoints loadable."""
    if fed is None:
        return None
    fp = {}
    if fed.async_depth > 0:
        fp.update(async_mode=fed.async_mode, min_lag=int(fed.min_lag),
                  adaptive_staleness=bool(fed.adaptive_staleness))
    from repro.core.aggregation import resolve_aggregator
    from repro.fl.engine import resolve_failure_model
    agg = resolve_aggregator(getattr(fed, "aggregator", "mean"))
    if agg != "mean":
        fp["aggregator"] = agg
    # event clock: the latency leaves are drawn once at init, so a resume
    # under different latency_* knobs would keep the WRITER's draws while
    # pushing timers from the reader's deadline — shape-invisible drift
    if fed.latency_mode != "none":
        fp.update(latency_mode=fed.latency_mode,
                  latency_mu=float(fed.latency_mu),
                  latency_sigma=float(fed.latency_sigma),
                  latency_net_mu=float(fed.latency_net_mu),
                  latency_net_sigma=float(fed.latency_net_sigma))
    if float(fed.round_deadline) != float("inf"):
        fp["round_deadline"] = float(fed.round_deadline)
    fm = resolve_failure_model(getattr(fed, "failure_model", "none"))
    if fm != "none":
        fp.update(failure_model=fm, crash_rate=float(fed.crash_rate),
                  dropout_rate=float(fed.dropout_rate),
                  dropout_len=int(fed.dropout_len),
                  corrupt_rate=float(fed.corrupt_rate),
                  corrupt_scale=float(fed.corrupt_scale))
    # wire codec: the EF accumulators carry residuals of the WRITER's
    # codec/rate knobs — resuming under a different codec (or topk/sketch
    # rate) would re-inject residuals that no longer describe the wire,
    # and (EF off) the compressed stream itself would change mid-run
    from repro.core.aggregation import resolve_wire_codec
    wc = resolve_wire_codec(getattr(fed, "wire_codec", "identity"))
    if wc != "identity":
        fp.update(wire_codec=wc, error_feedback=bool(fed.error_feedback))
        if wc == "topk":
            fp["codec_topk_frac"] = float(fed.codec_topk_frac)
        if wc == "sketch":
            fp["codec_sketch_dim"] = int(fed.codec_sketch_dim)
    # candidate pool: shape-invisible (pooling adds NO leaves — the dense
    # [C] leaves are only gathered/scattered), but a resume under a
    # different pool size or weighting samples different candidate pools
    # from round r on, so the restored backlog/EMA leaves would advance
    # for different clients than the writer's run
    cp = int(getattr(fed, "candidate_pool", 0))
    if cp > 0:
        fp.update(candidate_pool=cp,
                  pool_weighting=str(getattr(fed, "pool_weighting",
                                             "uniform")))
    return fp or None


def save_federation_state(path: str, state, rng, round_idx: int,
                          fed=None) -> None:
    """Checkpoint the FULL cross-round carry — FederationState (params,
    server-optimizer moments, backlog, utility EMAs) AND the driver PRNG
    key — as one msgpack pytree (checkpoint/io.py). Pass ``fed`` so async
    runs record their buffer-policy fingerprint and non-mean aggregators
    their registry name (``_state_fingerprint``) for resume-time
    validation."""
    save_pytree(path, {"state": state, "rng": rng}, step=int(round_idx),
                meta=_state_fingerprint(fed))


def load_federation_state(path: str, like_state, fed=None):
    """Restore (state, rng, next_round) saved by ``save_federation_state``.
    ``like_state`` fixes the pytree structure/shapes (``init_state`` with
    the run's config produces one). Pass ``fed`` to ALSO validate the
    shape-invisible knobs against the writer's recorded fingerprint:
    resuming a ready-mode buffer under fifo (or a different min_lag) would
    silently pop the restored slot ages on the wrong schedule, and resuming
    a robust/dp run under a different aggregator silently changes the
    semantics of the restored moments — a mismatch raises instead.
    Checkpoints written before fingerprints existed carry no metadata and
    load unvalidated."""
    tree, step, meta = load_pytree(path, {"state": like_state,
                                          "rng": jax.random.PRNGKey(0)})
    if fed is not None and meta is not None:
        want = _state_fingerprint(fed) or {}
        if meta != want:
            raise ValueError(
                f"checkpoint {path!r} was written with run fingerprint "
                f"{meta} but this config resumes with {want or '{}'} — "
                "async slot ages/timers would pop on the wrong schedule, "
                "the optimizer moments would be fed by a different "
                "aggregator, the restored error-feedback accumulators "
                "would re-inject residuals of a different wire codec (or "
                "topk/sketch rate), and/or the fault-injection stream "
                "would diverge from the writer's, and/or the candidate-pool "
                "sampler would draw different pools from this round on. "
                "Resume with the writer's async_mode/min_lag/"
                "adaptive_staleness/aggregator/latency_*/round_deadline/"
                "failure-model/wire_codec/error_feedback/codec-rate/"
                "candidate_pool/pool_weighting knobs (or drain the buffer "
                "before switching policies)")
    return tree["state"], tree["rng"], step


def _chunk_body(round_fn, data, pm, w, state, rng, r0, n):
    """n rounds as one scanned program; stats leaves come back [n, ...].
    The whole FederationState is the scan carry — params, optimizer
    moments, backlog, and EMAs update in place. ONE implementation shared
    by ``run_federation``'s jitted ``run_chunk`` (which donates the
    carry) and ``capture_chunk_program`` (which hands the same program to
    the static analyzer), so what fedlint checks is what the driver
    runs."""
    def body(carry, i):
        state, rng = carry
        rng, rkey = jax.random.split(rng)
        state, stats = round_fn(state, data, pm, w, rkey, r0 + i)
        return (state, rng), stats

    (state, rng), stats = jax.lax.scan(
        body, (state, rng), jnp.arange(n, dtype=jnp.int32))
    return state, rng, stats


def capture_chunk_program(loss_fn, init_params, fed, federation: Federation,
                          *, n: int = 2, start_round: int = 0):
    """The EXACT scanned chunk program ``run_federation`` jits, packaged
    for static analysis instead of execution:

        fn, args, donate, meta = capture_chunk_program(loss_fn, p0, fed, fedn)
        report = repro.analysis.lint_program(fn, args, fed,
                                             donate_argnums=donate, meta=meta)

    ``fn(state, rng, r0)`` runs ``n`` rounds (``n`` is bound statically,
    as in the driver); ``args`` holds a freshly initialized state, the
    seed key, and the start round; ``donate`` mirrors the driver's
    ``donate_argnums=(0, 1)``. ``meta`` carries the wire width
    (``m_total``), client count, and round count the lint rules key on.
    Note the chunk closes over the federation data — by design (it is
    round-invariant) — so the no-large-literal rule sees it; keep lint
    federations small, or lint ``make_round_fn``'s output directly with
    ShapeDtypeStruct args for huge-C analyses."""
    from repro.core.aggregation import check_client_weights
    from repro.utils import param_count
    round_fn = make_round_fn(loss_fn, fed)
    data = {"x": jnp.asarray(federation.x), "y": jnp.asarray(federation.y)}
    pm = jnp.asarray(federation.priority_mask)
    w = jnp.asarray(check_client_weights(federation.weights,
                                         where="Federation.weights"))
    C = int(pm.shape[0])
    state = init_state(init_params, fed, C)
    rng = jax.random.PRNGKey(fed.seed)

    def fn(state, rng, r0):
        return _chunk_body(round_fn, data, pm, w, state, rng, r0, n)

    args = (state, rng, jnp.int32(start_round))
    meta = {"m_total": param_count(init_params), "num_clients": C,
            "rounds": n}
    return fn, args, (0, 1), meta


def run_federation(loss_fn: Callable, init_params, fed, federation: Federation,
                   *, eval_every: int = 1, verbose: bool = False,
                   state=None, rng=None, start_round: int = 0,
                   checkpoint_path: Optional[str] = None,
                   drain_inflight: bool = False) -> History:
    """Run FedALIGN communication rounds ``start_round .. fed.rounds - 1``.

    ``init_params`` seeds a fresh FederationState; pass ``state``/``rng``
    (from ``load_federation_state``) plus ``start_round`` to resume a
    checkpointed run bit-identically instead. ``checkpoint_path`` writes
    the full (state, rng) carry at every chunk boundary (the host sync
    points), so a killed run loses at most ``eval_every`` rounds.

    ``backend="scan_async"`` runs (``fed.async_depth`` staggered cohorts)
    need no special handling here: the in-flight delta buffer is ordinary
    FederationState, so it rides the donated scan carry and the chunk-
    boundary checkpoints like the optimizer moments do — a mid-flight
    resume restores the pipeline bit-identically. ``drain_inflight=True``
    additionally flushes still-in-flight cohort deltas into the params
    after the final round (``engine.drain_inflight``) — and, when
    ``checkpoint_path`` is set, rewrites the final checkpoint with the
    drained state so resuming it can never re-apply the flushed deltas;
    the default leaves them in ``hist.state.inflight``, exactly as a
    checkpoint would."""
    from repro.core.aggregation import check_client_weights
    round_fn = make_round_fn(loss_fn, fed)
    data = {"x": jnp.asarray(federation.x), "y": jnp.asarray(federation.y)}
    pm = jnp.asarray(federation.priority_mask)
    # the last host-side boundary where the weights are still concrete:
    # inside the jitted round they are tracers and a bad p_k (negative/NaN
    # from a broken shard spec) would sign-flip/poison silently
    w = jnp.asarray(check_client_weights(federation.weights,
                                         where="Federation.weights"))
    C = int(pm.shape[0])
    if state is None:
        state = init_state(init_params, fed, C)
    # private copy: chunk buffers are donated, and the caller keeps ownership
    # of whatever it passed in
    state = jax.tree.map(lambda a: jnp.array(a, copy=True), state)
    rng = jax.random.PRNGKey(fed.seed) if rng is None else jnp.asarray(rng)
    hist = History()

    @functools.partial(jax.jit, static_argnames=("n",),
                       donate_argnums=(0, 1))
    def run_chunk(state, rng, r0, *, n):
        """The scanned chunk (``_chunk_body``) with the FederationState
        carry and driver key donated — update in place, no copy."""
        return _chunk_body(round_fn, data, pm, w, state, rng, r0, n)

    # chunk boundaries = the eval rounds of the old per-round loop
    # (r % eval_every == 0, plus the final round), so logging cadence and
    # History contents are unchanged — only the dispatch granularity is.
    # Resumed runs keep the ABSOLUTE boundaries so their eval/log cadence
    # matches an uninterrupted run exactly.
    bounds = sorted(b for b in set(range(0, fed.rounds, eval_every))
                    | {fed.rounds - 1} if b >= start_round)
    halt_skips = (int(fed.max_nonfinite_skips)
                  if fed.divergence_guard else 0)
    hist.diverged_at = None
    start = start_round
    for b in bounds:
        n = b - start + 1
        state, rng, stats = run_chunk(state, rng, jnp.int32(start), n=n)
        stats_np = jax.tree.map(np.asarray, stats)   # one transfer per chunk
        tl, ta = evaluate(loss_fn, state.params,
                          federation.test_x, federation.test_y)
        for i in range(n):
            s = {k: v[i] for k, v in stats_np.items()}
            if i == n - 1:
                hist.log(s, test_acc=ta, test_loss=tl)
                if verbose:
                    print(f"  round {b:4d} loss={float(s['global_loss']):.4f} "
                          f"test_acc={ta:.4f} "
                          f"inc={float(s['included_nonpriority']):.1f}")
            else:
                hist.log(s)
        if checkpoint_path is not None:
            save_federation_state(checkpoint_path, state, rng, b + 1, fed=fed)
        start = b + 1
        if halt_skips > 0:
            # divergence-guard halt: the scanned chunk already skipped
            # every non-finite apply bit-exactly; once the CONSECUTIVE skip
            # counter crosses the budget the model is not recovering, so
            # stop launching chunks and report instead of scanning NaNs
            # for the rest of the schedule.
            skips = np.asarray(stats_np["skipped_nonfinite"])
            hit = np.flatnonzero(skips >= halt_skips)
            if hit.size:
                hist.diverged_at = int(b - n + 1 + hit[0])
                print(f"run_federation: halting at round {hist.diverged_at} "
                      f"— {int(skips[hit[0]])} consecutive non-finite "
                      f"aggregates (>= max_nonfinite_skips="
                      f"{halt_skips}); params are the last finite ones")
                break
    if drain_inflight:
        from repro.fl import engine
        had_buffer = isinstance(state.inflight, dict)
        state = engine.drain_inflight(fed, state)
        if checkpoint_path is not None and had_buffer:
            # the final chunk-boundary checkpoint above predates the drain:
            # resuming from it and draining again would re-apply the same
            # in-flight cohort deltas. Rewrite it with the DRAINED state
            # (same next-round step), so a resume sees an empty buffer and
            # a second drain is a no-op.
            save_federation_state(checkpoint_path, state, rng, fed.rounds,
                                  fed=fed)
    hist.params = state.params
    hist.state = state
    hist.rng = rng
    # DP budget actually spent (None unless aggregator='dp' with noise):
    # one Gaussian mechanism per EXECUTED round since round 0 — a resumed
    # run composes with the rounds it resumed from — at the config's
    # target delta, via the RDP accountant
    from repro.core.aggregation import dp_report
    # `start` is one past the last executed chunk — a divergence halt still
    # ran (and noised) every round of its final chunk inside the scan
    dp = dp_report(fed, start)
    hist.dp_epsilon, hist.dp_delta = (dp if dp is not None else (None, None))
    return hist


def run_local_baseline(loss_fn, init_fn, fed, federation: Federation,
                       *, epochs: int = None, client_ids=None):
    """Paper App. C.1: train each client alone on its local data; report the
    per-client locally-trained model accuracy on the global test set."""
    from repro.core.round import _local_solver
    epochs = epochs or fed.rounds * fed.local_epochs
    fed_local = fed
    solver = _local_solver(loss_fn, fed_local)
    C = federation.x.shape[0]
    client_ids = client_ids if client_ids is not None else range(C)
    rng = jax.random.PRNGKey(fed.seed + 1)

    @jax.jit
    def train_one(d, key, params0):
        # reuse the E-epoch solver repeatedly to reach `epochs`
        def body(p, k):
            return solver(p, d, k, jnp.float32(fed.lr)), None
        keys = jax.random.split(key, max(epochs // fed.local_epochs, 1))
        p, _ = jax.lax.scan(body, params0, keys)
        return p

    accs = {}
    for c in client_ids:
        rng, k = jax.random.split(rng)
        d = {"x": jnp.asarray(federation.x[c]), "y": jnp.asarray(federation.y[c])}
        p = train_one(d, k, init_fn(jax.random.PRNGKey(fed.seed + 100 + c)))
        _, acc = evaluate(loss_fn, p, federation.test_x, federation.test_y)
        accs[c] = acc
    return accs
