from repro.fl.simulator import evaluate, run_federation, run_local_baseline  # noqa: F401
from repro.fl.engine import (BACKENDS, STRATEGIES, SelectionContext,  # noqa: F401
                             compute_gates, get_strategy, make_round_fn,
                             register_strategy)
