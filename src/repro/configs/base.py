"""Model / run configuration dataclasses.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact full-size config) and ``smoke_config()`` (a reduced
variant of the same family: <=2 layers-per-period repeats, d_model<=512,
<=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention
    causal: bool = True

    # --- MLA (DeepSeek/MiniCPM3-style latent attention) ---------------------
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    moe_every: int = 1                # MoE FFN on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- layer pattern ------------------------------------------------------
    # "attn"  : homogeneous attention blocks
    # "jamba" : period 8 = [attn, mamba x7]; MoE every other layer
    # "xlstm" : period 2 = [mlstm, slstm]
    pattern: str = "attn"
    first_dense: int = 0              # leading layers with dense FFN (DeepSeek-MoE: 1)

    # --- SSM (mamba) ----------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 -> ceil(d_model/16)
    ssm_chunk: int = 256              # chunked-scan length (train/prefill)

    # --- xLSTM ----------------------------------------------------------------
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # --- encoder/decoder (whisper) -------------------------------------------
    encdec: bool = False
    encoder_layers: int = 0
    num_frames: int = 1500            # stubbed conv-frontend output length

    # --- VLM (llava) -----------------------------------------------------------
    vlm: bool = False
    num_image_tokens: int = 0         # stubbed ViT/projector output tokens

    # --- numerics --------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = True

    # --- execution knobs ---------------------------------------------------------
    attn_block_q: int = 512           # flash-attention query block
    attn_block_kv: int = 1024         # flash-attention kv block
    loss_chunk: int = 512             # chunked softmax-xent sequence chunk
    remat: bool = True                # checkpoint each scanned period
    remat_policy: str = "full"        # full | save_mixer (keep attention/scan
                                      # outputs; don't recompute them in bwd)
    use_pallas: bool = False          # TPU kernels (CPU falls back to refs)
    # beyond-paper perf knobs (EXPERIMENTS.md SSPerf):
    seq_shard_attn: bool = False      # sequence-parallel attention: shard S over
                                      # "model" when heads % model_axis != 0
    attn_bf16: bool = False           # bf16 qk^T / pv matmuls (f32 softmax state)
    expert_parallel: bool = False     # shard MoE experts (not dff) over "model"
    dp_axes: tuple = ("data",)        # data-parallel mesh axes for constraints

    # --- citation / provenance ------------------------------------------------
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))

    # ------------------------------------------------------------------ helpers
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def period(self) -> int:
        return {"attn": 1, "jamba": 8, "xlstm": 2}[self.pattern]

    @property
    def n_periods(self) -> int:
        n = self.num_layers - self.first_dense
        assert n % self.period == 0, (self.name, self.num_layers, self.period)
        return n // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kinds(self) -> list[dict]:
        """Blocks of one period, in order. kind: mixer + ffn type."""
        if self.pattern == "attn":
            return [{"mixer": "attn", "ffn": "moe" if self.moe else "dense"}]
        if self.pattern == "jamba":
            kinds = []
            for i in range(8):
                mixer = "attn" if i == 0 else "mamba"
                ffn = "moe" if (self.moe and i % self.moe_every == self.moe_offset) else "dense"
                kinds.append({"mixer": mixer, "ffn": ffn})
            return kinds
        if self.pattern == "xlstm":
            # xLSTM blocks are self-contained (d_ff = 0): no separate FFN.
            return [{"mixer": "mlstm", "ffn": "none"}, {"mixer": "slstm", "ffn": "none"}]
        raise ValueError(self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    """One of the assigned (seq_len, global_batch) workload points."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class FedConfig:
    """FedALIGN / federation hyper-parameters (paper §3-4)."""
    num_clients: int = 60
    num_priority: int = 2
    local_epochs: int = 5             # E
    epsilon: float = 0.2              # selection threshold eps_t
    epsilon_decay: float = 0.0        # eps_t = epsilon * (1 - decay)^round (fine-tuning)
    epsilon_schedule: str = "constant"  # constant | linear | exp | step
    warmup_frac: float = 0.1          # priority-only warm-up rounds
    rounds: int = 100
    lr: float = 0.1
    lr_schedule: str = "constant"     # constant | paper_decay (2/(mu(t+gamma)))
    mu_strong: float = 1.0            # mu for paper_decay
    gamma_decay: float = 10.0         # gamma for paper_decay
    participation: float = 1.0        # fraction sampled per round (<1 = partial)
    straggler_period: int = 0         # >0: non-priority client k only shows up
                                      # every (2 + k % period) rounds — the
                                      # paper's App. A.4 arbitrary-participation
                                      # model (stragglers)
    candidate_pool: int = 0           # sample-then-evaluate population scaling
                                      # (cross-device regime of arXiv:
                                      # 2211.01549): each round draws a
                                      # candidate pool of P clients — priority
                                      # clients always in-pool, the remaining
                                      # P - num_priority sampled without
                                      # replacement from the round PRNG
                                      # stream — and ONLY the [P] slice pays
                                      # the eval pre-pass, gating, cohort
                                      # gather, training, and the fused
                                      # fedagg; the dense [C] state leaves
                                      # (backlog, util/incl EMAs, ef_accum)
                                      # are touched by gather/scatter at the
                                      # sampled indices only, so round cost
                                      # is O(P), flat in C. 0 disables
                                      # pooling; P >= num_clients also runs
                                      # the dense round (everyone is a
                                      # candidate) — both are bit-identical
                                      # to the legacy trace. Requires
                                      # P >= num_priority when on
    pool_weighting: str = "uniform"   # candidate-pool sampling weights for
                                      # the non-priority draw (Gumbel top-k,
                                      # i.e. sampling without replacement
                                      # proportional to the weight):
                                      # "uniform" — every non-priority client
                                      # equally likely | "backlog" — weight
                                      # 1 + backlog_k, so clients starved by
                                      # cohort overflow re-enter the pool
                                      # sooner | "ema" — weight
                                      # (1 + tiny) - incl_ema_k, so rarely-
                                      # included clients are re-sampled and
                                      # their utility estimate keeps
                                      # refreshing
    algorithm: str = "fedavg"         # local solver: fedavg | fedprox
    prox_mu: float = 1.0              # FedProx proximal coefficient
    selection: str = "fedalign"       # SelectionStrategy name (fl/engine.py
                                      # registry): fedalign | all |
                                      # priority_only | topk_align | grad_sim
                                      # | welfare
    topk: int = 4                     # topk_align budget: at most k best
                                      # loss-matched non-priority clients
    sim_threshold: float = 0.0        # grad_sim: min cosine(delta_k, delta_P)
    grad_sim_sketch: bool = False     # grad_sim: score clients on a
                                      # CountSketch random projection of
                                      # their delta instead of the exact
                                      # [C, M_total] flatten (streaming-
                                      # friendly; JL-approximate cosines)
    sketch_dim: int = 256             # sketch width for grad_sim_sketch and
                                      # the temporal (FSDP) grad_sim round
    utility_ema: float = 0.9          # decay beta of the cross-round client
                                      # utility EMAs (loss-gap + inclusion
                                      # history) carried in FederationState
    welfare_floor: float = 0.0        # welfare strategy: non-priority
                                      # clients whose inclusion EMA fell
                                      # below this floor are admitted even
                                      # when their smoothed loss gap is
                                      # outside eps_t (fairness floor after
                                      # Travadi et al., arXiv:2302.08976);
                                      # 0 disables the floor
    backend: str = "vmap_spatial"     # engine execution backend:
                                      # vmap_spatial (clients in parallel) |
                                      # scan_temporal (time-multiplexed) |
                                      # scan_async (overlapped cohorts: the
                                      # round's aggregated delta is applied
                                      # async_depth rounds later)
    async_depth: int = 0              # scan_async pipeline depth D: the
                                      # cohort gathered at round t trains
                                      # against w_t but its aggregated delta
                                      # is applied at round t + D, while
                                      # rounds t+1..t+D-1 evaluate/gate
                                      # without waiting for it. The D
                                      # in-flight deltas live in
                                      # FederationState.inflight (a ring
                                      # buffer, oldest first). 0 = fully
                                      # synchronous: scan_async is then
                                      # bit-identical to vmap_spatial
    staleness_decay: float = 1.0      # per-round discount on stale deltas:
                                      # a delta applied with staleness s is
                                      # scaled by staleness_decay ** s
                                      # before the ServerOptimizer step
                                      # (1.0 = no discount; cf. async FL
                                      # buffers, arXiv:2402.05050). Under
                                      # async_mode="fifo" s is always the
                                      # constant async_depth; under "ready"
                                      # s is the slot's measured age
    async_mode: str = "fifo"          # in-flight pop policy (scan_async):
                                      # "fifo"  — strict fixed-lag pipe:
                                      #   every delta ages exactly
                                      #   async_depth rounds (the PR 4
                                      #   pipeline, bit-identical)
                                      # "ready" — FedBuff-style variable
                                      #   lag: any slot whose age reached
                                      #   min_lag is applied, oldest first,
                                      #   possibly several per round; the
                                      #   buffer only fills to min_lag in
                                      #   steady state, async_depth is its
                                      #   capacity
    min_lag: int = 1                  # async_mode="ready": minimum rounds a
                                      # buffered delta must age before it
                                      # may be applied (its readiness
                                      # threshold). Must satisfy
                                      # 1 <= min_lag <= async_depth (a
                                      # delta can never pop the round it
                                      # was pushed, so 0 would silently
                                      # mean 1); a full buffer with no
                                      # ready slot force-pops the oldest
                                      # (FedBuff overflow rule)
    latency_mode: str = "none"        # per-client latency model for the
                                      # event-driven clock: "none" (disabled:
                                      # no latency leaves, no timers — the
                                      # pinned fixed-lag behaviour) |
                                      # "lognormal" (compute + network times
                                      # drawn ONCE per client at init_state,
                                      # in round units, from the latency_*
                                      # knobs; systems-heterogeneity model of
                                      # arXiv:2211.01549). With scan_async it
                                      # requires async_mode="ready": each
                                      # pushed slot carries a countdown timer
                                      # set by its SLOWEST surviving member
                                      # and lands when the timer expires, so
                                      # staleness becomes a measured
                                      # distribution instead of a fixed depth
    latency_mu: float = 0.0           # lognormal compute-time log-mean
    latency_sigma: float = 0.5        # lognormal compute-time log-std (>= 0)
    latency_net_mu: float = -1.0      # lognormal network-time log-mean
    latency_net_sigma: float = 0.3    # lognormal network-time log-std (>= 0)
    round_deadline: float = float("inf")  # deadline (round units) on simulated
                                      # completion times: clients slower than
                                      # the deadline are dropped from the
                                      # round's aggregate (partial-cohort
                                      # landing through the zero-mass-safe
                                      # fedagg path) and re-enqueued via the
                                      # backlog; under the event clock the
                                      # slot timer is capped at
                                      # ceil(round_deadline). Requires a
                                      # latency model; must be > 0 (a zero/
                                      # negative deadline would force-land
                                      # every slot empty — rejected by
                                      # check_clock_config)
    failure_model: str = "none"       # FailureModel registry name
                                      # (fl/engine.py): none | crash (per-
                                      # round Bernoulli: delta lost AFTER
                                      # training, mass masked, backlog
                                      # re-enqueue) | dropout (client
                                      # unavailable for dropout_len-round
                                      # windows, folded into the
                                      # participation mask) | corrupt
                                      # (delta rows NaN'd or scaled in
                                      # transit via the delta_transform
                                      # seam) | chaos (all three composed).
                                      # Keyed from fold_in(seed,
                                      # "failure_model") x absolute round —
                                      # bit-reproducible and resume-safe
    crash_rate: float = 0.0           # crash/chaos: per-client per-round
                                      # Bernoulli crash probability in [0, 1]
    dropout_rate: float = 0.0         # dropout/chaos: probability in [0, 1]
                                      # a client sits out a whole window
    dropout_len: int = 1              # dropout/chaos: window length k >= 1
                                      # (rounds) of a transient drop-out
    corrupt_rate: float = 0.0         # corrupt/chaos: per-client per-round
                                      # corruption probability in [0, 1]
    corrupt_scale: float = 0.0        # corrupt/chaos: corrupted deltas are
                                      # scaled by this factor; 0.0 means the
                                      # payload is garbled to NaN instead
                                      # (the divergence guard's target)
    divergence_guard: bool = False    # detect non-finite aggregated deltas /
                                      # eval loss inside the scanned driver
                                      # and lax.cond-skip the apply (bit-
                                      # exact no-op, like the zero-inclusion
                                      # skip); consecutive skips counted in
                                      # the nonfinite_skips state leaf and
                                      # surfaced as stats["skipped_nonfinite"]
    max_nonfinite_skips: int = 0      # divergence_guard: run_federation
                                      # halts-and-reports once this many
                                      # CONSECUTIVE rounds skipped on
                                      # non-finite aggregates (0 = never
                                      # halt, guard still skips/counts)
    adaptive_staleness: bool = False  # discount stale deltas by MEASURED
                                      # drift instead of age alone: each
                                      # applied delta is scaled by
                                      # staleness_decay**age *
                                      # max(0, cos(delta, last applied
                                      # delta)), with the cosine estimated
                                      # on sketch_dim CountSketches (the
                                      # last_delta leaf in FederationState).
                                      # False keeps the constant schedule
                                      # (the pinned PR 4 fallback)
    max_cohort: int = 0               # static training-cohort budget K for
                                      # gate-before-train strategies (those
                                      # not needing client deltas): gates are
                                      # computed from the cheap eval pre-pass,
                                      # the K included clients are gathered
                                      # into a dense [K, ...] buffer, and only
                                      # they run E local epochs. 0 disables
                                      # the gather (train everyone; gated-out
                                      # updates dropped at aggregation).
                                      # Overflow policy: if more than K
                                      # clients gate in, priority clients are
                                      # kept first, then the best loss-matched
                                      # non-priority clients; the worst-
                                      # matched overflow is dropped for the
                                      # round (deterministic, stable order)
    backlog_boost: float = 0.0        # cohort overflow priority boost: the
                                      # cohort rank becomes
                                      # |F_k - F| - backlog_boost * backlog,
                                      # so a starved-but-close client can
                                      # OUTRANK a slightly better-matched
                                      # one instead of only winning exact
                                      # ties (float match qualities almost
                                      # never tie exactly). 0.0 keeps the
                                      # pinned tie-break-only policy
                                      # bit-identical
    align_stat: str = "accuracy"      # accuracy (paper experiments) | loss (theory)
    server_opt: str = "none"          # ServerOptimizer registry name
                                      # (core/aggregation.py): sgd (= the
                                      # legacy "none") | momentum (FedAvgM)
                                      # | adam (FedAdam) | yogi (FedYogi),
                                      # applied to the fused aggregated
                                      # delta; moments persist across
                                      # rounds in FederationState.opt_state
    server_lr: float = 1.0
    server_momentum: float = 0.9
    aggregator: str = "mean"          # Aggregator registry name
                                      # (core/aggregation.py): how the gated
                                      # client deltas are REDUCED, always in
                                      # the one fused fedagg kernel launch:
                                      # mean (paper eq. (15), default) |
                                      # trimmed_mean | median (coordinate-
                                      # wise robust order statistics,
                                      # unweighted over included clients) |
                                      # dp (per-client L2 clip + Gaussian
                                      # noise, DP-FedAvg) | cosine_filter
                                      # (drop delta-sketch outliers, then
                                      # mean)
    trim_frac: float = 0.1            # trimmed_mean: fraction of the n
                                      # included clients trimmed from EACH
                                      # side per coordinate
                                      # (floor(trim_frac * n); must be
                                      # < 0.5). Robust to up to
                                      # floor(trim_frac * n) Byzantine
                                      # clients
    dp_clip: float = 1.0              # dp: per-client delta L2 clip bound S
                                      # (the DP sensitivity); clients over
                                      # the bound are scaled down, never up
    dp_noise: float = 0.0             # dp: noise multiplier z — per-
                                      # coordinate sigma is
                                      # z * dp_clip / inclusion_mass on the
                                      # renormalized mean. 0 = clip-only.
                                      # (eps, delta) over rounds comes from
                                      # the RDP accountant (dp_epsilon in
                                      # core/aggregation.py) at dp_delta
    dp_delta: float = 1e-5            # dp: target delta for the reported
                                      # (epsilon, delta) privacy budget
    outlier_cos: float = 0.0          # cosine_filter: clients whose sketch-
                                      # estimated delta-direction cosine to
                                      # the gated mean direction falls
                                      # BELOW this are gated out for the
                                      # round (0 drops anti-correlated
                                      # deltas; sketches are sketch_dim
                                      # CountSketches)
    server_b1: float = 0.9            # adam/yogi first-moment decay
    server_b2: float = 0.99           # adam/yogi second-moment decay
                                      # (FedOpt paper default)
    server_eps: float = 1e-3          # adam/yogi denominator floor (tau)
    agg_dtype: str = "float32"        # dtype of aggregated client DELTAS on the
                                      # wire (bfloat16 halves FedALIGN's
                                      # aggregation collective — beyond-paper)
    wire_codec: str = "identity"      # WireCodec registry name
                                      # (core/aggregation.py): lossy uplink
                                      # compression of the fused [C, M_total]
                                      # client-delta buffer, decoded INSIDE
                                      # the one fedagg kernel launch:
                                      # identity (no codec — the pinned
                                      # legacy wire, agg_dtype only) | int8
                                      # (symmetric per-client-row int8 with
                                      # one f32 scale per client,
                                      # dequantize-in-register) | topk (per-
                                      # client magnitude top-k
                                      # sparsification, sparse-scatter-
                                      # accumulate) | sketch (CountSketch
                                      # rows — delta_sketch infra — decoded
                                      # by hash/sign gather). Non-identity
                                      # codecs carry per-client error-
                                      # feedback accumulators in
                                      # FederationState.ef_accum (see
                                      # error_feedback)
    error_feedback: bool = True       # non-identity wire_codec: carry the
                                      # per-client compression residual
                                      # x - decode(encode(x)) in
                                      # FederationState.ef_accum and add it
                                      # to the NEXT round's delta before
                                      # encoding (EF / EF21-style memory),
                                      # so compression bias is re-injected
                                      # instead of lost and convergence
                                      # doesn't stall. Updates at PUSH time
                                      # under scan_async (when the delta is
                                      # encoded, not when it lands). Ignored
                                      # by identity
    codec_topk_frac: float = 0.01     # topk codec: fraction of M_total kept
                                      # per client row (k = max(1,
                                      # floor(frac * M)); values + int32
                                      # indices travel the wire). Must be in
                                      # (0, 1]
    codec_sketch_dim: int = 2048      # sketch codec: CountSketch width per
                                      # client row (the uplink is [C,
                                      # codec_sketch_dim] f32; one shared
                                      # hash/sign stream per run keyed from
                                      # fold_in(seed, "wire_sketch")). Must
                                      # be >= 1
    use_pallas: bool = False          # aggregate via the fedagg Pallas TPU
                                      # kernel (CPU keeps the jnp lowering)
    fused_agg: bool = True            # flatten the whole client-stacked pytree
                                      # to [C, M_total]: ONE fedagg call per
                                      # round instead of one per leaf
    batch_size: int = 32              # local minibatch
    seed: int = 0

    def replace(self, **kw) -> "FedConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Config validation: ONE entry point, decorator-registered subsystem hooks.
#
# The async, clock, aggregator, and codec checks used to be four scattered
# ``check_*_config`` functions every caller had to know to call (and in the
# right combination); now each subsystem contributes its check with
# ``@register_validator("name")`` at import time and every round builder /
# driver / CLI calls the single ``validate_config(fed)``. The old names
# survive as thin deprecated aliases of the registered hooks.
_VALIDATORS: dict = {}


def register_validator(name: str):
    """Decorator: contribute a subsystem's FedConfig check to
    ``validate_config``. The hook takes ``fed`` and raises ``ValueError``
    (with an actionable message) on an invalid knob combination; hooks run
    in sorted-name order, so error precedence is deterministic."""
    def deco(fn):
        _VALIDATORS[name] = fn
        return fn
    return deco


def validate_config(fed: "FedConfig") -> "FedConfig":
    """Run every registered subsystem validator against ``fed``.

    Returns ``fed`` unchanged so call sites can validate inline:
    ``fed = validate_config(fed)``. Importing the standard subsystems here
    (they register their hooks at import) means a bare
    ``validate_config(fed)`` never silently skips checks the caller's
    import graph happened not to pull in."""
    from repro.core import aggregation  # noqa: F401  (registers hooks)
    from repro.fl import engine         # noqa: F401  (registers hooks)
    for name in sorted(_VALIDATORS):
        _VALIDATORS[name](fed)
    return fed


@register_validator("population")
def check_pool_config(fed: "FedConfig") -> None:
    """Candidate-pool knobs (the population-scaling subsystem's hook)."""
    if fed.candidate_pool < 0:
        raise ValueError(
            f"candidate_pool must be >= 0, got {fed.candidate_pool} "
            "(0 disables pooling)")
    if fed.pool_weighting not in ("uniform", "backlog", "ema"):
        raise ValueError(
            f"unknown pool_weighting {fed.pool_weighting!r}; "
            "valid: ['backlog', 'ema', 'uniform']")
    if 0 < fed.candidate_pool < fed.num_priority:
        raise ValueError(
            f"candidate_pool={fed.candidate_pool} is smaller than "
            f"num_priority={fed.num_priority}: priority clients are always "
            "in-pool, so the pool must hold at least all of them")
