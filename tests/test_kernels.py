"""Per-kernel allclose tests: Pallas (interpret=True) and the production jnp
paths, swept over shapes/dtypes, against the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fedagg import fedagg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape).astype(dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd", [
    (1, 128, 128, 4, 4, 32),      # MHA
    (2, 128, 128, 8, 2, 64),      # GQA
    (1, 64, 256, 4, 1, 32),       # MQA, q shorter than kv
    (2, 256, 256, 6, 2, 16),      # odd head dim grouping
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 48])
def test_flash_attention(B, Sq, Skv, H, KV, hd, dtype, window):
    q = rand((B, Sq, H, hd), dtype, 1)
    k = rand((B, Skv, KV, hd), dtype, 2)
    v = rand((B, Skv, KV, hd), dtype, 3)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    got_jnp = ops.flash_attention(q, k, v, causal=True, window=window, block_kv=64)
    got_pal = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got_jnp, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(got_pal, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_nondivisible_kv():
    """kv length not a block multiple (whisper's 1500 frames)."""
    q = rand((1, 96, 4, 32), k=1)
    k = rand((1, 96, 4, 32), k=2)
    v = rand((1, 96, 4, 32), k=3)
    want = ref.attention_ref(q, k, v, causal=False)
    got = ops.flash_attention(q, k, v, causal=False, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ decode attention
@pytest.mark.parametrize("B,Skv,H,KV,hd", [
    (1, 256, 4, 4, 32), (3, 512, 8, 2, 64), (2, 128, 4, 1, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, Skv, H, KV, hd, dtype):
    q = rand((B, 1, H, hd), dtype, 4)
    kc = rand((B, Skv, KV, hd), dtype, 5)
    vc = rand((B, Skv, KV, hd), dtype, 6)
    kv_len = Skv - 37
    want = ref.decode_attention_ref(q, kc, vc, kv_len=kv_len)
    got_jnp = ops.decode_attention(q, kc, vc, kv_len=kv_len)
    got_pal = decode_attention_pallas(q, kc, vc, kv_len=kv_len,
                                      block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got_jnp, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(got_pal, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# --------------------------------------------------------------------- fedagg
@pytest.mark.parametrize("C,M", [(4, 64), (16, 1000), (60, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedagg(C, M, dtype):
    u = rand((C, M), dtype, 7)
    w = jax.random.uniform(jax.random.fold_in(KEY, 8), (C,))
    g = (jax.random.uniform(jax.random.fold_in(KEY, 9), (C,)) > 0.4).astype(jnp.float32)
    g = g.at[0].set(1.0)                       # never empty
    want = ref.fedagg_ref(u, w, g)
    got_jnp = ops.fedagg(u, w, g)
    got_pal = fedagg_pallas(u, w, g, block_m=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got_jnp, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(got_pal, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_fedagg_one_hot_returns_that_client():
    u = rand((5, 128), k=10)
    w = jnp.ones((5,))
    g = jnp.zeros((5,)).at[3].set(1.0)
    out = ops.fedagg(u, w, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(u[3]), atol=1e-6)


@pytest.mark.parametrize("C,M", [
    (1, 64),        # single client
    (1, 7),         # single client, M far below the lane width
    (4, 100),       # M not a lane multiple
    (5, 513),       # M just past a block boundary, C not a power of two
    (3, 2065),      # multi-block grid with a ragged tail
])
@pytest.mark.parametrize("agg", ["mean", "trimmed_mean", "median", "dp"])
def test_fedagg_shape_sweep_all_aggregators(C, M, agg):
    """fedagg_pallas (interpret) and the jnp lowering vs the naive refs on
    awkward shapes: M not a lane multiple, M < block_m, C == 1. Every
    registered in-kernel aggregator inherits the edge coverage."""
    u = rand((C, M), jnp.float32, k=C * 1009 + M)
    w = jax.random.uniform(jax.random.fold_in(KEY, C + M), (C,)) + 0.05
    g = (jax.random.uniform(jax.random.fold_in(KEY, C + M + 1), (C,)) > 0.3
         ).astype(jnp.float32)
    g = g.at[0].set(1.0)                       # never empty
    kw = {}
    if agg == "trimmed_mean":
        kw = dict(trim_frac=0.25)
        want = ref.fedagg_trimmed_ref(u, w, g, 0.25)
    elif agg == "median":
        want = ref.fedagg_median_ref(u, w, g)
    elif agg == "dp":
        norms = jnp.sqrt(jnp.sum(u.astype(jnp.float32) ** 2, axis=1))
        rs = jnp.minimum(1.0, 1.0 / jnp.maximum(norms, 1e-12))
        nz = jax.random.normal(jax.random.fold_in(KEY, C * 7 + M), (M,))
        kw = dict(row_scale=rs, noise=nz, noise_scale=0.7)
        want = ref.fedagg_dp_ref(u, w, g, rs, nz, 0.7)
    else:
        want = ref.fedagg_ref(u, w, g)
    got_jnp = ops.fedagg(u, w, g, aggregator=agg, **kw)
    got_pal = fedagg_pallas(u, w, g, block_m=256, interpret=True,
                            aggregator=agg, **kw)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pal), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------- fedagg x wire codecs
CODEC_EDGE_SHAPES = [
    (1, 64),        # single client
    (1, 7),         # single client, M far below the lane width
    (4, 100),       # M not a lane multiple
    (65, 513),      # C past the 64-lane sublane tile, ragged M
    (3, 2065),      # multi-block grid with a ragged tail
]


def _codec_inputs(C, M, codec):
    """Encode a random [C, M] buffer (row C//2 forced all-zero — the int8
    scale-1.0 / topk zero-value / sketch empty-bucket edge) through the
    registry codec, returning (enc, codec_kw, decoded_ref)."""
    from repro.configs.base import FedConfig
    from repro.core.aggregation import get_wire_codec

    # sketch_dim < M forces hash collisions — a dim >= M sketch can be
    # lossless and the decode parity would not exercise the gather
    fed = FedConfig(codec_topk_frac=0.1, codec_sketch_dim=max(2, M // 3),
                    seed=3)
    u = rand((C, M), jnp.float32, k=C * 1013 + M)
    u = u.at[C // 2].set(0.0)
    cls = get_wire_codec(codec)
    enc, kw = cls.encode(fed, u)
    if codec == "int8":
        want_dec = ref.decode_int8_ref(enc, kw["dequant_scale"])
    elif codec == "topk":
        want_dec = ref.decode_topk_ref(enc, kw["topk_idx"], M)
    else:
        want_dec = ref.decode_sketch_ref(enc, kw["sketch_h"],
                                         kw["sketch_sign"])
    dec = cls.decode(fed, enc, kw, M)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(want_dec),
                               atol=2e-5, rtol=2e-5)
    return enc, kw, want_dec


@pytest.mark.parametrize("C,M", CODEC_EDGE_SHAPES)
@pytest.mark.parametrize("codec", ["int8", "topk", "sketch"])
@pytest.mark.parametrize("agg", ["mean", "trimmed_mean", "median", "dp"])
def test_fedagg_codec_aggregator_sweep(C, M, codec, agg):
    """Every codec x aggregator pair: the fused decode-and-reduce (Pallas
    interpret and the jnp lowering) must match decode-then-reduce through
    the naive refs on the same edge shapes the dense sweep pins — plus an
    all-zero client row per case."""
    enc, codec_kw, dec = _codec_inputs(C, M, codec)
    w = jax.random.uniform(jax.random.fold_in(KEY, C * 7 + M), (C,)) + 0.05
    g = (jax.random.uniform(jax.random.fold_in(KEY, C * 7 + M + 1), (C,))
         > 0.3).astype(jnp.float32)
    g = g.at[0].set(1.0)                       # never empty
    g = g.at[C // 2].set(1.0)                  # the zero row is gated IN
    kw = {}
    if agg == "trimmed_mean":
        kw = dict(trim_frac=0.25)
        want = ref.fedagg_trimmed_ref(dec, w, g, 0.25)
    elif agg == "median":
        want = ref.fedagg_median_ref(dec, w, g)
    elif agg == "dp":
        norms = jnp.sqrt(jnp.sum(dec.astype(jnp.float32) ** 2, axis=1))
        rs = jnp.minimum(1.0, 1.0 / jnp.maximum(norms, 1e-12))
        nz = jax.random.normal(jax.random.fold_in(KEY, C * 11 + M), (M,))
        kw = dict(row_scale=rs, noise=nz, noise_scale=0.7)
        want = ref.fedagg_dp_ref(dec, w, g, rs, nz, 0.7)
    else:
        want = ref.fedagg_ref(dec, w, g)
    got_jnp = ops.fedagg(enc, w, g, aggregator=agg, **kw, **codec_kw)
    got_pal = fedagg_pallas(enc, w, g, block_m=256, interpret=True,
                            aggregator=agg, **kw, **codec_kw)
    assert got_jnp.dtype == jnp.float32 and got_pal.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pal), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_fedagg_codec_all_rows_zero():
    """An entirely-zero buffer through every codec still aggregates to
    exact zero (int8 scale floors at 1.0; sketch buckets are empty)."""
    C, M = 5, 130
    from repro.configs.base import FedConfig
    from repro.core.aggregation import get_wire_codec

    fed = FedConfig(codec_topk_frac=0.1, codec_sketch_dim=32, seed=3)
    u = jnp.zeros((C, M), jnp.float32)
    w = jnp.ones((C,))
    g = jnp.ones((C,))
    for codec in ("int8", "topk", "sketch"):
        enc, kw = get_wire_codec(codec).encode(fed, u)
        for out in (ops.fedagg(enc, w, g, **kw),
                    fedagg_pallas(enc, w, g, block_m=64, interpret=True,
                                  **kw)):
            np.testing.assert_array_equal(np.asarray(out),
                                          np.zeros((M,), np.float32))


# -------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(4, 37, 128), (2, 256), (1, 5, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = rand(shape, dtype, 11)
    s = jax.random.uniform(jax.random.fold_in(KEY, 12), (shape[-1],))
    want = ref.rmsnorm_ref(x, s)
    got = rmsnorm_pallas(x, s, block_r=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ------------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("Bt,S,Di,N,chunk", [
    (1, 64, 16, 4, 16), (2, 128, 32, 8, 32), (2, 96, 8, 16, 32),
])
def test_ssm_scan(Bt, S, Di, N, chunk):
    x = rand((Bt, S, Di), k=13) * 0.5
    dt = jax.nn.softplus(rand((Bt, S, Di), k=14)) * 0.1
    A = -jnp.exp(rand((Di, N), k=15) * 0.5)
    B = rand((Bt, S, N), k=16)
    C = rand((Bt, S, N), k=17)
    D = rand((Di,), k=18)
    want = ref.ssm_scan_ref(x, dt, A, B, C, D)
    got_jnp = ops.ssm_scan(x, dt, A, B, C, D, chunk=chunk)
    got_pal = ssm_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                              block_d=max(Di // 2, 1), interpret=True)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(got_pal), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_ssm_step_matches_scan():
    """Sequential decode steps reproduce the chunked scan."""
    Bt, S, Di, N = 2, 16, 8, 4
    x = rand((Bt, S, Di), k=19) * 0.5
    dt = jax.nn.softplus(rand((Bt, S, Di), k=20)) * 0.1
    A = -jnp.exp(rand((Di, N), k=21) * 0.5)
    B = rand((Bt, S, N), k=22)
    C = rand((Bt, S, N), k=23)
    D = rand((Di,), k=24)
    want = ref.ssm_scan_ref(x, dt, A, B, C, D)
    h = jnp.zeros((Bt, Di, N))
    outs = []
    for t in range(S):
        h, y = ops.ssm_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        outs.append(y + x[:, t] * D[None])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-4)
