"""SYNTH(alpha, beta) federated dataset — paper App. B.2, implemented exactly.

Priority clients: per-client model y = argmax(softmax(W_k x + b_k)) with
W_k, b_k ~ N(u_k, 1), u_k ~ N(0, alpha); x ~ N(v_k, Sigma),
Sigma_jj = j^-1.2; v_k elements ~ N(B_k, 1), B_k ~ N(0, beta).

Non-priority clients receive *global* data (one shared (W_g, b_g) model,
x ~ N(0, Sigma)) with two progressive noise processes (App. B.2):
  1. label flips    — per-client flip fraction up to ``label_noise_factor``,
                      skewed across clients by ``label_noise_skew``;
  2. irrelevant data — fraction of points replaced by an independent
                      distribution (x ~ N(0, I), uniform labels), up to
                      ``random_data_factor`` with ``random_data_skew``.

Per-client noise level: client with rank r in [0,1] gets
level = min(1, factor * r^skew): high skew => most clients near the max
(the paper: "high skews imply a larger number of non-priority clients are
misaligned").
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

DIM = 60
NUM_CLASSES = 10

# paper Fig. 2 noise presets: (label_noise_skew, random_data_skew)
NOISE_PRESETS = {"low": 0.5, "medium": 1.5, "high": 5.0}


@dataclass
class Federation:
    """In-memory federated dataset: equal-sized client arrays."""
    x: np.ndarray          # [C, n, ...]
    y: np.ndarray          # [C, n]
    priority_mask: np.ndarray  # [C] bool
    weights: np.ndarray    # [C] p_k; priority mass sums to 1
    test_x: np.ndarray     # global (priority-distribution) test set
    test_y: np.ndarray
    client_test_x: np.ndarray | None = None   # [C, m, ...] per-client test
    client_test_y: np.ndarray | None = None


def _sigma():
    return np.diag(np.arange(1, DIM + 1, dtype=np.float64) ** -1.2)


def _sample_model(rng, alpha):
    u = rng.normal(0, np.sqrt(alpha))
    W = rng.normal(u, 1, size=(NUM_CLASSES, DIM))
    b = rng.normal(u, 1, size=(NUM_CLASSES,))
    return W, b


def _sample_input_mean(rng, beta):
    Bk = rng.normal(0, np.sqrt(beta))
    return rng.normal(Bk, 1, size=(DIM,))


def _sample_inputs(rng, n, v, sigma):
    return rng.multivariate_normal(v, sigma, size=n)


def _labels(W, b, x):
    logits = x @ W.T + b
    return np.argmax(logits, axis=-1)


def _noise_level(rank, factor, skew):
    """Client at rank r in [0,1] gets min(1, factor * r^(1/skew)).
    High skew pushes most clients toward the maximum noise (paper: 'high
    skews imply a larger number of non-priority clients are misaligned')."""
    return float(min(1.0, factor * rank ** (1.0 / skew)))


def make_synth_federation(seed=0, alpha=1.0, beta=1.0, n_priority=10,
                          n_nonpriority=10, samples_per_client=200,
                          label_noise_factor=2.5, label_noise_skew=1.5,
                          random_data_factor=1.0, random_data_skew=1.5,
                          test_samples=2000) -> Federation:
    rng = np.random.default_rng(seed)
    sigma = _sigma()
    C = n_priority + n_nonpriority
    n = samples_per_client
    xs, ys = [], []

    # ---- priority clients: heterogeneous SYNTH(alpha, beta) ------------------
    pri_models = []
    for _ in range(n_priority):
        W, b = _sample_model(rng, alpha)
        v = _sample_input_mean(rng, beta)
        pri_models.append((W, b, v))
        x = _sample_inputs(rng, n, v, sigma)
        xs.append(x)
        ys.append(_labels(W, b, x))

    # ---- global data + test set: mixture over the priority clients' own
    #      (W_k, b_k, v_k) — i.e. fresh draws from the SAME distributions ------
    def global_batch(m):
        per = -(-m // n_priority)
        gx, gy = [], []
        for W, b, v in pri_models:
            x = _sample_inputs(rng, per, v, sigma)
            gx.append(x)
            gy.append(_labels(W, b, x))
        gx, gy = np.concatenate(gx)[:m], np.concatenate(gy)[:m]
        perm = rng.permutation(m)
        return gx[perm], gy[perm]

    test_x, test_y = global_batch(test_samples)

    # ---- non-priority clients: global data + progressive noise ----------------
    for i in range(n_nonpriority):
        rank = i / max(n_nonpriority - 1, 1)
        x, y = global_batch(n)
        flip_frac = _noise_level(rank, label_noise_factor, label_noise_skew)
        rand_frac = _noise_level(rank, random_data_factor, random_data_skew)
        nf = int(flip_frac * n)
        if nf:
            idx = rng.choice(n, nf, replace=False)
            y[idx] = rng.integers(0, NUM_CLASSES, nf)
        nr = int(rand_frac * n)
        if nr:
            idx = rng.choice(n, nr, replace=False)
            x[idx] = rng.normal(0, 1, size=(nr, DIM))
            y[idx] = rng.integers(0, NUM_CLASSES, nr)
        xs.append(x)
        ys.append(y)

    x = np.stack(xs).astype(np.float32)
    y = np.stack(ys).astype(np.int32)
    priority_mask = np.zeros(C, bool)
    priority_mask[:n_priority] = True
    weights = np.full(C, 1.0 / n_priority)   # equal D_k => p_k = 1/|P| for all
    return Federation(x, y, priority_mask, weights.astype(np.float32),
                      test_x.astype(np.float32), test_y.astype(np.int32))
