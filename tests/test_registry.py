"""utils.Registry error paths and the validate_config hook machinery —
the seams every pluggable table (strategies, aggregators, codecs, lint
rules) and every config check ride on."""
import pytest

from repro.configs import base as config_base
from repro.configs.base import FedConfig, register_validator, validate_config
from repro.utils import Registry


def test_register_stamps_attrs_and_returns_fn():
    reg = Registry("widget")

    @reg.register("alpha", widget_name="alpha", fancy=True)
    def alpha():
        return 1

    assert alpha.widget_name == "alpha"
    assert alpha.fancy is True
    assert reg["alpha"] is alpha
    assert alpha() == 1


def test_duplicate_registration_raises_with_kind_and_name():
    reg = Registry("widget")
    reg.register("alpha")(lambda: 1)
    with pytest.raises(ValueError, match="duplicate widget 'alpha'"):
        reg.register("alpha")(lambda: 2)


def test_unknown_lookup_lists_registered_names():
    reg = Registry("widget")
    reg.register("alpha")(lambda: 1)
    reg.register("beta")(lambda: 2)
    with pytest.raises(ValueError,
                       match=r"unknown widget 'gamma'; registered: "
                             r"\['alpha', 'beta'\]"):
        reg.lookup("gamma")


def test_alias_resolution_in_resolve_and_lookup():
    reg = Registry("widget", aliases={None: "alpha", "none": "alpha"})
    fn = reg.register("alpha")(lambda: 1)
    assert reg.resolve(None) == "alpha"
    assert reg.resolve("none") == "alpha"
    assert reg.resolve("alpha") == "alpha"
    assert reg.lookup(None) is fn
    # an alias pointing at an unregistered name still errors cleanly
    reg2 = Registry("widget", aliases={"fast": "missing"})
    with pytest.raises(ValueError, match="unknown widget 'fast'"):
        reg2.lookup("fast")


def test_names_sorted_and_dict_protocol():
    reg = Registry("widget")
    for name in ("zeta", "alpha", "mid"):
        reg.register(name)(lambda: None)
    assert reg.names() == ["alpha", "mid", "zeta"]
    assert "zeta" in reg and len(reg) == 3   # it IS a dict


# ------------------------------------------------------- validate_config


def test_validate_config_returns_fed_and_runs_standard_hooks():
    fed = FedConfig()
    assert validate_config(fed) is fed
    # the standard subsystem hooks registered at import
    for hook in ("aggregator", "async", "clock", "codec", "population"):
        assert hook in config_base._VALIDATORS


def test_validator_hooks_run_in_sorted_name_order():
    ran = []
    try:
        register_validator("zz_probe")(lambda fed: ran.append("zz_probe"))
        register_validator("aa_probe")(lambda fed: ran.append("aa_probe"))
        validate_config(FedConfig())
        assert ran == ["aa_probe", "zz_probe"]
    finally:
        config_base._VALIDATORS.pop("zz_probe", None)
        config_base._VALIDATORS.pop("aa_probe", None)


def test_validator_error_precedence_is_deterministic():
    # two invalid knobs from different subsystems: the sorted-first
    # hook's error is the one raised, every time
    fed = FedConfig(candidate_pool=-1, wire_codec="int8", fused_agg=False)
    msgs = set()
    for _ in range(3):
        with pytest.raises(ValueError) as ei:
            validate_config(fed)
        msgs.add(str(ei.value))
    assert len(msgs) == 1
