"""Config registry: one module per assigned architecture.

``get_config(name)`` returns the full-size config; ``get_smoke(name)`` a
reduced same-family variant for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import FedConfig, InputShape, ModelConfig, INPUT_SHAPES  # noqa: F401

ARCH_IDS = [
    "llava_next_34b",
    "phi3_mini_3_8b",
    "jamba_1_5_large_398b",
    "minicpm3_4b",
    "qwen2_5_3b",
    "whisper_medium",
    "xlstm_125m",
    "deepseek_moe_16b",
    "granite_moe_3b_a800m",
    "qwen1_5_0_5b",
]

# dashed aliases matching the assignment table
ALIASES = {
    "llava-next-34b": "llava_next_34b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen2.5-3b": "qwen2_5_3b",
    "whisper-medium": "whisper_medium",
    "xlstm-125m": "xlstm_125m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    assert name in ARCH_IDS, f"unknown arch {name!r}; known: {ARCH_IDS}"
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).smoke_config()
