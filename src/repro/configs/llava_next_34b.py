"""llava-next-34b [vlm] — LLaVA-NeXT with a 34B (Yi-34B-class) LM backbone.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] (anyres tiling), backbone scaled per
assignment: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The SigLIP/CLIP vision tower + projector are stubbed: ``input_specs()``
provides patch embeddings [B, num_image_tokens, d_model] directly (anyres =
base 576 tokens x tiles; we expose the token count as the tiling knob).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    vlm=True,
    num_image_tokens=576,             # one anyres base tile
    rope_theta=5_000_000.0,           # Yi-34B long-context base
    tie_embeddings=False,
    param_dtype="bfloat16",           # 34B fp32 exceeds per-device HBM at TP=16
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (anyres); backbone per assignment",
)


def smoke_config():
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, num_image_tokens=16, param_dtype="float32",
        compute_dtype="float32", loss_chunk=64, attn_block_kv=64, ssm_chunk=16)
