"""Paper Figure 4 (App C.2): FedALIGN adapted to FedProx (mu=1), 4 priority
clients — the selection rule is algorithm-independent."""
from __future__ import annotations

from benchmarks.common import fed_suite
from repro.data.shards import make_benchmark_federation


def run(fast=True, seeds=(0,)):
    rounds = 20 if fast else 150
    fedn = make_benchmark_federation("fmnist", seed=0, n_priority=4,
                                     samples_per_client=200 if fast else None)
    rows = fed_suite(fedn, "logreg",
                     dict(num_clients=fedn.x.shape[0], num_priority=4,
                          rounds=rounds, local_epochs=5, epsilon=0.2, lr=0.1,
                          warmup_frac=0.1, batch_size=32,
                          algorithm="fedprox", prox_mu=1.0),
                     seeds=seeds)
    for r in rows:
        r["algorithm"] = "fedprox"
    return rows


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "acc_curve"})
