"""Pure-jnp oracles for every Pallas kernel.

These are the *naive, obviously-correct* implementations used by the kernel
allclose tests (``tests/test_kernels.py``). They deliberately materialize
full intermediates (e.g. the [Sq, Skv] score matrix) — correctness first.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------- attention
def attention_ref(q, k, v, *, causal=True, window=0, kv_len=None, scale=None):
    """Naive full-scores GQA attention. q:[B,Sq,H,hd] k/v:[B,Skv,KV,hd]."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    q_offset = Skv - Sq  # queries are the last Sq positions
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask = mask & (k_pos[None, :] < kv_len)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", w, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, *, kv_len, scale=None):
    """Naive single-token attention against a cache with kv_len valid rows."""
    return attention_ref(q, k_cache, v_cache, causal=False, kv_len=kv_len, scale=scale)


# ------------------------------------------------------------------- fedagg
def fedagg_ref(updates, weights, gates):
    """FedALIGN gated weighted aggregation (paper eq. after (14)).

    updates: [C, M]  per-client flattened parameter updates
    weights: [C]     data fractions p_k (priority mass sums to 1)
    gates:   [C]     inclusion indicators I_k in {0,1} (priority rows = 1)
    returns: [M]     sum_k p_k g_k u_k / sum_k p_k g_k; exact 0 when no
                     client is included (zero inclusion mass), with
                     gated-out rows masked so their payload (possibly
                     non-finite) never enters the sum
    """
    wg = (weights * gates).astype(jnp.float32)
    u = jnp.where((wg > 0)[:, None], updates.astype(jnp.float32), 0.0)
    num = jnp.einsum("c,cm->m", wg, u)
    den = jnp.sum(wg)
    out = jnp.where(den > 0, num / jnp.maximum(den, 1e-30), 0.0)
    return out.astype(updates.dtype)


def _sorted_included_ref(updates, gates):
    """Values of included clients sorted ascending per column, plus count."""
    inc = gates > 0
    n = jnp.sum(inc.astype(jnp.int32))
    u = jnp.where(inc[:, None], updates.astype(jnp.float32), jnp.inf)
    return jnp.sort(u, axis=0), n


def fedagg_trimmed_ref(updates, weights, gates, trim_frac):
    """Coordinate-wise trimmed mean over included clients (unweighted,
    Yin et al., arXiv:1803.01498): drop the floor(trim_frac * n) smallest
    and largest values per coordinate, average the rest. n == 0 -> 0."""
    del weights
    C = updates.shape[0]
    s, n = _sorted_included_ref(updates, gates)
    t = jnp.floor(jnp.float32(trim_frac) * n.astype(jnp.float32)).astype(jnp.int32)
    idx = jnp.arange(C, dtype=jnp.int32)[:, None]
    keep = (idx >= t) & (idx < n - t)
    cnt = n - 2 * t
    total = jnp.sum(jnp.where(keep, s, 0.0), axis=0)
    out = jnp.where(cnt > 0, total / jnp.maximum(cnt, 1).astype(jnp.float32), 0.0)
    return out.astype(updates.dtype)


def fedagg_median_ref(updates, weights, gates):
    """Coordinate-wise median over included clients (unweighted); the even-n
    median averages the two central order statistics. n == 0 -> 0."""
    del weights
    C = updates.shape[0]
    s, n = _sorted_included_ref(updates, gates)
    idx = jnp.arange(C, dtype=jnp.int32)[:, None]
    lo, hi = (n - 1) // 2, n // 2
    med = 0.5 * (jnp.sum(jnp.where(idx == lo, s, 0.0), axis=0)
                 + jnp.sum(jnp.where(idx == hi, s, 0.0), axis=0))
    return jnp.where(n > 0, med, 0.0).astype(updates.dtype)


def fedagg_dp_ref(updates, weights, gates, row_scale, noise, noise_scale):
    """DP-FedAvg on the renormalized gated mean (McMahan et al.,
    arXiv:1710.06963): per-client clip factors ``row_scale`` [C] scale each
    included row inside the weighted sum; pre-drawn standard-normal
    ``noise`` [M] is added at sigma = noise_scale / inclusion_mass (the
    renormalized mean divides by the mass, so the noise must too)."""
    wg = (weights * gates).astype(jnp.float32)
    u = jnp.where((wg > 0)[:, None], updates.astype(jnp.float32), 0.0)
    # excluded rows mask their clip scale too (0 * NaN safety, as in ops)
    wgs = jnp.where(wg > 0, wg * row_scale.astype(jnp.float32), 0.0)
    num = jnp.einsum("c,cm->m", wgs, u)
    den = jnp.sum(wg)
    safe = jnp.maximum(den, 1e-30)
    noisy = num / safe + noise.astype(jnp.float32) * (jnp.float32(noise_scale) / safe)
    return jnp.where(den > 0, noisy, 0.0).astype(updates.dtype)


# ------------------------------------------------------------- wire decoders
def decode_int8_ref(q, scale):
    """Naive int8 row dequantization. q: [C, M] int8, scale: [C] -> [C, M] f32."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[:, None]


def decode_topk_ref(vals, idx, M):
    """Naive top-k densification via one-hot matmul.

    vals: [C, k] f32, idx: [C, k] i32 column indices (distinct within a
    row) -> [C, M] f32 with vals placed at their columns, zeros elsewhere.
    """
    onehot = (idx[..., None] == jnp.arange(M)[None, None, :]).astype(jnp.float32)
    return jnp.einsum("ck,ckm->cm", vals.astype(jnp.float32), onehot)


def decode_sketch_ref(s, h, sign):
    """Naive CountSketch estimate via one-hot matmul.

    s: [C, dim] f32 sketch rows, h: [M] i32 bucket ids, sign: [M] f32
    Rademacher signs -> [C, M] f32 where out[c, m] = s[c, h[m]] * sign[m].
    """
    dim = s.shape[1]
    onehot = (h[:, None] == jnp.arange(dim)[None, :]).astype(jnp.float32)  # [M, dim]
    return jnp.einsum("cd,md->cm", s.astype(jnp.float32), onehot) * sign[None, :]


# ------------------------------------------------------------------- rmsnorm
def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ ssm scan
def ssm_scan_ref(x, dt, A, B, C, D):
    """Sequential selective-scan oracle (Mamba S6).

    x:  [Bt, S, Di]      input sequence
    dt: [Bt, S, Di]      positive step sizes (already softplus'd)
    A:  [Di, N]          (negative) state matrix, diagonal over Di
    B:  [Bt, S, N]       input projection
    C:  [Bt, S, N]       output projection
    D:  [Di]             skip
    returns y: [Bt, S, Di]
    """
    Bt, S, Di = x.shape
    N = A.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Af, Bf, Cf, Df = (A.astype(jnp.float32), B.astype(jnp.float32),
                      C.astype(jnp.float32), D.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Btt, Ctt = inp                       # [Bt,Di],[Bt,Di],[Bt,N],[Bt,N]
        dA = jnp.exp(dtt[..., None] * Af[None])       # [Bt,Di,N]
        dB = dtt[..., None] * Btt[:, None, :]         # [Bt,Di,N]
        h = dA * h + dB * xt[..., None]
        y = jnp.einsum("bdn,bn->bd", h, Ctt)
        return h, y

    h0 = jnp.zeros((Bt, Di, N), jnp.float32)
    xs = (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xf * Df[None, None]
    return y.astype(x.dtype)
