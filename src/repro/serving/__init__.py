from repro.serving.scheduler import BatchScheduler, Request  # noqa: F401
